"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os
import time


def run():
    path = os.environ.get("DRYRUN_JSON", "reports/dryrun.json")
    rows = []
    t0 = time.time()
    if not os.path.exists(path):
        return [("roofline_table", 0.0,
                 f"missing {path}; run python -m repro.launch.dryrun --all")]
    with open(path) as f:
        results = json.load(f)
    ok = [r for r in results if r.get("status") == "OK"
          and r.get("mesh") == "16x16" and "roofline" in r]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}",
            (time.time() - t0) * 1e6 / max(len(ok), 1),
            f"compute={rf['compute_s']:.3e}s mem={rf['memory_s']:.3e}s "
            f"coll={rf['collective_s']:.3e}s dominant={rf['dominant']} "
            f"useful={rf['useful_ratio']:.3f}"))
    skips = [r for r in results if r.get("status") == "SKIP"
             and r.get("mesh") == "16x16"]
    for r in skips:
        rows.append((f"roofline_{r['arch']}_{r['shape']}", 0.0,
                     "SKIP: " + r.get("reason", "")[:80]))
    return rows
