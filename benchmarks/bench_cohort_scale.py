"""Cohort vs event engine throughput at C in {64, 512, 4096}.

Derived metric: client-rounds/sec per engine and the cohort speedup.
Both engines run the identical workload (same task, sizes, step sizes,
d=1), selected through ``make_simulator(FLConfig(engine=...), ...)``.
jit caches live on the task objects — the event engine's per-chunk fns
on the LogRegTask, the cohort engine's block fns on the CohortLogRegTask
— so each engine is warmed by one run and timed on a fresh simulator
that reuses the warm task: the event engine at small C (its per-chunk
jits are population-independent), the cohort engine at full C (its
vmapped block fns compile per population size).

Also writes ``BENCH_cohort.json`` (cwd) with the raw numbers.
"""
from __future__ import annotations

import json
import time

from repro.cohort import make_simulator
from repro.configs.base import FLConfig
from repro.core import LogRegTask
from repro.data import make_binary_dataset

COHORTS = [64, 512, 4096]
ROUNDS = 2
S = 8                       # iterations per round per client
ETAS = [0.1, 0.08]
EVENT_CAP = 4096            # largest C the event engine is timed at


def _mk_task(X, y):
    return LogRegTask(X, y, l2=1.0 / len(X), sample_seed=0)


def _time_run(sim) -> float:
    t0 = time.time()
    sim.run(max_rounds=ROUNDS)
    return time.time() - t0


def run():
    X, y = make_binary_dataset(2_048, 32, seed=0, noise=0.3)
    event_cfg = FLConfig(engine="event")
    cohort_cfg = FLConfig(engine="cohort", cohort_block=64)
    kw = dict(sizes_per_client=[S] * ROUNDS, round_stepsizes=ETAS,
              d=1, seed=0)

    # warm the event engine's per-chunk jits once at tiny C
    ev_task = _mk_task(X, y)
    _time_run(make_simulator(event_cfg, ev_task, n_clients=8, **kw))

    rows, report = [], {}
    for C in COHORTS:
        co_task = _mk_task(X, y)
        co = make_simulator(cohort_cfg, co_task, n_clients=C, **kw)
        _time_run(co)                       # compiles [C, D] block fns
        # re-simulate with the warm cohort task: steady-state timing
        co2 = make_simulator(cohort_cfg, co.ctask, n_clients=C, **kw)
        dt_co = _time_run(co2)
        tp_co = C * ROUNDS / dt_co

        entry = {"clients": C, "rounds": ROUNDS, "iters_per_round": S,
                 "cohort": {"sec": dt_co, "client_rounds_per_sec": tp_co}}
        derived = f"cohort {tp_co:,.0f} cr/s"
        if C <= EVENT_CAP:
            dt_ev = _time_run(make_simulator(event_cfg, ev_task,
                                             n_clients=C, **kw))
            tp_ev = C * ROUNDS / dt_ev
            entry["event"] = {"sec": dt_ev,
                              "client_rounds_per_sec": tp_ev}
            entry["speedup"] = tp_co / tp_ev
            derived += f"; event {tp_ev:,.0f}; speedup {tp_co / tp_ev:.1f}x"
        report[str(C)] = entry
        rows.append((f"cohort_scale_C{C}", dt_co * 1e6, derived))

    with open("BENCH_cohort.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows
