"""Engine throughput at C in {64, 512, 4096}: event vs host-cohort vs
device-resident cohort.

Two workloads, identical across engines (same task, sizes, step sizes,
d=1), selected through ``make_simulator(FLConfig(engine=...), ...)``:

  * ``compute_r2_s8`` — 2 rounds x 8 iters/client (PR-1's workload).
    Wall clock is dominated by the vmapped SGD blocks themselves, so it
    measures how little the engines add on top of the math.  The event
    engine is timed here up to C=4096 (minutes — it is the baseline the
    cohort engines exist to replace).
  * ``fedsgd_r8_s1`` — 8 rounds x 1 iter/client: FedSGD, the canonical
    protocol-dominated regime of massively federated populations taking
    a single local step per round (Bonawitz et al., 1902.01046).  This
    isolates the per-tick engine overhead — the host-loop engine pays
    Python control flow + host<->device syncs every tick, the device
    engine pays one jitted ``lax.while_loop`` per eval segment.

jit caches live on the task objects — the event engine's per-chunk fns
on the LogRegTask, the cohort engines' block/segment fns on the
CohortLogRegTask — so each engine is warmed by one run and timed on
fresh simulators that reuse the warm task.  Cohort engines record the
median of 3 runs (host wall clock is noisy at the ms scale); the event
engine runs once (it is minutes at large C).

A third, MODEL-SCALE workload drives a tiny transformer through the
flat-params adapter (``repro.cohort.flat``): ``model_tiny_r2`` runs a
reduced gemma-family decoder (1 layer, d_model=64, D = 86208 flat params)
with growing rounds [1, 2] — one "iteration" is a full minibatch
forward/backward, so throughput here measures the engines on the
workload class the ROADMAP's LLM-scale FL scenarios use.  The event
engine is timed at the smallest C only (per-step Python dispatch).

A fourth, SCENARIO workload (``scenario_smoke``) runs the protocol
under ``repro.scenarios`` presets — empirical latency tables sampled by
the alias method on the threefry chain, availability masks (diurnal
windows / churn), and drawn fleet speeds — on the two cohort engines.
It measures what heterogeneity costs each engine: the host engine pays
extra [C]-sized device calls per tick, the device engine folds the same
draws into its jitted tick at near-zero marginal dispatch.

A fifth workload (``heavy_tail_ring``) measures the heavy-tail ring
cost fix: an ``iot_straggler``-class Pareto table (q_hi=0.99) whose
tail spans ~80 ticks at the workload's dt.  The device engine is built
and run twice on the same table — ``capped`` (default
``Scenario.ring_cap=32``: bounded L-slot ring + overflow bucket) vs
``uncapped`` (ring_cap >= the tail, the pre-overflow behavior where
L = next_pow2(max latency ticks) and the per-slot scatter unrolls with
it) — recording ring length L, compile+warm seconds, and steady-state
run seconds for each.

A sixth workload (``fused_tick``) measures the device engine's tick
coalescing: the FedSGD-shaped leg with ``fuse_ticks`` off ("before",
one protocol tick per jitted while_loop iteration) vs on ("after",
overhead-only ticks merged into compute iterations), recording the
before/after iteration-based ``tick_overhead_ratio`` — the roofline
acceptance number.

A seventh workload (``aggregation_zoo``) runs the server-side
aggregation strategies (``repro.core.strategies``: paper default,
FedAsync constant/hinge/poly decay, FedBuff) head-to-head on the
device engine under the scenario presets.  One seed per preset means
one message schedule per preset — latency draws, availability, and the
staleness census are strategy-invariant by construction — so the grid
it emits (eval-loss trajectory + staleness histogram per strategy x
preset cell) attributes convergence differences to the aggregation
rule alone.  ``run_aggregation_zoo(grid_path=...)`` also writes the
grid standalone (CI uploads it as the ``aggregation-zoo-grid``
artifact).

Writes ``BENCH_cohort.json`` (cwd) with the raw numbers.  Each cohort /
device entry carries a ``phases`` block — ``compile_s`` (first run,
cold jit cache), ``warmup_s`` (second run, warm jit), ``steady_s``
(median of REPS fresh-simulator runs) and ``clients_per_sec`` — plus
``speedup_vs_event`` and ``speedup_vs_cohort`` for the device engine —
the acceptance number is device >= 5x host-cohort at C=4096 on the
FedSGD workload.  The file is merge-updated per workload key, so partial
re-runs refresh their own entries without clobbering the rest.
"""
from __future__ import annotations

import json
import statistics
import time

import jax

from repro.cohort import as_cohort_task, make_simulator
from repro.configs.base import FLConfig
from repro.core import LogRegTask
from repro.data import make_binary_dataset
from repro.telemetry import cost_decomposition

COHORTS = [64, 512, 4096]
WORKLOADS = {
    "compute_r2_s8": dict(rounds=2, iters=8, event_cap=4096),
    "fedsgd_r8_s1": dict(rounds=8, iters=1, event_cap=512),
}
MODEL_COHORTS = [16, 64]
MODEL_EVENT_CAP = 16
SCENARIO_COHORTS = [64, 512]
SCENARIO_PRESETS = ["mobile_diurnal", "iot_straggler"]
REPS = 3


def _mk_task(X, y):
    return LogRegTask(X, y, l2=1.0 / len(X), sample_seed=0)


def _time_run(sim, rounds: int) -> float:
    """One timed run; blocks on the final model so the async dispatch
    queue drains inside the measured window, and keeps the run result
    on the simulator (``bench_result``) for op-census attribution."""
    t0 = time.perf_counter()
    res = sim.run(max_rounds=rounds, eval_every=rounds)
    jax.block_until_ready(res["model"])
    sim.bench_result = res
    return time.perf_counter() - t0


def _median_run(mk_sim, rounds: int, reps: int = REPS) -> float:
    return statistics.median(_time_run(mk_sim(), rounds)
                             for _ in range(reps))


def _engine_phases(mk_sim, rounds: int, C: int) -> dict:
    """Per-phase timing for one engine config (repro.telemetry hooks):
    ``compile`` is the first run on a cold jit cache, ``warmup`` the
    next (warm jit, cold data paths), ``steady`` the median of REPS
    fresh-simulator runs on the warm task.  The steady number is the
    one throughput claims quote; compile/warmup make the amortization
    visible in BENCH_cohort.json instead of a single aggregate.  Cohort
    engines additionally carry their op census and its per-tick cost
    decomposition (``cost``, incl. the roofline tick_overhead_ratio)."""
    compile_s = _time_run(mk_sim(), rounds)
    warmup_s = _time_run(mk_sim(), rounds)
    times, tel, iters = [], None, None
    for _ in range(REPS):
        sim = mk_sim()
        times.append(_time_run(sim, rounds))
        tel = sim.bench_result["telemetry"]
        # device engine only: (loop_iters, block_iters) of the jitted
        # while_loop — the tick-coalescing census the iteration-based
        # tick_overhead_ratio is computed from
        iters = getattr(sim.engine, "fused_iters", None)
    steady_s = statistics.median(times)
    out = {
        "sec": steady_s,
        "client_rounds_per_sec": C * rounds / steady_s,
        "phases": {"compile_s": compile_s, "warmup_s": warmup_s,
                   "steady_s": steady_s,
                   "clients_per_sec": C / steady_s},
    }
    if tel is not None and tel.ops:
        li, bi = iters if iters is not None else (None, None)
        out["ops"] = dict(tel.ops)
        out["cost"] = cost_decomposition(tel.ops, steady_s=steady_s,
                                         ticks=tel.ticks,
                                         loop_iters=li, block_iters=bi)
    return out


def _merge_write(report):
    """Merge workload keys into BENCH_cohort.json (partial re-runs keep
    the other workloads' numbers)."""
    try:
        with open("BENCH_cohort.json") as f:
            existing = json.load(f)
    except (FileNotFoundError, ValueError):
        existing = {}
    existing.update(report)
    with open("BENCH_cohort.json", "w") as f:
        json.dump(existing, f, indent=2)


def run_model_scale(report=None):
    """Model-scale workload: tiny transformer through the flat adapter."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.core import BatchModelTask
    from repro.data import SeedAddressedBatcher
    from repro.models import init_params

    cfg = reduced(get_config("gemma-2b"), n_layers=1, d_model=64,
                  vocab=256)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batcher = SeedAddressedBatcher(cfg, batch_size=2, seq_len=16, seed=0)
    mk_task = lambda: BatchModelTask(cfg, params, batcher)  # noqa: E731

    rounds, sizes = 2, [1, 2]
    kw = dict(sizes_per_client=sizes,
              round_stepsizes=[0.1] * rounds, d=1, seed=0)
    own_report = report is None
    report = {} if own_report else report
    report["model_tiny_r2"] = {}
    rows = []
    # warm the event task once at tiny C (its _step/_eval_loss jits are
    # C-independent) so the timed event leg measures the engine, not XLA
    ev_task = mk_task()
    _time_run(make_simulator(FLConfig(engine="event"), ev_task,
                             n_clients=2, **kw), rounds)
    ctasks = {C: as_cohort_task(mk_task(), C) for C in MODEL_COHORTS}
    for C in MODEL_COHORTS:
        co_task = ctasks[C]
        cr = C * rounds
        co_cfg = FLConfig(engine="cohort", cohort_block=4)
        dv_cfg = FLConfig(engine="device", cohort_block=4)
        co = _engine_phases(
            lambda: make_simulator(co_cfg, co_task, n_clients=C, **kw),
            rounds, C)
        dv = _engine_phases(
            lambda: make_simulator(dv_cfg, co_task, n_clients=C, **kw),
            rounds, C)
        tp_co = co["client_rounds_per_sec"]
        tp_dv = dv["client_rounds_per_sec"]
        dv["speedup_vs_cohort"] = tp_dv / tp_co
        dt_dv = dv["sec"]
        entry = {
            "clients": C, "rounds": rounds, "sizes": sizes,
            "arch": cfg.arch_id, "flat_D": co_task.D,
            "cohort": co, "device": dv,
        }
        derived = (f"D={co_task.D}; device {tp_dv:,.1f} cr/s; "
                   f"cohort {tp_co:,.1f}; dev/cohort "
                   f"{tp_dv / tp_co:.1f}x")
        if C <= MODEL_EVENT_CAP:
            dt_ev = _time_run(
                make_simulator(FLConfig(engine="event"), ev_task,
                               n_clients=C, **kw), rounds)
            tp_ev = cr / dt_ev
            entry["event"] = {"sec": dt_ev,
                              "client_rounds_per_sec": tp_ev}
            entry["cohort"]["speedup_vs_event"] = tp_co / tp_ev
            entry["device"]["speedup_vs_event"] = tp_dv / tp_ev
            derived += f"; dev/event {tp_dv / tp_ev:.1f}x"
        report["model_tiny_r2"][str(C)] = entry
        rows.append((f"cohort_scale_model_tiny_r2_C{C}", dt_dv * 1e6,
                     derived))
    if own_report:
        _merge_write(report)
    return rows


def run_scenarios(report=None):
    """Scenario smoke workload: presets on both cohort engines.

    4 rounds x 4 iters under each preset's full heterogeneity stack
    (stochastic latency table + availability mask + drawn speeds); the
    event engine is excluded — churn has no continuous-time form.
    """
    X, y = make_binary_dataset(2_048, 32, seed=0, noise=0.3)
    rounds, iters = 4, 4
    kw = dict(sizes_per_client=[iters] * rounds,
              round_stepsizes=[0.1] * rounds, d=1, seed=0)
    own_report = report is None
    report = {} if own_report else report
    report["scenario_smoke"] = {}
    rows = []
    ctasks = {C: as_cohort_task(_mk_task(X, y), C)
              for C in SCENARIO_COHORTS}
    for preset in SCENARIO_PRESETS:
        report["scenario_smoke"][preset] = {}
        for C in SCENARIO_COHORTS:
            co_task = ctasks[C]
            cr = C * rounds
            co_cfg = FLConfig(engine="cohort", cohort_block=8,
                              scenario=preset)
            dv_cfg = FLConfig(engine="device", cohort_block=8,
                              scenario=preset)
            co = _engine_phases(
                lambda: make_simulator(co_cfg, co_task, n_clients=C,
                                       **kw), rounds, C)
            dv = _engine_phases(
                lambda: make_simulator(dv_cfg, co_task, n_clients=C,
                                       **kw), rounds, C)
            tp_co = co["client_rounds_per_sec"]
            tp_dv = dv["client_rounds_per_sec"]
            dv["speedup_vs_cohort"] = tp_dv / tp_co
            report["scenario_smoke"][preset][str(C)] = {
                "clients": C, "rounds": rounds, "iters_per_round": iters,
                "cohort": co, "device": dv,
            }
            rows.append((f"cohort_scale_scenario_{preset}_C{C}",
                         dv["sec"] * 1e6,
                         f"device {tp_dv:,.0f} cr/s; cohort {tp_co:,.0f};"
                         f" dev/cohort {tp_dv / tp_co:.1f}x"))
    if own_report:
        _merge_write(report)
    return rows


def run_heavy_tail(report=None):
    """Heavy-tail ring workload: capped ring + overflow bucket vs the
    legacy unbounded ring on an iot_straggler-class Pareto table."""
    from repro.cohort.state import next_pow2
    from repro.scenarios import LatencyTable, Scenario, scenario_plan

    X, y = make_binary_dataset(2_048, 32, seed=0, noise=0.3)
    table = LatencyTable.from_pareto(scale=16.0, alpha=1.05, n_bins=12,
                                     q_hi=0.99)
    rounds, iters, C = 4, 4, 64
    kw = dict(sizes_per_client=[iters] * rounds,
              round_stepsizes=[0.1] * rounds, d=1, seed=0)
    # dt = block / max(speed) = 4 s -> the q_hi tail spans ~80 ticks
    probe = scenario_plan(Scenario("probe", table), C=C, seed=0, dt=4.0)
    uncapped_ring = next_pow2(probe.max_lat_ticks + 1)
    variants = {
        "capped": Scenario("iot_tail_capped", table),
        "uncapped": Scenario("iot_tail_uncapped", table,
                             ring_cap=uncapped_ring),
    }
    own_report = report is None
    report = {} if own_report else report
    entry = {"clients": C, "rounds": rounds, "iters_per_round": iters,
             "max_lat_ticks": probe.max_lat_ticks}
    rows = []
    for vname, scn in variants.items():
        cfg = FLConfig(engine="device", cohort_block=4, scenario=scn)
        task = as_cohort_task(_mk_task(X, y), C)
        t0 = time.time()
        sim = make_simulator(cfg, task, n_clients=C, **kw)
        _time_run(sim, rounds)               # compile + first run
        compile_s = time.time() - t0
        dt_run = _median_run(
            lambda: make_simulator(cfg, task, n_clients=C, **kw), rounds)
        eng = sim.engine
        entry[vname] = {
            "ring_L": eng.L, "overflow_Q": eng.Q,
            "far_groups_F": eng.F,
            "compile_and_warm_sec": compile_s, "run_sec": dt_run,
            "client_rounds_per_sec": C * rounds / dt_run,
        }
        rows.append((f"cohort_scale_heavy_tail_{vname}", dt_run * 1e6,
                     f"L={eng.L} Q={eng.Q} compile {compile_s:.2f}s "
                     f"run {dt_run * 1e3:.1f}ms"))
    cap, unc = entry["capped"], entry["uncapped"]
    entry["capped"]["compile_speedup_vs_uncapped"] = (
        unc["compile_and_warm_sec"] / cap["compile_and_warm_sec"])
    report["heavy_tail_ring"] = entry
    if own_report:
        _merge_write(report)
    return rows


ZOO_STRATEGIES = {
    "paper": None,
    "fedasync_const": {"kind": "fedasync", "decay": "constant"},
    # hinge_b=0: decay every stale apply (the presets' gate keeps tau
    # small, so the FLGo default b=6 would never leave the flat region)
    "fedasync_hinge": {"kind": "fedasync", "decay": "hinge",
                       "hinge_b": 0},
    "fedasync_poly": "fedasync",
    "fedbuff": {"kind": "fedbuff", "buffer_size": 4},
}
ZOO_PRESETS = ["mobile_diurnal", "iot_straggler"]


def run_aggregation_zoo(report=None, grid_path=None):
    """Aggregation-zoo workload: convergence-vs-staleness grid.

    Every strategy runs the device engine under the SAME seed, preset,
    and gate, so each preset column shares one message schedule and one
    staleness histogram; the rows differ only in the eval-loss
    trajectory the aggregation rule produces from those arrivals.
    """
    X, y = make_binary_dataset(2_048, 32, seed=0, noise=0.3)
    C, rounds, iters, d = 32, 4, 4, 3
    kw = dict(sizes_per_client=[iters] * rounds,
              round_stepsizes=[0.1, 0.08, 0.06, 0.05], d=d, seed=0)
    own_report = report is None
    report = {} if own_report else report
    grid = {"clients": C, "rounds": rounds, "iters_per_round": iters,
            "d": d, "engine": "device", "presets": {}}
    rows = []
    task = as_cohort_task(_mk_task(X, y), C)
    for preset in ZOO_PRESETS:
        cell = {}
        for sname, spec in ZOO_STRATEGIES.items():
            cfg = FLConfig(engine="device", cohort_block=8,
                           scenario=preset, aggregation=spec)
            sim = make_simulator(cfg, task, n_clients=C, **kw)
            t0 = time.time()
            res = sim.run(max_rounds=rounds, eval_every=1)
            dt = time.time() - t0
            tel = res["telemetry"]
            cell[sname] = {
                "losses": [float(h["loss"]) for h in res["history"]],
                "final_loss": float(res["final"]["loss"]),
                "messages": int(res["final"]["messages"]),
                "staleness_hist": [int(x) for x in tel.staleness_hist],
                "sec": dt,
            }
            rows.append((f"cohort_scale_agg_zoo_{preset}_{sname}",
                         dt * 1e6,
                         f"final loss {cell[sname]['final_loss']:.4f}; "
                         f"tau-hist {cell[sname]['staleness_hist']}"))
        grid["presets"][preset] = cell
    report["aggregation_zoo"] = grid
    if grid_path:
        with open(grid_path, "w") as f:
            json.dump(grid, f, indent=2)
    if own_report:
        _merge_write(report)
    return rows


def run_fused_tick(report=None, ctasks=None):
    """Tick-coalescing workload: the FedSGD-shaped device leg run with
    ``fuse_ticks=False`` ("before": one protocol tick per while_loop
    iteration, the PR-9 behavior) and ``fuse_ticks=True`` ("after":
    overhead-only ticks ride along with compute iterations).  Emits the
    before/after iteration-based ``tick_overhead_ratio`` — the roofline
    acceptance number — into BENCH_cohort.json."""
    rounds = 8
    kw = dict(sizes_per_client=[1] * rounds,
              round_stepsizes=[0.1] * rounds, d=1, seed=0)
    own_report = report is None
    report = {} if own_report else report
    if ctasks is None:
        X, y = make_binary_dataset(2_048, 32, seed=0, noise=0.3)
        ctasks = {C: as_cohort_task(_mk_task(X, y), C) for C in COHORTS}
    report["fused_tick"] = {}
    rows = []
    dv_cfg = FLConfig(engine="device", cohort_block=64)
    for C in COHORTS:
        co_task = ctasks[C]
        legs = {}
        for lname, fuse in (("before", False), ("after", True)):
            legs[lname] = _engine_phases(
                lambda: make_simulator(dv_cfg, co_task, n_clients=C,
                                       fuse_ticks=fuse, **kw),
                rounds, C)
        before = legs["before"]["cost"]["tick_overhead_ratio"]
        after = legs["after"]["cost"]["tick_overhead_ratio"]
        report["fused_tick"][str(C)] = {
            "clients": C, "rounds": rounds, "iters_per_round": 1,
            "before": legs["before"], "after": legs["after"],
            "tick_overhead_ratio_before": before,
            "tick_overhead_ratio_after": after,
        }
        rows.append((f"cohort_scale_fused_tick_C{C}",
                     legs["after"]["sec"] * 1e6,
                     f"tick_overhead_ratio {before:.2f} -> {after:.2f}; "
                     f"steady {legs['after']['sec'] * 1e3:.1f}ms"))
    if own_report:
        _merge_write(report)
    return rows


def run():
    X, y = make_binary_dataset(2_048, 32, seed=0, noise=0.3)
    rows, report = [], {}

    # warm the event engine's per-chunk jits once at tiny C; the rounds
    # cover every chunk size the workloads use (8 and 1)
    ev_task = _mk_task(X, y)
    make_simulator(FLConfig(engine="event"), ev_task, n_clients=8,
                   sizes_per_client=[8, 1], round_stepsizes=[0.1, 0.08],
                   d=1, seed=0).run(max_rounds=2)

    # ONE cohort task per C: the cohort engines' jit caches (block fns,
    # device segment fns) live on the CohortLogRegTask, so warm runs and
    # timed runs must share it — rebuilding it would re-compile.
    ctasks = {C: as_cohort_task(_mk_task(X, y), C) for C in COHORTS}

    for wname, wl in WORKLOADS.items():
        rounds, iters = wl["rounds"], wl["iters"]
        kw = dict(sizes_per_client=[iters] * rounds,
                  round_stepsizes=[0.1] * rounds, d=1, seed=0)
        report[wname] = {}
        for C in COHORTS:
            co_task = ctasks[C]
            cr = C * rounds    # client-rounds per run

            # first run per engine compiles [C, D] block/segment fns;
            # _engine_phases records it as the compile phase
            co_cfg = FLConfig(engine="cohort", cohort_block=64)
            dv_cfg = FLConfig(engine="device", cohort_block=64)
            co = _engine_phases(
                lambda: make_simulator(co_cfg, co_task, n_clients=C, **kw),
                rounds, C)
            dv = _engine_phases(
                lambda: make_simulator(dv_cfg, co_task, n_clients=C, **kw),
                rounds, C)
            tp_co = co["client_rounds_per_sec"]
            tp_dv = dv["client_rounds_per_sec"]
            dv["speedup_vs_cohort"] = tp_dv / tp_co

            entry = {
                "clients": C, "rounds": rounds, "iters_per_round": iters,
                "cohort": co, "device": dv,
            }
            derived = (f"device {tp_dv:,.0f} cr/s; cohort {tp_co:,.0f}; "
                       f"dev/cohort {tp_dv / tp_co:.1f}x")
            if C <= wl["event_cap"]:
                dt_ev = _time_run(
                    make_simulator(FLConfig(engine="event"), ev_task,
                                   n_clients=C, **kw), rounds)
                tp_ev = cr / dt_ev
                entry["event"] = {"sec": dt_ev,
                                  "client_rounds_per_sec": tp_ev}
                entry["cohort"]["speedup_vs_event"] = tp_co / tp_ev
                entry["device"]["speedup_vs_event"] = tp_dv / tp_ev
                derived += f"; dev/event {tp_dv / tp_ev:.0f}x"
            report[wname][str(C)] = entry
            rows.append((f"cohort_scale_{wname}_C{C}", dv["sec"] * 1e6,
                         derived))

    rows += run_fused_tick(report, ctasks)
    rows += run_model_scale(report)
    rows += run_scenarios(report)
    rows += run_heavy_tail(report)
    rows += run_aggregation_zoo(report)
    _merge_write(report)
    # regression-gate time series: one fingerprinted row per full run
    from benchmarks.history import append_history
    with open("BENCH_cohort.json") as f:
        append_history(json.load(f))
    return rows
