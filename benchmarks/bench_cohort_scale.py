"""Engine throughput at C in {64, 512, 4096}: event vs host-cohort vs
device-resident cohort.

Two workloads, identical across engines (same task, sizes, step sizes,
d=1), selected through ``make_simulator(FLConfig(engine=...), ...)``:

  * ``compute_r2_s8`` — 2 rounds x 8 iters/client (PR-1's workload).
    Wall clock is dominated by the vmapped SGD blocks themselves, so it
    measures how little the engines add on top of the math.  The event
    engine is timed here up to C=4096 (minutes — it is the baseline the
    cohort engines exist to replace).
  * ``fedsgd_r8_s1`` — 8 rounds x 1 iter/client: FedSGD, the canonical
    protocol-dominated regime of massively federated populations taking
    a single local step per round (Bonawitz et al., 1902.01046).  This
    isolates the per-tick engine overhead — the host-loop engine pays
    Python control flow + host<->device syncs every tick, the device
    engine pays one jitted ``lax.while_loop`` per eval segment.

jit caches live on the task objects — the event engine's per-chunk fns
on the LogRegTask, the cohort engines' block/segment fns on the
CohortLogRegTask — so each engine is warmed by one run and timed on
fresh simulators that reuse the warm task.  Cohort engines record the
median of 3 runs (host wall clock is noisy at the ms scale); the event
engine runs once (it is minutes at large C).

Also writes ``BENCH_cohort.json`` (cwd) with the raw numbers, including
``speedup_vs_event`` and ``speedup_vs_cohort`` for the device engine —
the acceptance number is device >= 5x host-cohort at C=4096 on the
FedSGD workload.
"""
from __future__ import annotations

import json
import statistics
import time

from repro.cohort import as_cohort_task, make_simulator
from repro.configs.base import FLConfig
from repro.core import LogRegTask
from repro.data import make_binary_dataset

COHORTS = [64, 512, 4096]
WORKLOADS = {
    "compute_r2_s8": dict(rounds=2, iters=8, event_cap=4096),
    "fedsgd_r8_s1": dict(rounds=8, iters=1, event_cap=512),
}
REPS = 3


def _mk_task(X, y):
    return LogRegTask(X, y, l2=1.0 / len(X), sample_seed=0)


def _time_run(sim, rounds: int) -> float:
    t0 = time.time()
    sim.run(max_rounds=rounds, eval_every=rounds)
    return time.time() - t0


def _median_run(mk_sim, rounds: int, reps: int = REPS) -> float:
    return statistics.median(_time_run(mk_sim(), rounds)
                             for _ in range(reps))


def run():
    X, y = make_binary_dataset(2_048, 32, seed=0, noise=0.3)
    rows, report = [], {}

    # warm the event engine's per-chunk jits once at tiny C; the rounds
    # cover every chunk size the workloads use (8 and 1)
    ev_task = _mk_task(X, y)
    make_simulator(FLConfig(engine="event"), ev_task, n_clients=8,
                   sizes_per_client=[8, 1], round_stepsizes=[0.1, 0.08],
                   d=1, seed=0).run(max_rounds=2)

    # ONE cohort task per C: the cohort engines' jit caches (block fns,
    # device segment fns) live on the CohortLogRegTask, so warm runs and
    # timed runs must share it — rebuilding it would re-compile.
    ctasks = {C: as_cohort_task(_mk_task(X, y), C) for C in COHORTS}

    for wname, wl in WORKLOADS.items():
        rounds, iters = wl["rounds"], wl["iters"]
        kw = dict(sizes_per_client=[iters] * rounds,
                  round_stepsizes=[0.1] * rounds, d=1, seed=0)
        report[wname] = {}
        for C in COHORTS:
            co_task = ctasks[C]
            cr = C * rounds    # client-rounds per run

            # one warm run per engine compiles [C, D] block/segment fns
            co_cfg = FLConfig(engine="cohort", cohort_block=64)
            dv_cfg = FLConfig(engine="device", cohort_block=64)
            _time_run(make_simulator(co_cfg, co_task, n_clients=C, **kw),
                      rounds)
            _time_run(make_simulator(dv_cfg, co_task, n_clients=C, **kw),
                      rounds)

            dt_co = _median_run(
                lambda: make_simulator(co_cfg, co_task, n_clients=C, **kw),
                rounds)
            dt_dv = _median_run(
                lambda: make_simulator(dv_cfg, co_task, n_clients=C, **kw),
                rounds)
            tp_co, tp_dv = cr / dt_co, cr / dt_dv

            entry = {
                "clients": C, "rounds": rounds, "iters_per_round": iters,
                "cohort": {"sec": dt_co, "client_rounds_per_sec": tp_co},
                "device": {"sec": dt_dv, "client_rounds_per_sec": tp_dv,
                           "speedup_vs_cohort": tp_dv / tp_co},
            }
            derived = (f"device {tp_dv:,.0f} cr/s; cohort {tp_co:,.0f}; "
                       f"dev/cohort {tp_dv / tp_co:.1f}x")
            if C <= wl["event_cap"]:
                dt_ev = _time_run(
                    make_simulator(FLConfig(engine="event"), ev_task,
                                   n_clients=C, **kw), rounds)
                tp_ev = cr / dt_ev
                entry["event"] = {"sec": dt_ev,
                                  "client_rounds_per_sec": tp_ev}
                entry["cohort"]["speedup_vs_event"] = tp_co / tp_ev
                entry["device"]["speedup_vs_event"] = tp_dv / tp_ev
                derived += f"; dev/event {tp_dv / tp_ev:.0f}x"
            report[wname][str(C)] = entry
            rows.append((f"cohort_scale_{wname}_C{C}", dt_dv * 1e6,
                         derived))

    with open("BENCH_cohort.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows
