"""Table 3: accuracy of constant sample sizes {50..1000} at fixed K.

Paper shows accuracy degrades for very large constant sample sizes (fewer,
coarser rounds) — we reproduce the trend on the synthetic convex task.
"""
from __future__ import annotations

import time

from repro.core import LogRegTask, run_sync_baseline
from repro.data import make_binary_dataset

K = 8_000
N_CLIENTS = 5


def run():
    rows = []
    X, y = make_binary_dataset(4_000, 32, seed=4, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X))
    for s in (50, 100, 200, 500, 1000):
        t0 = time.time()
        n_rounds = max(1, K // s)
        res = run_sync_baseline(task, n_clients=N_CLIENTS,
                                n_rounds=n_rounds,
                                sample_size=max(1, s // N_CLIENTS),
                                eta=0.0025)
        dt = time.time() - t0
        rows.append((f"table3_constant_s{s}", dt * 1e6,
                     f"rounds={n_rounds} acc="
                     f"{res['final']['accuracy']:.4f}"))
    return rows
