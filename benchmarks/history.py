"""Bench regression gate: fingerprinted history + baseline comparison.

``bench_cohort_scale`` writes raw numbers to ``BENCH_cohort.json``; this
module turns them into a time series and a CI gate:

  * ``append_history`` flattens the bench report into per-workload
    metrics (steady-state ``clients_per_sec``, ``compile_s``) and
    appends one JSONL row to ``BENCH_history.jsonl`` together with a
    machine fingerprint (platform / python / jax / backend / cpu count /
    hashed hostname), so numbers from different machines never get
    compared as if they were the same rig.
  * ``check_regression`` compares a current report against a committed
    baseline (``benchmarks/BENCH_baseline.json``) and returns one
    problem string per workload whose throughput dropped more than
    ``TOL_THROUGHPUT`` or whose compile time grew more than
    ``TOL_COMPILE``.

CLI (``PYTHONPATH=src python -m benchmarks.history <cmd>``):

  append    BENCH_cohort.json -> BENCH_history.jsonl row
  check     gate the current bench against the baseline; exits 1 on
            regression.  A fingerprint mismatch (different machine)
            downgrades to a warning unless ``--strict``.
  rebase    write the committed baseline from the current bench
  selftest  verify the gate MECHANICS: inject a synthetic slowdown
            (default 20%) into the baseline's own metrics and exit 0
            only if the gate catches it.  CI runs this blocking; the
            real ``check`` stays advisory until runners are steady.

The throughput tolerance (15%) is deliberately below the selftest's
injected 20% slowdown, so the blocking selftest proves the gate would
fire on a real regression of that size.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import platform
import socket
import time
from typing import Any, Dict, List, Optional

HISTORY_PATH = "BENCH_history.jsonl"
BASELINE_PATH = "benchmarks/BENCH_baseline.json"
#: fail when steady-state clients_per_sec drops by more than this
TOL_THROUGHPUT = 0.15
#: fail when cold-cache compile_s grows by more than this
TOL_COMPILE = 0.50
#: fingerprint keys that must match for numbers to be comparable
COMPARABLE_KEYS = ("platform", "machine", "python", "jax", "backend")


def fingerprint() -> Dict[str, Any]:
    """Identity of the measuring rig (hostname only as a salted hash)."""
    import jax
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "cpus": os.cpu_count(),
        "host": hashlib.sha256(
            socket.gethostname().encode()).hexdigest()[:12],
    }


def extract_metrics(bench: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    """Flatten BENCH_cohort.json: every entry carrying a ``phases``
    block (the cohort/device engine legs) becomes one
    ``workload/.../engine`` key with its gateable numbers."""
    out: Dict[str, Dict[str, float]] = {}

    def walk(node: Any, path: List[str]) -> None:
        if not isinstance(node, dict):
            return
        ph = node.get("phases")
        if isinstance(ph, dict) and "clients_per_sec" in ph:
            out["/".join(path)] = {
                "clients_per_sec": float(ph["clients_per_sec"]),
                "compile_s": float(ph["compile_s"]),
                "steady_s": float(ph["steady_s"]),
            }
            return
        for k, v in node.items():
            walk(v, path + [str(k)])

    walk(bench, [])
    return out


def append_history(bench: Dict[str, Any], history_path: str = HISTORY_PATH,
                   note: Optional[str] = None) -> Dict[str, Any]:
    """Append one fingerprinted metrics row; returns the row."""
    row: Dict[str, Any] = {"ts": time.time(), "fingerprint": fingerprint(),
                           "metrics": extract_metrics(bench)}
    if note:
        row["note"] = note
    with open(history_path, "a") as fh:
        fh.write(json.dumps(row, sort_keys=True) + "\n")
    return row


def fingerprint_mismatches(a: Dict[str, Any], b: Dict[str, Any]
                           ) -> List[str]:
    return [f"{k}: {a.get(k)!r} != {b.get(k)!r}"
            for k in COMPARABLE_KEYS if a.get(k) != b.get(k)]


def check_regression(current: Dict[str, Dict[str, float]],
                     baseline: Dict[str, Dict[str, float]], *,
                     tol_throughput: float = TOL_THROUGHPUT,
                     tol_compile: float = TOL_COMPILE) -> List[str]:
    """Problem strings for every shared workload that regressed."""
    problems: List[str] = []
    shared = sorted(set(current) & set(baseline))
    if not shared:
        return ["no comparable workload keys between current bench and "
                "baseline — did the bench run?"]
    for key in shared:
        cur, base = current[key], baseline[key]
        b_tp = base.get("clients_per_sec", 0.0)
        if b_tp > 0:
            drop = 1.0 - cur.get("clients_per_sec", 0.0) / b_tp
            if drop > tol_throughput:
                problems.append(
                    f"{key}: clients_per_sec "
                    f"{cur['clients_per_sec']:,.0f} is {drop:.0%} below "
                    f"baseline {b_tp:,.0f} (tolerance {tol_throughput:.0%})")
        b_c = base.get("compile_s", 0.0)
        if b_c > 0:
            growth = cur.get("compile_s", 0.0) / b_c - 1.0
            if growth > tol_compile:
                problems.append(
                    f"{key}: compile_s {cur['compile_s']:.2f}s is "
                    f"{growth:.0%} above baseline {b_c:.2f}s "
                    f"(tolerance {tol_compile:.0%})")
    return problems


def _load(path: str) -> Dict[str, Any]:
    with open(path) as fh:
        return json.load(fh)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.history",
        description="bench history + regression gate for "
                    "BENCH_cohort.json")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("append", help="append a fingerprinted history row")
    p.add_argument("--bench", default="BENCH_cohort.json")
    p.add_argument("--history", default=HISTORY_PATH)
    p.add_argument("--note", default=None)

    p = sub.add_parser("check", help="gate current bench vs baseline")
    p.add_argument("--bench", default="BENCH_cohort.json")
    p.add_argument("--baseline", default=BASELINE_PATH)
    p.add_argument("--tol-throughput", type=float, default=TOL_THROUGHPUT)
    p.add_argument("--tol-compile", type=float, default=TOL_COMPILE)
    p.add_argument("--strict", action="store_true",
                   help="fail on fingerprint mismatch instead of "
                        "downgrading to a warning")

    p = sub.add_parser("rebase", help="write baseline from current bench")
    p.add_argument("--bench", default="BENCH_cohort.json")
    p.add_argument("--baseline", default=BASELINE_PATH)

    p = sub.add_parser("selftest",
                       help="prove the gate catches an injected slowdown")
    p.add_argument("--baseline", default=BASELINE_PATH)
    p.add_argument("--slowdown", type=float, default=0.20)

    args = ap.parse_args(argv)

    if args.cmd == "append":
        row = append_history(_load(args.bench), args.history, args.note)
        print(f"appended {len(row['metrics'])} workload metrics to "
              f"{args.history}")
        return 0

    if args.cmd == "rebase":
        doc = {"ts": time.time(), "fingerprint": fingerprint(),
               "metrics": extract_metrics(_load(args.bench))}
        with open(args.baseline, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
        print(f"wrote {args.baseline}: {len(doc['metrics'])} workloads")
        return 0

    if args.cmd == "check":
        base_doc = _load(args.baseline)
        cur = extract_metrics(_load(args.bench))
        mism = fingerprint_mismatches(fingerprint(),
                                      base_doc.get("fingerprint", {}))
        if mism and not args.strict:
            print("fingerprint mismatch — numbers are not comparable, "
                  "skipping the gate (use --strict to force):")
            for m in mism:
                print(f"  {m}")
            return 0
        problems = check_regression(
            cur, base_doc["metrics"],
            tol_throughput=args.tol_throughput,
            tol_compile=args.tol_compile)
        if mism:
            problems = [f"fingerprint: {m}" for m in mism] + problems
        for pb in problems:
            print(f"REGRESSION: {pb}")
        if problems:
            return 1
        print(f"OK: {len(set(cur) & set(base_doc['metrics']))} workloads "
              f"within tolerance")
        return 0

    if args.cmd == "selftest":
        base = _load(args.baseline)["metrics"]
        slowed = {k: dict(v, clients_per_sec=v["clients_per_sec"]
                          * (1.0 - args.slowdown))
                  for k, v in base.items()}
        problems = check_regression(slowed, base)
        if not problems:
            print(f"FAILED: gate did not flag an injected "
                  f"{args.slowdown:.0%} slowdown")
            return 1
        print(f"OK: gate flags {len(problems)} workload(s) at an "
              f"injected {args.slowdown:.0%} slowdown")
        return 0

    return 2


if __name__ == "__main__":
    raise SystemExit(main())
