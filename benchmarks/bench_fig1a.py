"""Fig 1a: diminishing step + increasing sample sizes vs constant/constant.

Derived metric: rounds used by each scheme to reach its final accuracy,
and the accuracy delta (paper: same-or-better accuracy, 9 vs 20 rounds).
"""
from __future__ import annotations

import time

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (AsyncFLSimulator, LogRegTask, round_stepsizes,
                        rounds_for_budget, run_sync_baseline)
from repro.data import make_binary_dataset

K = 8_000
N_CLIENTS = 5


def run():
    t0 = time.time()
    X, y = make_binary_dataset(4_000, 32, seed=1, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X))

    sizes = rounds_for_budget(
        SampleSequenceConfig(kind="linear", s0=100, a=100.0), K)
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001), sizes)
    sim = AsyncFLSimulator(
        task, n_clients=N_CLIENTS,
        sizes_per_client=[[max(1, s // N_CLIENTS) for s in sizes]]
        * N_CLIENTS,
        round_stepsizes=etas, d=1, seed=0)
    res_inc = sim.run(max_rounds=len(sizes))

    n_rounds_const = K // 400
    res_const = run_sync_baseline(task, n_clients=N_CLIENTS,
                                  n_rounds=n_rounds_const,
                                  sample_size=400 // N_CLIENTS, eta=0.0025)
    dt = time.time() - t0
    derived = (f"rounds {res_inc['final']['round']} vs {n_rounds_const}; "
               f"acc {res_inc['final']['accuracy']:.4f} vs "
               f"{res_const['final']['accuracy']:.4f}")
    return [("fig1a_async_incr_vs_const", dt * 1e6, derived)]
