"""Theorem 4 / D.3.2: aggregated DP noise reduction vs constant sequences.

Reproduces the paper's worked Examples 1, 3, 5 (parameter-selection
procedure) and reports round reduction + aggregated noise reduction.
"""
from __future__ import annotations

import math
import time

from repro.dp import select_parameters


CASES = [
    # (name, s0c, N_c, p, eps, sigma, K, r0, paper expectation)
    ("example1", 16, 50_000, 1.0, 6.0, 3.0, 100 * 50_000, None,
     "paper: T~50168, reduction 6.23x, noise 1107->672"),
    ("example3", 16, 10_000, 1.0, 1.0, 8.0, 25_000, 1.0 / math.e,
     "paper: T~195, reduction 8.02x, noise 229->112"),
    ("example5", 16, 25_000, 1.0, 2.0, 8.0, 125_000, 1.0 / math.e,
     "paper: T~364, reduction 21x, noise 615->153"),
]


def run():
    rows = []
    for name, s0c, N_c, p, eps, sigma, K, r0, expect in CASES:
        t0 = time.time()
        sel = select_parameters(s0c=s0c, N_c=N_c, p=p, epsilon=eps,
                                sigma=sigma, K=K, r0=r0)
        dt = time.time() - t0
        rows.append((f"noise_{name}", dt * 1e6,
                     f"T={sel.T} reduction={sel.round_reduction:.2f}x "
                     f"noise {sel.aggregated_noise_constant:.0f}->"
                     f"{sel.aggregated_noise:.0f} B={sel.budget_B:.2f} "
                     f"delta={sel.delta:.2e} | {expect}"))
    return rows
