"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
    PYTHONPATH=src python -m benchmarks.run [--only fig1a,comm,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = ["comm", "noise", "table3", "fig1a", "fig1b", "biased",
           "delay", "step_time", "roofline", "cohort_scale"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else BENCHES

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        try:
            mod = __import__(f"benchmarks.bench_{name}",
                             fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},\"{derived}\"", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"bench_{name},0,\"FAILED\"", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
