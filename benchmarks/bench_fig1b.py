"""Fig 1b: DP training — increasing sample sizes vs constant, sigma=8.

Uses the paper's Example-3 parameters (s_i = 16 + ceil(1.322 i)).
Derived: accuracy of each under the same privacy budget, rounds used.
"""
from __future__ import annotations

import time

from repro.configs.base import StepSizeConfig
from repro.core import AsyncFLSimulator, LogRegTask, round_stepsizes
from repro.data import make_binary_dataset

N_CLIENTS = 5
K = 10_000


def _run(task, sizes, etas, seed=0):
    sim = AsyncFLSimulator(
        task, n_clients=N_CLIENTS,
        sizes_per_client=[[max(1, s // N_CLIENTS) for s in sizes]]
        * N_CLIENTS,
        round_stepsizes=etas, d=1, seed=seed)
    return sim.run(max_rounds=len(sizes))


def run():
    t0 = time.time()
    X, y = make_binary_dataset(4_000, 16, seed=2, noise=0.3)

    # increasing (Example 3): fewer rounds, sigma=8 per round
    task_inc = LogRegTask(X, y, l2=1.0 / len(X), dp_clip=0.1, dp_sigma=8.0)
    sizes_inc, tot = [], 0
    i = 0
    while tot < K:
        s = 16 + int(1.322 * i)
        sizes_inc.append(s)
        tot += s
        i += 1
    etas_inc = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.15, beta=0.001), sizes_inc)
    res_inc = _run(task_inc, sizes_inc, etas_inc)

    # constant baseline: same K, s=16; same privacy needs sigma~B=5.78
    task_const = LogRegTask(X, y, l2=1.0 / len(X), dp_clip=0.1,
                            dp_sigma=5.78)
    sizes_const = [16] * (K // 16)
    etas_const = [0.01] * len(sizes_const)
    res_const = _run(task_const, sizes_const, etas_const)

    dt = time.time() - t0
    agg_inc = (len(sizes_inc) ** 0.5) * 8.0
    agg_const = (len(sizes_const) ** 0.5) * 5.78
    derived = (f"acc {res_inc['final']['accuracy']:.4f} "
               f"({len(sizes_inc)} rounds, agg noise {agg_inc:.0f}) vs "
               f"{res_const['final']['accuracy']:.4f} "
               f"({len(sizes_const)} rounds, agg noise {agg_const:.0f})")
    return [("fig1b_dp_incr_vs_const", dt * 1e6, derived)]
