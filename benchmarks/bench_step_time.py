"""Wall-clock per-call timings of the core computational steps (CPU host).

Measures the jitted FL round step and serve step on reduced architectures
(one per family) — the us_per_call column of the harness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config, reduced
from repro.core import fl_step
from repro.data import FederatedBatcher
from repro.models import init_cache, init_params, serve_step


ARCHS = ["gemma-2b", "qwen2-moe-a2.7b", "mamba2-780m", "hymba-1.5b"]


def _time(fn, *args, n=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run():
    rows = []
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        run_cfg = RunConfig(model=cfg)
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batcher = FederatedBatcher(cfg, batch_size=2, seq_len=64, seed=0)
        batch = batcher.global_batch(1, 0)
        step = jax.jit(fl_step.make_train_step(
            cfg, run_cfg, n_client_shards=1, client_axis=None))
        us = _time(lambda p, b: step(p, None, b, jnp.float32(0.01),
                                     jax.random.PRNGKey(1)), params, batch)
        rows.append((f"train_step_{arch}_reduced", us,
                     "2L reduced, B2xS64, CPU"))

        cache = init_cache(cfg, 2, 32, jnp.float32)
        tokens = batch["tokens"][0][:, :1]
        sstep = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t,
                                                   jnp.int32(0),
                                                   seq_len=32))
        us = _time(sstep, params, cache, tokens)
        rows.append((f"serve_step_{arch}_reduced", us,
                     "decode 1 token, cache 32"))
    return rows
