"""Fig 2: biased vs unbiased client datasets (label-skew tolerance)."""
from __future__ import annotations

import time

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (AsyncFLSimulator, LogRegTask, round_stepsizes,
                        rounds_for_budget)
from repro.data import biased_split, make_binary_dataset, unbiased_split


def run():
    t0 = time.time()
    X, y = make_binary_dataset(4_000, 16, seed=6, noise=0.3)
    sizes = rounds_for_budget(
        SampleSequenceConfig(kind="linear", s0=100, a=100.0), 6_000)
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.01, beta=0.001), sizes)

    accs = {}
    for name, shards in [("unbiased", unbiased_split(X, y, 2, seed=0)),
                         ("biased", biased_split(X, y, 2, bias=1.0,
                                                 seed=0))]:
        global_task = LogRegTask(X, y, l2=1.0 / len(X))
        sim = AsyncFLSimulator(
            global_task, n_clients=2,
            sizes_per_client=[[max(1, s // 2) for s in sizes]] * 2,
            round_stepsizes=etas, d=1, seed=0)
        for c, (sx, sy) in enumerate(shards):
            sim.clients[c].task = LogRegTask(sx, sy, l2=1.0 / len(sx))
        res = sim.run(max_rounds=len(sizes))
        accs[name] = res["final"]["accuracy"]
    dt = time.time() - t0
    return [("fig2_biased_vs_unbiased", dt * 1e6,
             f"unbiased={accs['unbiased']:.4f} biased={accs['biased']:.4f}")]
