"""§2.2: communication rounds T vs grad budget K — the T ~ sqrt(K) claim."""
from __future__ import annotations

import math
import time

from repro.configs.base import SampleSequenceConfig
from repro.core import rounds_for_budget


def run():
    rows = []
    for kind, cfg in [
        ("linear", SampleSequenceConfig(kind="linear", s0=16, a=1.0)),
        ("ilog", SampleSequenceConfig(kind="ilog", s0=16, m=2900, d=1)),
        ("constant", SampleSequenceConfig(kind="constant", s0=16)),
    ]:
        t0 = time.time()
        ts = []
        for K in (10_000, 40_000, 160_000):
            ts.append(len(rounds_for_budget(cfg, K)))
        dt = time.time() - t0
        # scaling exponent between successive 4x budgets
        e1 = math.log(ts[1] / ts[0], 4)
        e2 = math.log(ts[2] / ts[1], 4)
        rows.append((f"comm_T_vs_K_{kind}", dt * 1e6,
                     f"T={ts} exponents=({e1:.2f},{e2:.2f}) "
                     f"[0.5 => T~sqrt(K), 1.0 => T~K]"))
    return rows
