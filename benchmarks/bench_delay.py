"""Delay-slack ablation: the asynchrony the protocol is designed to exploit.

Larger d lets fast clients run ahead (less blocking => shorter virtual
wall-clock) while condition (3) keeps convergence guaranteed.  Measures
virtual completion time + accuracy for d in {1, 2, 4} with heterogeneous
client speeds, plus a fully synchronous reference.
"""
from __future__ import annotations

import time

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (AsyncFLSimulator, LogRegTask, round_stepsizes,
                        rounds_for_budget)
from repro.data import make_binary_dataset

N_CLIENTS = 4
SPEEDS = [1.0, 0.55, 1.6, 0.8]       # stragglers + fast clients


def run():
    rows = []
    X, y = make_binary_dataset(3_000, 16, seed=9, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X))
    sizes = rounds_for_budget(
        SampleSequenceConfig(kind="linear", s0=100, a=100.0), 6_000)
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001), sizes)
    per_client = [[max(1, s // N_CLIENTS) for s in sizes]] * N_CLIENTS

    for d in (1, 2, 4):
        t0 = time.time()
        sim = AsyncFLSimulator(
            task, n_clients=N_CLIENTS, sizes_per_client=per_client,
            round_stepsizes=etas, d=d, seed=0, speeds=SPEEDS,
            latency_fn=lambda r: 0.5 + 1.0 * r.random())  # slow network
        res = sim.run(max_rounds=len(sizes))
        rows.append((
            f"delay_slack_d{d}", (time.time() - t0) * 1e6,
            f"virtual_time={res['final']['time']:.0f} "
            f"acc={res['final']['accuracy']:.4f} "
            f"rounds={res['final']['round']}"))
    return rows
