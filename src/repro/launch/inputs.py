"""ShapeDtypeStruct input specs for every (arch x input-shape) pair.

Following the shannon/kernels pattern: weak-type-correct, shardable
stand-ins — no device allocation ever happens in the dry-run.  The
modality frontends (whisper conv/mel, chameleon VQ) appear here as the
stub embeddings/token streams the carve-out prescribes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.models import model as model_api
from repro.sharding.specs import (batch_spec, cache_pspecs,
                                  client_batch_spec, param_shardings)


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def n_client_shards(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1)


def params_spec(cfg: ModelConfig, mesh, dtype=jnp.bfloat16
                ) -> Tuple[Any, Any]:
    """(params ShapeDtypeStruct tree, NamedSharding tree)."""
    shapes = jax.eval_shape(
        lambda k: model_api.init_params(cfg, k, dtype),
        jax.random.PRNGKey(0))
    shardings = param_shardings(mesh, shapes)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return structs, shardings


def train_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                 dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Inputs for fl_step.make_train_step: (params, momentum, batch, eta, rng)."""
    C = n_client_shards(mesh)
    B = shape.global_batch // C
    params, param_sh = params_spec(cfg, mesh, dtype)
    bspec = client_batch_spec(mesh, B, extra_dims=1)
    batch = {"tokens": jax.ShapeDtypeStruct(
        (C, B, shape.seq_len), jnp.int32,
        sharding=NamedSharding(mesh, bspec))}
    if cfg.family == "encdec":
        espec = client_batch_spec(mesh, B, extra_dims=2)
        batch["encoder_embeds"] = jax.ShapeDtypeStruct(
            (C, B, cfg.encoder_seq_len, cfg.d_model), dtype,
            sharding=NamedSharding(mesh, espec))
    rep = NamedSharding(mesh, P())
    return {
        "params": params,
        "momentum": None,
        "batch": batch,
        "eta_bar": jax.ShapeDtypeStruct((), jnp.float32, sharding=rep),
        "rng": jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep),
        "param_shardings": param_sh,
    }


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
    params, param_sh = params_spec(cfg, mesh, dtype)
    bspec = batch_spec(mesh, shape.global_batch, extra_dims=1)
    batch = {"tokens": jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len), jnp.int32,
        sharding=NamedSharding(mesh, bspec))}
    if cfg.family == "encdec":
        espec = batch_spec(mesh, shape.global_batch, extra_dims=2)
        batch["encoder_embeds"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.encoder_seq_len, cfg.d_model), dtype,
            sharding=NamedSharding(mesh, espec))
    return {"params": params, "batch": batch, "param_shardings": param_sh}


def decode_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """long_500k uses the windowed-ring variant (see DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.sliding_window is not None:
        return int(cfg.sliding_window)
    return int(shape.seq_len)


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                  dtype=jnp.bfloat16) -> Dict[str, Any]:
    import os
    params, param_sh = params_spec(cfg, mesh, dtype)
    B = shape.global_batch
    cache_len = decode_cache_len(cfg, shape)
    kv_dtype = jnp.int8 if os.environ.get("REPRO_KV_DTYPE") == "int8"         else dtype
    cache_shapes = jax.eval_shape(
        lambda: model_api.init_cache(cfg, B, cache_len, kv_dtype))
    cache_specs = cache_pspecs(mesh, cache_shapes)
    cache = jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        cache_shapes, cache_specs)
    bspec = batch_spec(mesh, B, extra_dims=1)
    rep = NamedSharding(mesh, P())
    return {
        "params": params,
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                       sharding=NamedSharding(mesh, bspec)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32, sharding=rep),
        "param_shardings": param_sh,
        "cache_shardings": jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), cache_specs),
    }


def input_specs(cfg: ModelConfig, shape_name: str, mesh, *,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return train_inputs(cfg, shape, mesh, dtype=dtype)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape, mesh, dtype=dtype)
    return decode_inputs(cfg, shape, mesh, dtype=dtype)


def shape_is_applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, ("pure full-attention arch: long_500k requires a "
                       "sub-quadratic variant (see DESIGN.md §4)")
    return True, ""
