"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes   / (chips * 819e9 B/s HBM)
    collective = coll_bytes  / (chips * 50e9 B/s per ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``;
collective bytes are parsed from the optimized HLO text (cost_analysis
does not report them).  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE)
gives the useful-compute ratio that flags remat/redundancy waste.
"""
from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip, TPU v5e
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[a,b,c]' result (tuples handled by caller)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in (optimized) HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # lines look like:  %all-reduce.5 = f32[8,128]{1,0} all-reduce(...)
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)",
                     stripped)
        if not m:
            continue
        shape_part, op = m.group(1), m.group(2)
        # shape_part may be a tuple "(f32[..], f32[..])"
        out[op] += _shape_bytes(shape_part)
    return out


def model_flops(cfg, shape, *, backward: bool) -> float:
    """MODEL_FLOPS = 6*N*D for train (fwd+bwd), 2*N*D for inference,
    using active params for MoE.  D = processed tokens."""
    n_total = cfg.param_count()
    if cfg.n_experts:
        # swap full expert compute for top-k + shared
        d = cfg.d_model
        per_layer_all = cfg.n_experts * 3 * d * cfg.moe_d_ff
        active_frac = cfg.moe_top_k / cfg.n_experts
        per_layer_active = per_layer_all * active_frac \
            + cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
        n_active = n_total - cfg.n_layers * (per_layer_all
                                             + cfg.n_shared_experts * 3 * d
                                             * cfg.moe_d_ff) \
            + cfg.n_layers * per_layer_active
    else:
        n_active = n_total
    # the input-embedding LOOKUP does no matmul: subtract one table when
    # untied; tied models reuse the same table for the unembed matmul
    if not cfg.tie_embeddings:
        n_active -= cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1   # decode: one token per sequence
    return 2.0 * n_active * tokens


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops_total: float
    bytes_per_device: float = 0.0
    compile_seconds: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_total / self.hlo_flops \
            if self.hlo_flops else 0.0

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_ratio=self.useful_ratio)
        return d

    def row(self) -> str:
        return (f"{self.arch:18s} {self.shape:12s} {self.mesh:10s} "
                f"compute={self.compute_s:9.3e}s mem={self.memory_s:9.3e}s "
                f"coll={self.collective_s:9.3e}s -> {self.dominant:10s} "
                f"useful={self.useful_ratio:6.3f}")


def analyze(compiled, lowered_text: str, *, cfg, shape, mesh_name: str,
            chips: int, compile_seconds: float = 0.0) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(lowered_text)
    mem = compiled.memory_analysis()
    bytes_per_dev = 0.0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes"):
        bytes_per_dev += float(getattr(mem, attr, 0.0) or 0.0)
    return RooflineReport(
        arch=cfg.arch_id, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        model_flops_total=model_flops(cfg, shape,
                                      backward=shape.kind == "train"),
        bytes_per_device=bytes_per_dev, compile_seconds=compile_seconds)
