import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

For each pair this builds the jitted, sharding-annotated step function,
lowers it against ShapeDtypeStruct inputs (no allocation), compiles it,
and records memory_analysis / cost_analysis / collective bytes for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
        --shape train_4k [--multi-pod] [--all] [--out reports/]
"""


import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, RunConfig, get_config
from repro.core import fl_step
from repro.launch import inputs as inputs_mod
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, n_chips
from repro.sharding.context import (use_activation_spec,
                                    use_param_cotangent_specs)
from repro.sharding.specs import param_pspecs


ACT_SPEC_MODE = os.environ.get("REPRO_ACT_SPEC", "seqpar")


def act_spec(shape_kind: str, mesh) -> P:
    """Batch-leading activation spec for full-sequence passes.

    Inside the per-client vmap (train, multi-pod) the client axis is pinned
    by spmd_axis_name='pod', so the inner batch pins only 'data'; prefill
    has no client axis and uses the combined axes.

    Modes (REPRO_ACT_SPEC, used by the §Perf iterations):
      dataonly — batch over data, sequence unsharded (paper-faithful naive
                 data parallelism; exceeds HBM on the big archs)
      seqpar   — batch over data, sequence over model (sequence
                 parallelism; the production default)
      flatbatch— batch over BOTH axes (works when per-client batch is a
                 multiple of 256; removes seq-parallel collectives)
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if shape_kind == "train":
        if ACT_SPEC_MODE == "dataonly":
            return P("data")
        if ACT_SPEC_MODE == "flatbatch":
            return P(("data", "model"))
        return P("data", "model")
    combined = axes if len(axes) > 1 else axes[0]
    if ACT_SPEC_MODE == "dataonly":
        return P(combined)
    if ACT_SPEC_MODE == "flatbatch":
        flat = tuple(a for a in (("pod", "data", "model"))
                     if a in mesh.axis_names + ("model",))
        return P(tuple(dict.fromkeys(flat)))
    return P(combined, "model")


def build_step(cfg, run_cfg, shape, mesh, *, unroll: bool = False):
    """Returns (fn, example_args)."""
    kind = shape.kind
    spec = inputs_mod.input_specs(cfg, shape.name, mesh,
                                  dtype=jnp.bfloat16)
    aspec = act_spec(kind, mesh)
    if kind == "train":
        C = inputs_mod.n_client_shards(mesh)
        from jax.sharding import PartitionSpec as PS
        from repro.models import init_params as _init_params
        gspecs = None
        cot_specs = None
        if os.environ.get("REPRO_GRAD_RS", "1") == "1":
            shapes = jax.eval_shape(
                lambda k: _init_params(cfg, k, jnp.bfloat16),
                jax.random.PRNGKey(0))
            gspecs = param_pspecs(mesh, shapes)
            if "blocks" in gspecs:
                cot_specs = jax.tree_util.tree_map(
                    lambda sp: PS(*tuple(sp)[1:]), gspecs["blocks"])
        raw_step = fl_step.make_train_step(
            cfg, run_cfg, n_client_shards=C,
            client_axis="pod" if C > 1 else None, unroll=unroll,
            grad_pspecs=gspecs)

        def step(*a, _raw=raw_step, _sp=aspec, _cs=cot_specs):
            with use_activation_spec(_sp), use_param_cotangent_specs(_cs):
                return _raw(*a)
        args = (spec["params"], spec["momentum"], spec["batch"],
                spec["eta_bar"], spec["rng"])
        return step, args
    if kind == "prefill":
        raw_step = fl_step.make_prefill_step(cfg, run_cfg, unroll=unroll)

        def step(*a, _raw=raw_step, _sp=aspec):
            with use_activation_spec(_sp):
                return _raw(*a)
        return step, (spec["params"], spec["batch"])
    # decode
    step = fl_step.make_serve_step(cfg, run_cfg, seq_len=shape.seq_len,
                                   unroll=unroll)
    return step, (spec["params"], spec["cache"], spec["tokens"],
                  spec["pos"])


def analysis_variant(cfg, n_layers: int):
    """Reduced-depth, same-width config for trip-count-exact costing."""
    upd = {"n_layers": n_layers}
    if cfg.family == "encdec":
        upd["n_encoder_layers"] = n_layers
    if cfg.global_layers:
        upd["global_layers"] = tuple(
            g for g in cfg.global_layers if g < n_layers) or (0,)
    return dataclasses.replace(cfg, **upd)


def variant_costs(cfg, run_cfg, shape, mesh, n_layers: int):
    """(flops, bytes, coll_bytes) of an unrolled reduced-depth variant."""
    vcfg = analysis_variant(cfg, n_layers)
    with mesh:
        step, args = build_step(vcfg, run_cfg, shape, mesh, unroll=True)
        compiled = jax.jit(step).lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll = roofline.collective_bytes(compiled.as_text())
    chips = n_chips(mesh)  # cost_analysis reports the per-device module
    return (float(cost.get("flops", 0.0)) * chips,
            float(cost.get("bytes accessed", 0.0)) * chips,
            {k: v * chips for k, v in coll.items()})


def corrected_costs(cfg, run_cfg, shape, mesh):
    """Linear-extrapolate exact costs: cost(L) = c(P) + (L/P-1)(c(2P)-c(P)).

    XLA's cost_analysis counts while-loop bodies ONCE, so the production
    scan-over-layers executable under-reports by ~L.  Two unrolled
    reduced-depth compiles (depth P and 2P, P = the local/global period)
    give the exact per-layer-group delta.
    """
    P = cfg.local_global_period or 1
    L = cfg.n_layers
    f1, b1, c1 = variant_costs(cfg, run_cfg, shape, mesh, P)
    f2, b2, c2 = variant_costs(cfg, run_cfg, shape, mesh, 2 * P)
    groups = L // P
    flops = f1 + (groups - 1) * (f2 - f1)
    byts = b1 + (groups - 1) * (b2 - b1)
    coll = {k: c1.get(k, 0) + (groups - 1) * (c2.get(k, 0) - c1.get(k, 0))
            for k in set(c1) | set(c2)}
    return flops, byts, coll


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               verbose: bool = True, with_roofline: bool = None) -> dict:
    if with_roofline is None:
        with_roofline = not multi_pod   # roofline table is single-pod only
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = inputs_mod.shape_is_applicable(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "SKIP", "reason": why}
    run_cfg = RunConfig(model=cfg, shape=shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            step, args = build_step(cfg, run_cfg, shape, mesh)
            lowered = jax.jit(step).lower(*args)
            compiled = lowered.compile()
            hlo_text = compiled.as_text()
            mem = compiled.memory_analysis()
        if with_roofline:
            flops, byts, coll = corrected_costs(cfg, run_cfg, shape, mesh)
        else:
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            chips_ = n_chips(mesh)
            flops = float(cost.get("flops", 0.0)) * chips_
            byts = float(cost.get("bytes accessed", 0.0)) * chips_
            coll = {k: v * chips_ for k, v in
                    roofline.collective_bytes(hlo_text).items()}
        report = roofline.RooflineReport(
            arch=cfg.arch_id, shape=shape.name, mesh=mesh_name,
            chips=n_chips(mesh), hlo_flops=flops, hlo_bytes=byts,
            coll_bytes=float(sum(coll.values())),
            coll_breakdown={k: int(v) for k, v in coll.items()},
            model_flops_total=roofline.model_flops(
                cfg, shape, backward=shape.kind == "train"),
            bytes_per_device=0.0, compile_seconds=time.time() - t0)
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "OK", "roofline": report.to_dict(),
                  "memory_analysis": {
                      a: float(getattr(mem, a, 0) or 0)
                      for a in ("temp_size_in_bytes",
                                "argument_size_in_bytes",
                                "output_size_in_bytes",
                                "generated_code_size_in_bytes")}}
        if verbose:
            print(report.row(), flush=True)
            print(f"  bytes/device: args="
                  f"{result['memory_analysis']['argument_size_in_bytes']/1e9:.2f}GB "
                  f"temp={result['memory_analysis']['temp_size_in_bytes']/1e9:.2f}GB "
                  f"compile={report.compile_seconds:.1f}s", flush=True)
        return result
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        if verbose:
            print(f"{arch} {shape_name} {mesh_name} FAIL: {e}", flush=True)
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "FAIL", "error": str(e)[:2000],
                "compile_seconds": time.time() - t0}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun.json")
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                results.append(dryrun_one(arch, shape_name,
                                          multi_pod=multi_pod))
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    # merge with existing results (sweeps run incrementally)
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    merged = {key(r): r for r in existing}
    for r in results:
        merged[key(r)] = r
    with open(args.out, "w") as f:
        json.dump(list(merged.values()), f, indent=1)
    n_fail = sum(1 for r in results if r["status"] == "FAIL")
    print(f"\n{len(results)} runs, {n_fail} failures -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
