"""Batched serving driver: prefill + decode loop with KV/SSM caches.

Serves a (reduced) model on local devices: builds the decode cache,
prefills a prompt batch, then decodes tokens autoregressively with the
same ``serve_step`` the production dry-run lowers for decode_32k/long_500k.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data import make_batch
from repro.models import (forward_prefill, init_cache, init_params,
                          serve_step)
from repro.models import encdec, model as model_api


def prefill_into_cache(cfg, params, cache, tokens, *, seq_len):
    """Sequential prefill via serve_step (correct for every family)."""
    B, P = tokens.shape
    logits = None
    for pos in range(P):
        logits, cache = serve_step(cfg, params, cache, tokens[:, pos:pos+1],
                                   jnp.int32(pos), seq_len=seq_len)
    return logits, cache


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    seq_len = args.prompt_len + args.gen
    params = init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    cache = init_cache(cfg, args.batch, seq_len, jnp.float32)

    batch = make_batch(cfg, args.batch, args.prompt_len, seed=args.seed)
    tokens = jnp.asarray(batch["tokens"])

    if cfg.family == "encdec":
        enc_out = encdec.encode(cfg, params,
                                jnp.asarray(batch["encoder_embeds"]))
        cache = encdec.prime_cross_cache(cfg, params, cache, enc_out)

    step = jax.jit(lambda p, c, t, pos: serve_step(
        cfg, p, c, t, pos, seq_len=seq_len))

    t0 = time.time()
    logits, cache = prefill_into_cache(cfg, params, cache, tokens,
                                       seq_len=seq_len)
    print(f"prefill {args.prompt_len} tokens x{args.batch}: "
          f"{time.time()-t0:.2f}s")

    out = []
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = step(params, cache, cur,
                             jnp.int32(args.prompt_len + i))
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(cur)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen} tokens x{args.batch} in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)")
    print("sample:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
