"""End-to-end FL training driver.

Runs the paper's asynchronous FL protocol over any registered architecture
on the locally available devices: increasing sample-size rounds, diminishing
round step sizes, optional DP, checkpointing.  At production scale the same
step functions are what the dry-run lowers for the 16x16 / 2x16x16 meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --rounds 20 --batch 8 --seq 128 [--dp] [--p 1.0]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_fl_state
from repro.configs import (DPConfig, FLConfig, RunConfig,
                           SampleSequenceConfig, StepSizeConfig, get_config,
                           reduced)
from repro.core import (AsyncFLSimulator, BatchModelTask, round_stepsizes,
                        rounds_for_budget)
from repro.data import FederatedBatcher, client_sample_sizes
from repro.models import init_params


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CPU-friendly)")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eta0", type=float, default=0.05)
    ap.add_argument("--p", type=float, default=1.0,
                    help="sample-size growth exponent (0 => constant)")
    ap.add_argument("--s0", type=int, default=1,
                    help="local batch-steps in round 0")
    ap.add_argument("--d", type=int, default=1, help="delay gate slack")
    ap.add_argument("--dp", action="store_true")
    ap.add_argument("--sigma", type=float, default=8.0)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"arch={cfg.arch_id} family={cfg.family} layers={cfg.n_layers} "
          f"d={cfg.d_model} params~{cfg.param_count()/1e6:.1f}M")

    seq_cfg = SampleSequenceConfig(
        kind="power" if args.p > 0 else "constant",
        s0=args.s0, p=args.p, m=1.0)
    sizes = [max(1, int(round(args.s0 * ((i + 2) / 2) ** args.p)))
             for i in range(args.rounds)] if args.p > 0 \
        else [args.s0] * args.rounds
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_sqrt", eta0=args.eta0, beta=0.01), sizes)

    params = init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    batcher = FederatedBatcher(cfg, batch_size=args.batch, seq_len=args.seq,
                               seed=args.seed)
    task = BatchModelTask(cfg, params, batcher,
                          dp_clip=args.clip if args.dp else 0.0,
                          dp_sigma=args.sigma if args.dp else 0.0)

    per_client = [sizes] * args.clients   # p_c uniform
    sim = AsyncFLSimulator(
        task, n_clients=args.clients, sizes_per_client=per_client,
        round_stepsizes=etas, d=args.d, seed=args.seed,
        speeds=list(1.0 + 0.1 * np.arange(args.clients)))

    t0 = time.time()
    res = sim.run(max_rounds=args.rounds)
    dt = time.time() - t0
    print(f"rounds={res['final']['round']} messages="
          f"{res['final']['messages']} loss={res['final'].get('loss')} "
          f"wall={dt:.1f}s")
    for h in res["history"]:
        print(f"  round {h['round']:3d} loss={h.get('loss')}")
    if args.checkpoint:
        save_fl_state(args.checkpoint, global_model=res["model"],
                      server_k=res["final"]["round"])
        print(f"checkpoint -> {args.checkpoint}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
