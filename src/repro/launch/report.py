"""Render EXPERIMENTS.md tables from reports/dryrun.json."""
from __future__ import annotations

import json
import sys


def render(path: str = "reports/dryrun.json") -> str:
    with open(path) as f:
        results = json.load(f)
    out = []

    def fmt_bytes(b):
        return f"{b/1e9:.2f}"

    # --- dry-run table (both meshes) --------------------------------------
    out.append("### Dry-run results\n")
    out.append("| arch | shape | mesh | status | args GB/dev | temp GB/dev "
               "| compile s |")
    out.append("|---|---|---|---|---|---|---|")
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    for r in sorted(results, key=key):
        if r["status"] == "OK":
            ma = r.get("memory_analysis", {})
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
                f"{fmt_bytes(ma.get('argument_size_in_bytes', 0))} | "
                f"{fmt_bytes(ma.get('temp_size_in_bytes', 0))} | "
                f"{r.get('roofline', {}).get('compile_seconds', 0):.0f} |")
        elif r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP "
                       f"| - | - | - |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAIL: {r.get('error', '')[:60]} | - | - | - |")

    # --- roofline table (single-pod) ---------------------------------------
    out.append("\n### Roofline (16x16, 256 chips, v5e constants)\n")
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | MODEL/HLO flops |")
    out.append("|---|---|---|---|---|---|---|")
    for r in sorted(results, key=key):
        if r["status"] != "OK" or r["mesh"] != "16x16" \
                or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"**{rf['dominant']}** | {rf['useful_ratio']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else
                 "reports/dryrun.json"))
