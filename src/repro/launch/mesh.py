"""Production mesh definitions (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state (the 512-device dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
