from repro.data.convex import (biased_split, make_binary_dataset,
                               unbiased_split)
from repro.data.federated import (FederatedBatcher, SeedAddressedBatcher,
                                  client_sample_sizes)
from repro.data.synthetic import TokenStream, encoder_embed_stub, make_batch

__all__ = ["biased_split", "make_binary_dataset", "unbiased_split",
           "FederatedBatcher", "SeedAddressedBatcher",
           "client_sample_sizes", "TokenStream",
           "encoder_embed_stub", "make_batch"]
