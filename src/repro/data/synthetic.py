"""Synthetic LM token pipeline (deterministic, seedable, shardable).

Produces next-token-predictable streams (orderly Markov-ish sequences so a
training run shows decreasing loss) for smoke tests, examples, and the
end-to-end driver; ``federated.py`` layers client partitioning on top.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class TokenStream:
    """Deterministic pseudo-corpus: y_{t+1} = (a*y_t + b + drift) % V."""

    def __init__(self, vocab_size: int, *, seed: int = 0):
        self.V = vocab_size
        self.seed = seed

    def batch(self, batch_size: int, seq_len: int, *, step: int = 0,
              client_id: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + client_id * 7919 + step) % (2 ** 63))
        a = 2 * rng.integers(1, 8, size=(batch_size, 1)) + 1
        b = rng.integers(0, self.V, size=(batch_size, 1))
        start = rng.integers(0, self.V, size=(batch_size, 1))
        t = np.arange(seq_len)[None, :]
        toks = (start + a * t + b * (t // 7)) % self.V
        # inject noise tokens to keep the task non-trivial
        noise_mask = rng.random((batch_size, seq_len)) < 0.05
        noise = rng.integers(0, self.V, size=(batch_size, seq_len))
        toks = np.where(noise_mask, noise, toks)
        return {"tokens": toks.astype(np.int32)}


def encoder_embed_stub(batch_size: int, enc_seq: int, d_model: int, *,
                       seed: int = 0, step: int = 0) -> np.ndarray:
    """Precomputed frame/patch embeddings — the modality-frontend stub."""
    rng = np.random.default_rng(seed * 65_537 + step)
    return (0.02 * rng.standard_normal(
        (batch_size, enc_seq, d_model))).astype(np.float32)


def make_batch(cfg, batch_size: int, seq_len: int, *, seed: int = 0,
               step: int = 0, client_id: int = 0) -> Dict[str, np.ndarray]:
    """Family-aware batch: adds the encoder stub for enc-dec archs."""
    stream = TokenStream(cfg.vocab_size, seed=seed)
    batch = stream.batch(batch_size, seq_len, step=step, client_id=client_id)
    if cfg.family == "encdec":
        batch["encoder_embeds"] = encoder_embed_stub(
            batch_size, cfg.encoder_seq_len, cfg.d_model,
            seed=seed, step=step)
    return batch
