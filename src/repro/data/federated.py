"""Federated data layer: client-sharded batch production.

Implements SETUP's coin-flipping assignment (Algorithm 2 lines 5-13):
round i's s_i global samples are assigned to clients with probabilities
p_c, giving s_{i,c} with E[s_{i,c}] = p_c s_i.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.data.synthetic import make_batch


def client_sample_sizes(sizes: Sequence[int], p: Sequence[float], *,
                        seed: int = 0, exact: bool = False
                        ) -> List[List[int]]:
    """s_{i,c} per client.  exact=True uses s_{i,c} = round(p_c s_i)
    (the law-of-large-numbers approximation §A uses for the DP theory);
    exact=False flips coins per Algorithm 2."""
    n = len(p)
    rng = np.random.default_rng(seed)
    out: List[List[int]] = [[] for _ in range(n)]
    for s in sizes:
        if exact:
            counts = [max(1, int(round(pc * s))) for pc in p]
        else:
            assign = rng.choice(n, size=s, p=np.asarray(p) / np.sum(p))
            counts = [max(1, int(np.sum(assign == c))) for c in range(n)]
        for c in range(n):
            out[c].append(counts[c])
    return out


class FederatedBatcher:
    """Per-client LM batch producer for BatchModelTask / fl_step."""

    def __init__(self, cfg, *, batch_size: int, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed

    def __call__(self, client_id: int, round_idx: int, h: int, rng=None):
        import jax.numpy as jnp
        step = round_idx * 10_000 + h
        batch = make_batch(self.cfg, self.batch_size, self.seq_len,
                           seed=self.seed, step=step, client_id=client_id)
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def global_batch(self, n_clients: int, round_idx: int):
        """(C, B, S) stacked batch for the sharded fl_step."""
        import jax.numpy as jnp
        parts = [self(c, round_idx, 0) for c in range(n_clients)]
        return {k: jnp.stack([p[k] for p in parts]) for k in parts[0]}
