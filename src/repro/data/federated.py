"""Federated data layer: client-sharded batch production.

Implements SETUP's coin-flipping assignment (Algorithm 2 lines 5-13):
round i's s_i global samples are assigned to clients with probabilities
p_c, giving s_{i,c} with E[s_{i,c}] = p_c s_i.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.data.synthetic import make_batch


def client_sample_sizes(sizes: Sequence[int], p: Sequence[float], *,
                        seed: int = 0, exact: bool = False
                        ) -> List[List[int]]:
    """s_{i,c} per client.  exact=True uses s_{i,c} = round(p_c s_i)
    (the law-of-large-numbers approximation §A uses for the DP theory);
    exact=False flips coins per Algorithm 2."""
    n = len(p)
    rng = np.random.default_rng(seed)
    out: List[List[int]] = [[] for _ in range(n)]
    for s in sizes:
        if exact:
            counts = [max(1, int(round(pc * s))) for pc in p]
        else:
            assign = rng.choice(n, size=s, p=np.asarray(p) / np.sum(p))
            counts = [max(1, int(np.sum(assign == c))) for c in range(n)]
        for c in range(n):
            out[c].append(counts[c])
    return out


class SeedAddressedBatcher:
    """(client, round, iteration)-addressed LM batches, jit-traceable.

    ``FederatedBatcher`` builds batches host-side with numpy, which the
    cohort engines' vmapped block cannot call.  This variant derives one
    key per (client, round, iteration) with the exact ``fold_in`` chain
    ``CohortLogRegTask.sample_idx`` uses —
    ``fold_in(fold_in(fold_in(PRNGKey(seed), client), round), h)`` — and
    produces the batch from that key in pure jnp (``batch_from_key``), so
    the event simulator (calling this object as ``data_fn``) and the
    cohort engines (embedding ``batch_from_key`` inside their scans) draw
    bit-identical batches for the same (client, round, iteration),
    regardless of how either engine chunks a round.

    The token process mirrors ``TokenStream`` (orderly Markov-ish
    sequences + 5% noise) so training loss decreases on it.
    """

    def __init__(self, cfg, *, batch_size: int, seq_len: int, seed: int = 0):
        import jax
        if cfg.family == "encdec":
            raise ValueError(
                "SeedAddressedBatcher supports decoder families only: the "
                "encdec encoder-embedding stub is host-side numpy (use "
                "FederatedBatcher with the event engine)")
        self.cfg = cfg
        self.batch_size = int(batch_size)
        self.seq_len = int(seq_len)
        self.seed = int(seed)
        self.base = jax.random.PRNGKey(self.seed)

    def key_for(self, client_id, round_idx: int, h: int):
        import jax
        k = jax.random.fold_in(self.base, client_id)
        k = jax.random.fold_in(k, round_idx)
        return jax.random.fold_in(k, h)

    def batch_from_key(self, key):
        """key -> {"tokens": (B, S) i32}; pure jnp, traceable in jit."""
        import jax
        import jax.numpy as jnp
        V, B, S = self.cfg.vocab_size, self.batch_size, self.seq_len
        ka, kb, ks, km, kn = jax.random.split(key, 5)
        a = 2 * jax.random.randint(ka, (B, 1), 1, 8) + 1
        b = jax.random.randint(kb, (B, 1), 0, V)
        start = jax.random.randint(ks, (B, 1), 0, V)
        t = jnp.arange(S)[None, :]
        toks = (start + a * t + b * (t // 7)) % V
        noise_mask = jax.random.uniform(km, (B, S)) < 0.05
        noise = jax.random.randint(kn, (B, S), 0, V)
        return {"tokens": jnp.where(noise_mask, noise,
                                    toks).astype(jnp.int32)}

    def __call__(self, client_id: int, round_idx: int, h: int, rng=None):
        # rng accepted (and ignored) for data_fn-signature compatibility:
        # addressing is purely (client, round, iteration)
        return self.batch_from_key(self.key_for(client_id, round_idx, h))


class FederatedBatcher:
    """Per-client LM batch producer for BatchModelTask / fl_step."""

    def __init__(self, cfg, *, batch_size: int, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed

    def __call__(self, client_id: int, round_idx: int, h: int, rng=None):
        import jax.numpy as jnp
        step = round_idx * 10_000 + h
        batch = make_batch(self.cfg, self.batch_size, self.seq_len,
                           seed=self.seed, step=step, client_id=client_id)
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def global_batch(self, n_clients: int, round_idx: int):
        """(C, B, S) stacked batch for the sharded fl_step."""
        import jax.numpy as jnp
        parts = [self(c, round_idx, 0) for c in range(n_clients)]
        return {k: jnp.stack([p[k] for p in parts]) for k in parts[0]}
