"""Synthetic datasets for the paper's convex experiments.

The paper uses LIBSVM binary sets (phishing, a9a, covtype, w8a, ijcnn1)
and MNIST subsets; those files are not available offline, so we generate
statistically similar synthetic binary-classification problems (separable
with label noise) plus biased federated splits (Fig 2's regime).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def make_binary_dataset(n: int = 10_000, d: int = 64, *, noise: float = 0.5,
                        seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Linearly separable + Gaussian label noise (logreg-friendly)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d)
    X = rng.normal(size=(n, d)).astype(np.float32)
    margin = X @ w / np.sqrt(d)
    y = (margin + noise * rng.normal(size=n) > 0).astype(np.float32)
    return X, y


def unbiased_split(X, y, n_clients: int, *, seed: int = 0
                   ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """IID shards: each client sees the global distribution."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(X))
    return [(X[s], y[s]) for s in np.array_split(idx, n_clients)]


def biased_split(X, y, n_clients: int, *, bias: float = 1.0, seed: int = 0
                 ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Label-skewed shards (Fig 2): bias=1 gives fully class-pure clients
    (client c predominantly holds class c % 2), bias=0 reduces to IID."""
    rng = np.random.default_rng(seed)
    pos = np.flatnonzero(y == 1.0)
    neg = np.flatnonzero(y == 0.0)
    rng.shuffle(pos)
    rng.shuffle(neg)
    shards = []
    pos_parts = np.array_split(pos, n_clients)
    neg_parts = np.array_split(neg, n_clients)
    for c in range(n_clients):
        own = pos_parts[c] if c % 2 == 0 else neg_parts[c]
        other = neg_parts[c] if c % 2 == 0 else pos_parts[c]
        n_other = int(round(len(other) * (1.0 - bias)))
        take = np.concatenate([own, other[:n_other]])
        rng.shuffle(take)
        shards.append((X[take], y[take]))
    return shards
