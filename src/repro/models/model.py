"""Model API: family dispatch for init / train_loss / serve_step.

All architectures expose:
    init_params(cfg, key, dtype)                -> params pytree
    train_loss(cfg, params, batch, remat=True)  -> scalar loss (f32)
    init_cache(cfg, batch, cache_len, dtype)    -> decode cache pytree
    serve_step(cfg, params, cache, tokens, pos, seq_len) -> (logits, cache)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import encdec, transformer

_DECODER_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")


def init_params(cfg, key, dtype=jnp.bfloat16):
    if cfg.family in _DECODER_FAMILIES:
        return transformer.init_decoder(cfg, key, dtype)
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key, dtype)
    raise ValueError(f"unknown family {cfg.family!r}")


def train_loss(cfg, params, batch, *, remat: bool = True,
               unroll: bool = False):
    if cfg.family in _DECODER_FAMILIES:
        return transformer.train_loss(cfg, params, batch, remat=remat,
                                      unroll=unroll)
    if cfg.family == "encdec":
        return encdec.train_loss(cfg, params, batch, remat=remat,
                                 unroll=unroll)
    raise ValueError(f"unknown family {cfg.family!r}")


def forward_prefill(cfg, params, batch, *, remat: bool = True,
                    unroll: bool = False):
    """Prefill pass: returns last-position logits (B, V)."""
    if cfg.family in _DECODER_FAMILIES:
        hidden, _ = transformer.forward(cfg, params, batch["tokens"],
                                        remat=remat, unroll=unroll)
        from repro.models.common import unembed
        return unembed(cfg, params, hidden[:, -1])
    if cfg.family == "encdec":
        enc_out = encdec.encode(cfg, params, batch["encoder_embeds"],
                                remat=remat, unroll=unroll)
        hidden = encdec.decode_full(cfg, params, batch["tokens"], enc_out,
                                    remat=remat, unroll=unroll)
        from repro.models.common import unembed
        return unembed(cfg, params, hidden[:, -1])
    raise ValueError(f"unknown family {cfg.family!r}")


def init_cache(cfg, batch, cache_len, dtype=jnp.bfloat16):
    if cfg.family in _DECODER_FAMILIES:
        return transformer.init_cache(cfg, batch, cache_len, dtype)
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, cache_len, dtype)
    raise ValueError(f"unknown family {cfg.family!r}")


def serve_step(cfg, params, cache, tokens, pos, *, seq_len: int,
               unroll: bool = False):
    if cfg.family in _DECODER_FAMILIES:
        return transformer.serve_step(cfg, params, cache, tokens, pos,
                                      seq_len=seq_len, unroll=unroll)
    if cfg.family == "encdec":
        return encdec.serve_step(cfg, params, cache, tokens, pos,
                                 seq_len=seq_len, unroll=unroll)
    raise ValueError(f"unknown family {cfg.family!r}")
