"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain GELU MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import expand_rank, fan_in_init, gated_act


def init_mlp(cfg, key, dtype, *, n_layers=None, d_ff=None):
    L = n_layers if n_layers is not None else cfg.n_layers
    ff = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.activation in ("silu", "geglu"):
        p = {
            "wg": fan_in_init(ks[0], (L, d, ff), dtype),
            "wu": fan_in_init(ks[1], (L, d, ff), dtype),
            "wd": fan_in_init(ks[2], (L, ff, d), dtype),
        }
    else:  # plain gelu (whisper / grok expert style handled in moe)
        p = {
            "wu": fan_in_init(ks[0], (L, d, ff), dtype),
            "wd": fan_in_init(ks[1], (L, ff, d), dtype),
        }
        if cfg.mlp_bias:
            p["bu"] = jnp.zeros((L, ff), dtype)
            p["bd"] = jnp.zeros((L, d), dtype)
    return p


def apply_mlp(cfg, lp, x):
    """lp holds one layer's slices (no leading L axis)."""
    if "wg" in lp:
        gate = jnp.einsum("bsd,df->bsf", x, lp["wg"])
        up = jnp.einsum("bsd,df->bsf", x, lp["wu"])
        h = gated_act(cfg.activation, gate, up)
    else:
        h = jnp.einsum("bsd,df->bsf", x, lp["wu"])
        if "bu" in lp:
            h = h + expand_rank(lp["bu"], h.ndim)
        h = jax.nn.gelu(h, approximate=True)
    out = jnp.einsum("bsf,fd->bsd", h, lp["wd"])
    if "bd" in lp:
        out = out + expand_rank(lp["bd"], out.ndim)
    return out
