"""Whisper-style encoder-decoder backbone.

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``encoder_embeds`` (B, S_enc, d_model) arrive precomputed.  The
encoder adds sinusoidal positions and runs bidirectional attention; the
decoder is causal self-attention + cross-attention + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (apply_norm, init_norm, normal_init,
                                 padded_vocab, sinusoidal_positions,
                                 unembed)
from repro.models.transformer import _stack_norm, chunked_loss
from repro.sharding.context import constrain


def init_encdec(cfg, key, dtype):
    ks = jax.random.split(key, 12)
    d = cfg.d_model
    Vp = padded_vocab(cfg.vocab_size)
    params = {"embed": normal_init(ks[0], (Vp, d), dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = normal_init(ks[1], (Vp, d), dtype)

    Le = cfg.n_encoder_layers
    import dataclasses
    enc_cfg = dataclasses.replace(cfg, n_layers=Le)
    params["encoder"] = {
        "ln1": _stack_norm(cfg, ks[2], Le, d, dtype),
        "attn": attn.init_attention(enc_cfg, ks[3], dtype),
        "ln2": _stack_norm(cfg, ks[4], Le, d, dtype),
        "mlp": mlp_mod.init_mlp(cfg, ks[5], dtype, n_layers=Le),
    }
    params["encoder_final_norm"] = init_norm(cfg, ks[6], d, dtype)

    L = cfg.n_layers
    params["decoder"] = {
        "ln1": _stack_norm(cfg, ks[7], L, d, dtype),
        "self_attn": attn.init_attention(cfg, ks[8], dtype),
        "ln_x": _stack_norm(cfg, ks[9], L, d, dtype),
        "cross_attn": attn.init_attention(cfg, ks[9], dtype, cross=True),
        "ln2": _stack_norm(cfg, ks[10], L, d, dtype),
        "mlp": mlp_mod.init_mlp(cfg, ks[10], dtype),
    }
    params["final_norm"] = init_norm(cfg, ks[11], d, dtype)
    return params


def encode(cfg, params, encoder_embeds, *, remat: bool = True,
           unroll: bool = False):
    """encoder_embeds: (B, S_enc, d) from the conv/mel stub."""
    B, S, d = encoder_embeds.shape
    pe = sinusoidal_positions(S, d).astype(encoder_embeds.dtype)
    x = constrain(encoder_embeds + pe[None])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    full = jnp.ones((1, 1, S, S), bool)

    def body(x, lp):
        h = apply_norm(cfg, x, lp["ln1"])
        q, k, v = attn._project_qkv(cfg, lp["attn"], h, positions, rope=False)
        o = attn._scores_to_out(cfg, q, k, v, full)
        o = jnp.einsum("bsq,qd->bsd", o.reshape(B, S, -1), lp["attn"]["wo"])
        x = x + o
        h2 = apply_norm(cfg, x, lp["ln2"])
        return constrain(x + mlp_mod.apply_mlp(cfg, lp["mlp"], h2)), None

    if remat:
        body = jax.checkpoint(body)
    if unroll:
        for li in range(cfg.n_encoder_layers):
            lp = jax.tree_util.tree_map(lambda a: a[li], params["encoder"])
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(cfg, x, params["encoder_final_norm"])


def _decoder_embed(cfg, params, tokens):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    pe = sinusoidal_positions(max(S, 1), cfg.d_model).astype(x.dtype)
    return x + pe[None, :S]


def decode_full(cfg, params, tokens, enc_out, *, remat: bool = True,
                unroll: bool = False):
    """Teacher-forced decoder pass.  tokens (B,S_dec)."""
    B, S = tokens.shape
    x = constrain(_decoder_embed(cfg, params, tokens))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        h = apply_norm(cfg, x, lp["ln1"])
        x = x + attn.attend_full(cfg, lp["self_attn"], h, positions,
                                 rope=False, unroll=unroll)
        hx = apply_norm(cfg, x, lp["ln_x"])
        ek, ev = attn.project_cross_kv(cfg, lp["cross_attn"], enc_out)
        x = x + attn.cross_attend(cfg, lp["cross_attn"], hx, ek, ev)
        h2 = apply_norm(cfg, x, lp["ln2"])
        return constrain(x + mlp_mod.apply_mlp(cfg, lp["mlp"], h2)), None

    if remat:
        body = jax.checkpoint(body)
    if unroll:
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[li], params["decoder"])
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(body, x, params["decoder"])
    return apply_norm(cfg, x, params["final_norm"])


def train_loss(cfg, params, batch, *, remat: bool = True,
               unroll: bool = False):
    """batch: {"tokens": (B,S_dec), "encoder_embeds": (B,S_enc,d)}."""
    enc_out = encode(cfg, params, batch["encoder_embeds"], remat=remat,
                     unroll=unroll)
    tokens = batch["tokens"]
    hidden = decode_full(cfg, params, tokens[:, :-1], enc_out, remat=remat,
                         unroll=unroll)
    return chunked_loss(cfg, params, hidden, tokens[:, 1:],
                        batch.get("mask")[:, 1:] if batch.get("mask")
                        is not None else None, unroll=unroll)


# ---------------------------------------------------------------------------
# Decode with cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, cache_len: int, dtype):
    kv = attn.init_kv_cache(cfg, batch, cache_len, dtype)
    cross_shape = (cfg.n_layers, batch, cfg.encoder_seq_len,
                   cfg.n_kv_heads, cfg.head_dim)
    return {"kv": kv,
            "cross_k": jnp.zeros(cross_shape, dtype),
            "cross_v": jnp.zeros(cross_shape, dtype)}


def prime_cross_cache(cfg, params, cache, enc_out):
    """Fill per-layer cross K/V once after encoding."""
    def per_layer(lp):
        return attn.project_cross_kv(cfg, lp, enc_out)
    ks, vs = jax.vmap(per_layer)(params["decoder"]["cross_attn"])
    return dict(cache, cross_k=ks, cross_v=vs)


def serve_step(cfg, params, cache, tokens, pos, *, seq_len: int,
               unroll: bool = False):
    B = tokens.shape[0]
    x = _decoder_embed_pos(cfg, params, tokens, pos)

    def body(x, per_layer):
        lp, ck, cv, k, v = (per_layer["params"], per_layer["cross_k"],
                            per_layer["cross_v"], per_layer["k"],
                            per_layer["v"])
        h = apply_norm(cfg, x, lp["ln1"])
        o, nk, nv = attn.decode_attend(cfg, lp["self_attn"], h, k, v, pos,
                                       None, rope=False)
        x = x + o
        hx = apply_norm(cfg, x, lp["ln_x"])
        x = x + attn.cross_attend(cfg, lp["cross_attn"], hx, ck, cv)
        h2 = apply_norm(cfg, x, lp["ln2"])
        x = x + mlp_mod.apply_mlp(cfg, lp["mlp"], h2)
        return x, {"k": nk, "v": nv}

    xs = {"params": params["decoder"], "cross_k": cache["cross_k"],
          "cross_v": cache["cross_v"], "k": cache["kv"]["k"],
          "v": cache["kv"]["v"]}
    if unroll:
        kvs = []
        for li in range(cfg.n_layers):
            per = jax.tree_util.tree_map(lambda a: a[li], xs)
            x, kv = body(x, per)
            kvs.append(kv)
        new_kv = jax.tree_util.tree_map(lambda *us: jnp.stack(us), *kvs)
    else:
        x, new_kv = jax.lax.scan(body, x, xs)
    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params, x)
    return logits, dict(cache, kv={"k": new_kv["k"], "v": new_kv["v"]})


def _decoder_embed_pos(cfg, params, tokens, pos):
    x = jnp.take(params["embed"], tokens, axis=0)
    # sinusoidal position for a single dynamic position
    d = cfg.d_model
    import math
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10_000.0) * dim / d)
    ang = pos.astype(jnp.float32) * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
    return x + pe.astype(x.dtype)
