from repro.models.model import (forward_prefill, init_cache, init_params,
                                serve_step, train_loss)

__all__ = ["forward_prefill", "init_cache", "init_params", "serve_step",
           "train_loss"]
