"""Mamba-2 SSD (state-space duality) mixer — pure-JAX chunked algorithm.

Follows the SSD formulation of [arXiv:2405.21060] §6: the sequence is split
into chunks; intra-chunk interactions are a masked matmul (dual "attention"
form), inter-chunk state is carried by a short ``lax.scan`` over chunks.
A Pallas kernel version lives in ``repro.kernels.ssd_scan`` and is verified
against :func:`ssd_chunked` (the oracle).

Decode is the classic recurrent update h' = h·exp(dtA) + dt·(B ⊗ x).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.models.common import expand_rank, fan_in_init, rms_norm


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    proj_dim = 2 * d_inner + 2 * N + H
    return d_inner, H, N, conv_dim, proj_dim


def init_ssm(cfg, key, dtype, n_layers=None):
    L = n_layers if n_layers is not None else cfg.n_layers
    d = cfg.d_model
    d_inner, H, N, conv_dim, proj_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": fan_in_init(ks[0], (L, d, proj_dim), dtype),
        "conv_w": fan_in_init(ks[1], (L, conv_dim, cfg.ssm_conv_width), dtype),
        "conv_b": jnp.zeros((L, conv_dim), dtype),
        "dt_bias": jnp.zeros((L, H), dtype),
        "A_log": jnp.zeros((L, H), dtype),          # A = -exp(A_log) = -1 init
        "D": jnp.ones((L, H), dtype),
        "gate_norm": jnp.ones((L, d_inner), dtype),
        "out_proj": fan_in_init(ks[2], (L, d_inner, d), dtype),
    }


# ---------------------------------------------------------------------------
# Chunked SSD scan (training / prefill)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None,
                unroll: bool = False):
    """SSD over a full sequence.

    x: (b,s,h,p)  dt: (b,s,h)  A: (h,)  B,C: (b,s,n)  (single group).
    Returns (y (b,s,h,p), final_state (b,h,n,p)).
    """
    b, s_orig, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, s_orig)
    pad = (-s_orig) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 => no update
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // Q

    # §Perf knob: bf16 intra-chunk tensors (the (Q,Q,h) decay matrix is
    # the memory-bound term of the XLA SSD path; the Pallas kernel keeps
    # it in VMEM instead — see EXPERIMENTS.md §Perf, mamba2 iterations).
    intra_dt = (jnp.bfloat16 if os.environ.get("REPRO_SSD_BF16") == "1"
                else jnp.float32)
    xf = x.astype(jnp.float32).reshape(b, nc, Q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, Q, h)
    Bc = B.astype(jnp.float32).reshape(b, nc, Q, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, Q, n)
    Af = A.astype(jnp.float32)

    dA = dtf * expand_rank(Af, dtf.ndim)            # (b,nc,Q,h)
    dA_cum = jnp.cumsum(dA, axis=2)
    # intra-chunk decay matrix L[i,j] = exp(dA_cum[i] - dA_cum[j]), j <= i
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # (b,nc,Q,Q,h)
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tril[None, None, :, :, None], jnp.exp(seg),
                  0.0).astype(intra_dt)

    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc).astype(intra_dt)
    if os.environ.get("REPRO_SSD_TWOSTEP", "1") == "1":  # default ON (−32% mem)
        # §Perf: explicit scores + one batched (Q,Q)@(Q,P) matmul per
        # (b,c,h) — one materialization of the (Q,Q,h) tensor instead of
        # XLA's pairwise contraction order.
        scores = (CB[..., None] * L)             * dtf.astype(intra_dt)[:, :, None, :, :]
        Y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores,
                            xf.astype(intra_dt),
                            preferred_element_type=jnp.float32)
    else:
        Y_diag = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                            CB, L, dtf.astype(intra_dt),
                            xf.astype(intra_dt),
                            preferred_element_type=jnp.float32)

    # per-chunk end state contribution
    dA_sum = dA_cum[:, :, -1]                                   # (b,nc,h)
    w = jnp.exp(dA_sum[:, :, None] - dA_cum) * dtf              # (b,nc,Q,h)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w, Bc, xf)    # (b,nc,h,n,p)

    init = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def step(carry, inp):
        s_c, dA_s = inp                                         # (b,h,n,p),(b,h)
        new = carry * jnp.exp(dA_s)[..., None, None] + s_c
        return new, carry                                       # emit prev

    st_t = states.transpose(1, 0, 2, 3, 4)
    da_t = dA_sum.transpose(1, 0, 2)
    if unroll:
        carry, prevs = init, []
        for ci in range(nc):
            carry, out = step(carry, (st_t[ci], da_t[ci]))
            prevs.append(out)
        final, prev = carry, jnp.stack(prevs)
        prev = prev.transpose(1, 0, 2, 3, 4)                    # (b,nc,h,n,p)
    elif os.environ.get("REPRO_SSD_ASSOC") == "1" and initial_state is None:
        # §Perf: the inter-chunk linear recurrence as an associative scan
        # (log-depth tree) — avoids per-step resharding of the
        # model-axis-sharded chunk dimension in the sequential lax.scan.
        alpha = jnp.exp(dA_sum)[..., None, None]                # (b,nc,h,1,1)

        def combine(l, r):
            al, sl = l
            ar, sr = r
            return al * ar, sr + ar * sl

        a_inc, s_inc = jax.lax.associative_scan(
            combine, (alpha, states), axis=1)
        # inclusive prefix h_c; previous state = shift right with init
        prev = jnp.concatenate(
            [jnp.broadcast_to(init[:, None], states[:, :1].shape),
             s_inc[:, :-1]], axis=1)
        final = s_inc[:, -1]
    else:
        final, prev = jax.lax.scan(step, init, (st_t, da_t))
        prev = prev.transpose(1, 0, 2, 3, 4)                    # (b,nc,h,n,p)

    Y_off = jnp.einsum("bcin,bcih,bchnp->bcihp",
                       Cc, jnp.exp(dA_cum), prev)
    y = (Y_diag + Y_off).reshape(b, s, h, p)[:, :s_orig].astype(x.dtype)
    return y, final


def causal_depthwise_conv(x, w, b):
    """x: (B,S,C), w: (C,W), b: (C,).  Causal depthwise conv."""
    W = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.T[:, None, :].astype(jnp.float32),        # (W,1,C) -> spec below
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return (out + expand_rank(b.astype(jnp.float32), out.ndim)).astype(x.dtype)


def _split_proj(cfg, zxbcdt):
    d_inner, H, N, _, _ = ssm_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xBC, dt, d_inner, H, N


def apply_ssm(cfg, lp, x, *, return_state: bool = False, ssd_fn=None,
              unroll: bool = False):
    """Full-sequence mamba2 mixer.  x: (B,S,d) -> (B,S,d)."""
    B_, S, _ = x.shape
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, lp["in_proj"])
    z, xBC, dt, d_inner, H, N = _split_proj(cfg, zxbcdt)

    xBC = jax.nn.silu(causal_depthwise_conv(xBC, lp["conv_w"], lp["conv_b"]))
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + N]
    Cm = xBC[..., d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + expand_rank(lp["dt_bias"].astype(jnp.float32),
                                       dt.ndim))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))

    P = cfg.ssm_head_dim
    xh = xs.reshape(B_, S, H, P)
    chunk = int(os.environ.get("REPRO_SSD_CHUNK", cfg.ssm_chunk))
    if ssd_fn is not None:
        y, final = ssd_fn(xh, dt, A, Bm, Cm, chunk)
    else:
        y, final = ssd_chunked(xh, dt, A, Bm, Cm, chunk,
                               unroll=unroll)
    y = y + lp["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B_, S, d_inner)

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, lp["out_proj"])
    if return_state:
        # conv state: last (W-1) xBC inputs (pre-activation path needs raw
        # conv input; we store the raw projection tail)
        raw_xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
        W = cfg.ssm_conv_width
        conv_state = raw_xBC[:, -(W - 1):, :]
        return out, final, conv_state
    return out


def init_ssm_state(cfg, batch: int, n_layers=None):
    L = n_layers if n_layers is not None else cfg.n_layers
    d_inner, H, N, conv_dim, _ = ssm_dims(cfg)
    P = cfg.ssm_head_dim
    return {
        "h": jnp.zeros((L, batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.ssm_conv_width - 1, conv_dim),
                          jnp.float32),
    }


def decode_ssm(cfg, lp, x, h_state, conv_state):
    """Single-token recurrent step.

    x: (B,1,d); h_state: (B,H,N,P); conv_state: (B,W-1,conv_dim).
    Returns (out (B,1,d), new_h, new_conv).
    """
    B_ = x.shape[0]
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, lp["in_proj"])[:, 0]  # (B,k)
    z, xBC, dt, d_inner, H, N = _split_proj(cfg, zxbcdt[:, None, :])
    z, xBC, dt = z[:, 0], xBC[:, 0], dt[:, 0]

    # conv ring: window = [conv_state, xBC]
    win = jnp.concatenate([conv_state.astype(xBC.dtype), xBC[:, None, :]],
                          axis=1)                               # (B,W,conv)
    conv_out = jnp.einsum("bwc,cw->bc", win.astype(jnp.float32),
                          lp["conv_w"].astype(jnp.float32)) \
        + expand_rank(lp["conv_b"].astype(jnp.float32), 2)
    xBC_act = jax.nn.silu(conv_out)
    new_conv = win[:, 1:, :].astype(jnp.float32)

    xs = xBC_act[..., :d_inner]
    Bm = xBC_act[..., d_inner:d_inner + N]
    Cm = xBC_act[..., d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + expand_rank(lp["dt_bias"].astype(jnp.float32),
                                       dt.ndim))                # (B,H)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))               # (H,)
    P = cfg.ssm_head_dim
    xh = xs.reshape(B_, H, P).astype(jnp.float32)

    decay = jnp.exp(dt * expand_rank(A, dt.ndim))               # (B,H)
    new_h = h_state * decay[..., None, None] \
        + jnp.einsum("bh,bn,bhp->bhnp", dt, Bm, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm, new_h) \
        + lp["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B_, d_inner)

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                 lp["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y.astype(x.dtype), lp["out_proj"])
    return out[:, None, :], new_h, new_conv
