"""Grouped-query attention with sliding-window, softcap, and KV-cache decode.

Two full-sequence paths:
  * ``attend_full``    — masked dense attention (baseline; window via mask)
  * ``attend_chunked`` — block-local attention that only computes the
    window-adjacent chunks (beyond-paper optimization; used when
    ``chunked_local=True`` and a window is set).  Saves O(S/W) of the
    attention FLOPs for local layers at long sequence lengths.

Decode path attends a single query token against a (ring-buffered) cache.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

import os

from repro.models.common import (apply_rope, expand_rank, fan_in_init,
                                 softcap, zeros_init)

NEG_INF = -2.0 ** 30


def init_attention(cfg, key, dtype, *, cross: bool = False):
    d, q_dim = cfg.d_model, cfg.n_heads * cfg.head_dim
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": fan_in_init(ks[0], (cfg.n_layers, d, q_dim), dtype),
        "wk": fan_in_init(ks[1], (cfg.n_layers, d, kv_dim), dtype),
        "wv": fan_in_init(ks[2], (cfg.n_layers, d, kv_dim), dtype),
        "wo": fan_in_init(ks[3], (cfg.n_layers, q_dim, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_layers, q_dim), dtype)
        p["bk"] = jnp.zeros((cfg.n_layers, kv_dim), dtype)
        p["bv"] = jnp.zeros((cfg.n_layers, kv_dim), dtype)
    return p


def _project_qkv(cfg, lp, x, positions, *, rope: bool = True):
    """x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, lp["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, lp["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, lp["wv"])
    if "bq" in lp:
        q = q + expand_rank(lp["bq"], q.ndim)
        k = k + expand_rank(lp["bk"], k.ndim)
        v = v + expand_rank(lp["bv"], v.ndim)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # (§Perf note: an attempted "project-then-gather-KV" constraint here
    # REGRESSED collective time 3.0->3.6s on gemma2 train_4k — GSPMD's own
    # propagation was already better; see EXPERIMENTS.md §Perf.)
    return q, k, v


def _scores_to_out(cfg, q, k, v, mask):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask broadcastable (B,1,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    qg = q.reshape(B, Sq, KV, group, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                       scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def causal_mask(Sq: int, Sk: int, window) -> jnp.ndarray:
    """(1,1,Sq,Sk) boolean; window may be a traced scalar (None => full)."""
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kj = jnp.arange(Sk)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (qi - kj < window)
    return m[None, None]


def attend_full(cfg, lp, x, positions, window=None, *, rope=True,
                q_chunk: int = None, unroll: bool = False):
    if q_chunk is None:
        q_chunk = int(os.environ.get("REPRO_Q_CHUNK", "1024"))
    """Masked attention over the full sequence.

    For S > q_chunk the query dimension is processed in chunks (bounding
    the S x S score buffer to q_chunk x S — the XLA stand-in for the
    Pallas flash kernel).  ``unroll=True`` replaces the chunk scan with a
    Python loop so cost_analysis counts every trip (dry-run analysis
    variants only).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, lp, x, positions, rope=rope)
    if S <= q_chunk:
        mask = causal_mask(S, S, window)
        out = _scores_to_out(cfg, q, k, v, mask)
        return jnp.einsum("bsq,qd->bsd",
                          out.reshape(B, S, -1), lp["wo"])

    QC = q_chunk
    pad = (-S) % QC
    if pad:  # keep chunks homogeneous
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (S + pad) // QC
    kj = jnp.arange(S)[None, :]

    def one_chunk(ci, q_c):
        qi = ci * QC + jnp.arange(QC)[:, None]
        m = kj <= qi
        if window is not None:
            m = m & (qi - kj < window)
        return _scores_to_out(cfg, q_c, k, v, m[None, None])

    if unroll:
        outs = [one_chunk(jnp.int32(ci), q[:, ci * QC:(ci + 1) * QC])
                for ci in range(nq)]
        out = jnp.concatenate(outs, axis=1)
    else:
        qr = q.reshape(B, nq, QC, cfg.n_heads, cfg.head_dim) \
            .transpose(1, 0, 2, 3, 4)

        def body(_, inp):
            ci, q_c = inp
            return None, one_chunk(ci, q_c)

        _, outs = jax.lax.scan(body, None,
                               (jnp.arange(nq, dtype=jnp.int32), qr))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, -1)
    out = out[:, :S] if pad else out.reshape(B, S, -1)
    return jnp.einsum("bsq,qd->bsd", out.reshape(B, S, -1), lp["wo"])


def attend_chunked(cfg, lp, x, positions, window: int, *, rope=True):
    """Block-local attention: queries in chunk c attend to chunks c-1, c.

    Requires S % window == 0.  Exact for any sliding window <= chunk size
    (we set chunk = window).  FLOPs: 2*S*W*d instead of S^2*d/2.
    """
    B, S, _ = x.shape
    W = window
    if S % W != 0:
        return attend_full(cfg, lp, x, positions, window, rope=rope)
    q, k, v = _project_qkv(cfg, lp, x, positions, rope=rope)
    C = S // W
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    qc = q.reshape(B, C, W, H, hd)
    kc = k.reshape(B, C, W, KV, hd)
    vc = v.reshape(B, C, W, KV, hd)
    # previous chunk (zero for c=0, masked out anyway)
    kp = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vp = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kp, kc], axis=2)  # (B,C,2W,KV,hd)
    v2 = jnp.concatenate([vp, vc], axis=2)

    group = H // KV
    qg = qc.reshape(B, C, W, KV, group, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bcqkgh,bcskh->bckgqs", qg.astype(jnp.float32),
                        k2.astype(jnp.float32)) * scale
    scores = softcap(scores, cfg.attn_softcap)

    qi = jnp.arange(W)[:, None] + W           # position within the 2W window
    kj = jnp.arange(2 * W)[None, :]
    mask = (kj <= qi) & (qi - kj < W)         # causal + window
    first = jnp.arange(C)[:, None, None] == 0
    valid = jnp.where(first, kj[None] >= W, True)  # chunk 0 has no prev
    mask = mask[None] & valid                  # (C,W,2W)
    scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bckgqs,bcskh->bcqkgh", probs, v2.astype(jnp.float32))
    out = out.reshape(B, S, H * hd).astype(x.dtype)
    return jnp.einsum("bsq,qd->bsd", out, lp["wo"])


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, cache_len: int, dtype):
    """dtype jnp.int8 selects the quantized cache layout (per-(token,head)
    absmax scales) — halves decode HBM vs bf16; see EXPERIMENTS.md §Dry-run
    note (‡) on qwen1.5-32b decode_32k capacity."""
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    if dtype == jnp.int8:
        sshape = shape[:-1]
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.bfloat16),
                "v_scale": jnp.zeros(sshape, jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def quantize_kv(x):
    """x: (..., hd) -> (int8 values, bf16 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def decode_attend(cfg, lp, x, cache_k, cache_v, pos, window=None, *,
                  rope=True, ring: bool = False):
    """One-token decode.  x: (B,1,d); cache_[kv]: (B,L_cache,KV,hd);
    pos: scalar int32 current position.  Returns (out (B,1,d), new_k, new_v).

    ring=True treats the cache as a ring buffer of size L_cache (used when
    the cache is smaller than the logical sequence, i.e. windowed decode).
    """
    B = x.shape[0]
    L_cache = cache_k.shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, lp, x, positions, rope=rope)
    slot = jnp.where(jnp.asarray(ring), pos % L_cache,
                     jnp.minimum(pos, L_cache - 1))
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))

    idx = jnp.arange(L_cache)
    if ring:
        # entry at idx holds logical position: reconstructed from ring layout
        logical = jnp.where(idx <= slot, pos - (slot - idx),
                            pos - (slot + L_cache - idx))
        valid = logical >= 0
    else:
        logical = idx
        valid = idx <= pos
    if window is not None:
        valid = valid & (pos - logical < window)
    mask = valid[None, None, None, :]  # (1,1,1,L_cache)

    out = _scores_to_out(cfg, q, cache_k, cache_v, mask)
    out = jnp.einsum("bsq,qd->bsd", out.reshape(B, 1, -1), lp["wo"])
    return out, cache_k, cache_v


def decode_attend_quantized(cfg, lp, x, qcache, pos, window=None, *,
                            rope=True, ring: bool = False):
    """int8-KV decode: dequantize-on-read, quantize-on-write.

    qcache: {k, v: int8 (B,L,KV,hd); k_scale, v_scale: bf16 (B,L,KV)}.
    Returns (out, new_cache_dict).
    """
    B = x.shape[0]
    L_cache = qcache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, lp, x, positions, rope=rope)

    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    slot = jnp.where(jnp.asarray(ring), pos % L_cache,
                     jnp.minimum(pos, L_cache - 1))
    new = {}
    new["k"] = jax.lax.dynamic_update_slice(qcache["k"], kq, (0, slot, 0, 0))
    new["v"] = jax.lax.dynamic_update_slice(qcache["v"], vq, (0, slot, 0, 0))
    new["k_scale"] = jax.lax.dynamic_update_slice(
        qcache["k_scale"], ks, (0, slot, 0))
    new["v_scale"] = jax.lax.dynamic_update_slice(
        qcache["v_scale"], vs, (0, slot, 0))

    k_f = dequantize_kv(new["k"], new["k_scale"]).astype(q.dtype)
    v_f = dequantize_kv(new["v"], new["v_scale"]).astype(q.dtype)

    idx = jnp.arange(L_cache)
    if ring:
        logical = jnp.where(idx <= slot, pos - (slot - idx),
                            pos - (slot + L_cache - idx))
        valid = logical >= 0
    else:
        logical = idx
        valid = idx <= pos
    if window is not None:
        valid = valid & (pos - logical < window)
    mask = valid[None, None, None, :]
    out = _scores_to_out(cfg, q, k_f, v_f, mask)
    out = jnp.einsum("bsq,qd->bsd", out.reshape(B, 1, -1), lp["wo"])
    return out, new


def cross_attend(cfg, lp, x, enc_k, enc_v):
    """Cross attention (whisper decoder).  enc_[kv]: (B,S_enc,KV,hd)."""
    B, Sq, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, lp["wq"])
    if "bq" in lp:
        q = q + expand_rank(lp["bq"], q.ndim)
    q = q.reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    mask = jnp.ones((1, 1, Sq, enc_k.shape[1]), bool)
    out = _scores_to_out(cfg, q, enc_k, enc_v, mask)
    return jnp.einsum("bsq,qd->bsd", out.reshape(B, Sq, -1), lp["wo"])


def project_cross_kv(cfg, lp, enc_out):
    """Precompute cross-attention K/V from encoder output (done once)."""
    B, S, _ = enc_out.shape
    k = jnp.einsum("bsd,dk->bsk", enc_out, lp["wk"])
    v = jnp.einsum("bsd,dk->bsk", enc_out, lp["wv"])
    if "bk" in lp:
        k = k + expand_rank(lp["bk"], k.ndim)
        v = v + expand_rank(lp["bv"], v.ndim)
    return (k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim))
