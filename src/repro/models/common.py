"""Shared neural-net building blocks (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays.  Per-layer parameters are
*stacked* on a leading layer axis so the decoder runs as a single
``jax.lax.scan`` — this keeps HLO size O(1) in depth, which matters when
compiling 64-layer models in the multi-pod dry-run.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, stddev: float = 0.02):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def fan_in_init(key, shape, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return normal_init(key, shape, dtype, stddev=1.0 / math.sqrt(fan_in))


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def expand_rank(v, ndim: int):
    """Left-pad ``v`` with unit axes so it broadcasts against a rank-``ndim``
    array along trailing axes.  Explicit so the suite can run with
    ``jax_numpy_rank_promotion='raise'``."""
    return jnp.reshape(v, (1,) * (ndim - v.ndim) + v.shape)


def rms_norm(x, scale, eps: float = 1e-6, *, gemma_style: bool = False):
    """RMSNorm.  gemma_style uses (1 + scale) weighting."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if gemma_style \
        else scale.astype(jnp.float32)
    return (x * expand_rank(w, x.ndim)).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * expand_rank(scale.astype(jnp.float32), x.ndim)
            + expand_rank(bias.astype(jnp.float32), x.ndim)).astype(dtype)


def apply_norm(cfg, x, params):
    if cfg.norm == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rms_norm(x, params["scale"], cfg.norm_eps,
                    gemma_style=cfg.embed_scale)


def init_norm(cfg, key, d, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    init = zeros_init if cfg.embed_scale else ones_init
    return {"scale": init(key, (d,), dtype)}


# ---------------------------------------------------------------------------
# Activations / softcap
# ---------------------------------------------------------------------------

def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def gated_act(kind: str, gate, up):
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    if kind == "silu":
        return jax.nn.silu(gate) * up
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, head_dim); positions: (..., S)."""
    if theta <= 0:
        return x
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)
    pos = positions[..., None].astype(jnp.float32)             # (..., S, 1)
    angles = pos * expand_rank(freqs, pos.ndim)                # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int):
    """Whisper-style fixed sinusoidal position embeddings."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10_000.0) * dim / d_model)
    ang = pos * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe  # (S, d_model)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def padded_vocab(vocab_size: int, multiple: int = 256) -> int:
    """Storage rows of the embedding table.

    Odd vocabularies (whisper 51866, hymba 32001, mamba2 50280) cannot be
    sharded on the 16-way model axis; padding the PARAMETER to a multiple
    of 256 (a standard implementation detail — ids never reach the pad
    rows, pad logits are masked to -inf) restores vocab-parallel
    unembedding.  §Perf iteration; semantics (cfg.vocab_size) unchanged.
    """
    if vocab_size % multiple == 0 or vocab_size < multiple:
        return vocab_size
    return vocab_size + (-vocab_size) % multiple


def embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg, params, x):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("...d,vd->...v", x, table)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    Vp = table.shape[0]
    if Vp != cfg.vocab_size:   # mask padded rows out of the softmax
        valid = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(expand_rank(valid, logits.ndim), logits, -1e30)
    return logits


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token-level cross entropy.  logits f32 (..., V), labels int."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
