"""Unified decoder backbone for dense / moe / ssm / hybrid / vlm families.

Per-layer params are stacked on a leading axis and the stack runs under one
``jax.lax.scan`` (homogeneous layers; per-layer heterogeneity such as
gemma2's local/global alternation is expressed as a scanned per-layer
``window`` scalar).  ``jax.checkpoint`` wraps the body when remat is on.

Loss materialization: logits for 256k vocabularies are never materialized
for the full sequence — cross entropy runs in sequence chunks under
``jax.checkpoint`` (recompute in backward), bounding live memory.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (apply_norm, cross_entropy_loss, embed_tokens,
                                 init_norm, normal_init, padded_vocab,
                                 softcap, unembed)
from repro.sharding.context import constrain, shard_layer_param_cotangents


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_decoder(cfg, key, dtype):
    ks = jax.random.split(key, 8)
    Vp = padded_vocab(cfg.vocab_size)
    params = {"embed": normal_init(ks[0], (Vp, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = normal_init(ks[1], (Vp, cfg.d_model), dtype)

    blocks = {}
    L, d = cfg.n_layers, cfg.d_model
    blocks["ln1"] = _stack_norm(cfg, ks[2], L, d, dtype)
    if cfg.family != "ssm":
        blocks["attn"] = attn.init_attention(cfg, ks[3], dtype)
        blocks["ln2"] = _stack_norm(cfg, ks[4], L, d, dtype)
        if cfg.post_attn_norm:
            blocks["post_attn"] = _stack_norm(cfg, ks[4], L, d, dtype)
            blocks["post_mlp"] = _stack_norm(cfg, ks[5], L, d, dtype)
        if cfg.n_experts:
            blocks["moe"] = moe_mod.init_moe(cfg, ks[5], dtype)
        else:
            blocks["mlp"] = mlp_mod.init_mlp(cfg, ks[5], dtype)
    if cfg.family in ("ssm", "hybrid"):
        blocks["ssm"] = ssm_mod.init_ssm(cfg, ks[6], dtype)
    params["blocks"] = blocks
    params["final_norm"] = init_norm(cfg, ks[7], d, dtype)
    return params


def _stack_norm(cfg, key, L, d, dtype):
    one = init_norm(cfg, key, d, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)


def layer_windows(cfg, seq_len: int) -> jnp.ndarray:
    """(L,) int32 effective attention window per layer (seq_len == full)."""
    out = []
    for i in range(cfg.n_layers):
        if cfg.sliding_window is not None and cfg.layer_is_local(i):
            out.append(min(cfg.sliding_window, seq_len))
        else:
            out.append(seq_len)
    return jnp.asarray(out, jnp.int32)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_body(cfg, x, lp, window, positions, *, unroll=False,
                chunked_local_window: Optional[int] = None):
    """One decoder layer.  x: (B,S,d).

    chunked_local_window: when set (static int), the layer uses the
    block-local attention path (computes only window-adjacent chunks —
    the beyond-paper FLOP saving; see EXPERIMENTS.md §Perf).
    """
    aux = jnp.float32(0.0)
    h = apply_norm(cfg, x, _idx(lp, "ln1"))
    if cfg.family == "ssm":
        x = x + ssm_mod.apply_ssm(cfg, lp["ssm"], h, unroll=unroll)
        return x, aux
    if chunked_local_window is not None:
        attn_out = attn.attend_chunked(cfg, lp["attn"], h, positions,
                                       chunked_local_window)
    else:
        attn_out = attn.attend_full(cfg, lp["attn"], h, positions, window,
                                    unroll=unroll)
    if cfg.family == "hybrid":
        ssm_out = ssm_mod.apply_ssm(cfg, lp["ssm"], h, unroll=unroll)
        attn_out = 0.5 * (attn_out + ssm_out)
    if cfg.post_attn_norm:
        attn_out = apply_norm(cfg, attn_out, _idx(lp, "post_attn"))
    x = x + attn_out
    h2 = apply_norm(cfg, x, _idx(lp, "ln2"))
    if cfg.n_experts:
        ff, aux = moe_mod.apply_moe(cfg, lp["moe"], h2)
    else:
        ff = mlp_mod.apply_mlp(cfg, lp["mlp"], h2)
    if cfg.post_attn_norm:
        ff = apply_norm(cfg, ff, _idx(lp, "post_mlp"))
    x = x + ff
    return x, aux


def _idx(lp, name):
    return lp[name]


def forward(cfg, params, tokens, *, remat: bool = True,
            positions: Optional[jnp.ndarray] = None, unroll: bool = False):
    """tokens (B,S) -> final hidden states (B,S,d) and aux loss.

    unroll=True runs a Python loop over layers (and inner chunk loops) so
    the compiled HLO has exact trip counts for cost analysis.
    """
    B, S = tokens.shape
    x = constrain(embed_tokens(cfg, params, tokens))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = layer_windows(cfg, S)

    chunked_local = (
        os.environ.get("REPRO_CHUNKED_LOCAL") == "1"
        and cfg.sliding_window is not None
        and cfg.local_global_period == 2
        and cfg.n_layers % 2 == 0
        and S > 2 * cfg.sliding_window)

    if chunked_local:
        # §Perf: scan over (local, global) layer PAIRS so the local layer
        # can take the block-local attention path with a STATIC window.
        W = int(cfg.sliding_window)
        pair_blocks = jax.tree_util.tree_map(
            lambda a: a.reshape((cfg.n_layers // 2, 2) + a.shape[1:]),
            params["blocks"])

        def pair_body(carry, lp2):
            x, aux = carry
            lp_loc = jax.tree_util.tree_map(lambda a: a[0], lp2)
            lp_glb = jax.tree_util.tree_map(lambda a: a[1], lp2)
            lp_loc = shard_layer_param_cotangents(lp_loc)
            lp_glb = shard_layer_param_cotangents(lp_glb)
            x, a1 = _layer_body(cfg, x, lp_loc, None, positions,
                                unroll=unroll, chunked_local_window=W)
            x = constrain(x)
            x, a2 = _layer_body(cfg, x, lp_glb, jnp.int32(S), positions,
                                unroll=unroll)
            return (constrain(x), aux + a1 + a2), None

        if remat:
            pair_body = jax.checkpoint(pair_body)
        if unroll:
            carry = (x, jnp.float32(0.0))
            for li in range(cfg.n_layers // 2):
                lp2 = jax.tree_util.tree_map(lambda a: a[li], pair_blocks)
                carry, _ = pair_body(carry, lp2)
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(pair_body, (x, jnp.float32(0.0)),
                                       pair_blocks)
        x = apply_norm(cfg, x, params["final_norm"])
        return x, aux

    def body(carry, per_layer):
        x, aux = carry
        lp, window = per_layer
        lp = shard_layer_param_cotangents(lp)
        x, a = _layer_body(cfg, x, lp, window, positions, unroll=unroll)
        return (constrain(x), aux + a), None

    if remat:
        body = jax.checkpoint(body)
    if unroll:
        carry = (x, jnp.float32(0.0))
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[li], params["blocks"])
            carry, _ = body(carry, (lp, windows[li]))
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   (params["blocks"], windows))
    x = apply_norm(cfg, x, params["final_norm"])
    return x, aux


def chunked_loss(cfg, params, hidden, labels, mask=None, chunk: int = 512,
                 unroll: bool = False):
    """Cross entropy over sequence chunks (never materializes (B,S,V))."""
    B, S, _ = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        base = jnp.ones_like(labels).at[:, S:].set(0)
        mask = base if mask is None else jnp.pad(mask, ((0, 0), (0, pad)))
        S = S + pad
    nc = S // chunk

    @jax.checkpoint
    def one(h_c, y_c, m_c):
        logits = unembed(cfg, params, h_c)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y_c[..., None], axis=-1)[..., 0]
        m = m_c.astype(jnp.float32)
        return jnp.sum(-ll * m), jnp.sum(m)

    hs = hidden.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    ys = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    ms = (jnp.ones_like(labels) if mask is None else mask) \
        .reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        l, c = one(*xs)
        return (tot + l, cnt + c), None

    if unroll:
        carry = (jnp.float32(0.0), jnp.float32(0.0))
        for ci in range(nc):
            carry, _ = body(carry, (hs[ci], ys[ci], ms[ci]))
        tot, cnt = carry
    else:
        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ys, ms))
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(cfg, params, batch, *, remat: bool = True,
               unroll: bool = False):
    """Next-token LM loss.  batch: {"tokens": (B,S)} (+ optional mask)."""
    tokens = batch["tokens"]
    hidden, aux = forward(cfg, params, tokens[:, :-1], remat=remat,
                          unroll=unroll)
    labels = tokens[:, 1:]
    mask = batch.get("mask")
    if mask is not None:
        mask = mask[:, 1:]
    loss = chunked_loss(cfg, params, hidden, labels, mask, unroll=unroll)
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# Decode (single token) with stacked caches scanned over layers
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, cache_len: int, dtype):
    cache = {}
    if cfg.family != "ssm":
        cache["kv"] = attn.init_kv_cache(cfg, batch, cache_len, dtype)
    if cfg.family in ("ssm", "hybrid"):
        cache["ssm"] = ssm_mod.init_ssm_state(cfg, batch)
    return cache


def serve_step(cfg, params, cache, tokens, pos, *, seq_len: int,
               unroll: bool = False):
    """Decode one token.  tokens (B,1); pos scalar int32.

    ``seq_len`` is the logical max sequence; ring buffering activates when
    the allocated cache is shorter (windowed long-context decode).
    """
    B = tokens.shape[0]
    x = embed_tokens(cfg, params, tokens)
    windows = layer_windows(cfg, seq_len)

    cache_len = None
    ring = False
    quantized = False
    if "kv" in cache:
        cache_len = cache["kv"]["k"].shape[2]
        ring = cache_len < seq_len
        quantized = "k_scale" in cache["kv"]

    def body(x, per_layer):
        lp, window, layer_cache = (per_layer["params"], per_layer["window"],
                                   per_layer["cache"])
        h = apply_norm(cfg, x, _idx(lp, "ln1"))
        new_cache = {}
        if cfg.family == "ssm":
            out, new_h, new_conv = ssm_mod.decode_ssm(
                cfg, lp["ssm"], h, layer_cache["ssm_h"],
                layer_cache["ssm_conv"])
            new_cache.update(ssm_h=new_h, ssm_conv=new_conv)
            return x + out, new_cache
        eff_window = jnp.minimum(window, seq_len)
        if quantized:
            a_out, qc = attn.decode_attend_quantized(
                cfg, lp["attn"], h,
                {k: layer_cache[k] for k in
                 ("k", "v", "k_scale", "v_scale")},
                pos, eff_window, ring=ring)
            new_cache.update(qc)
        else:
            a_out, nk, nv = attn.decode_attend(
                cfg, lp["attn"], h, layer_cache["k"], layer_cache["v"],
                pos, eff_window, ring=ring)
            new_cache.update(k=nk, v=nv)
        if cfg.family == "hybrid":
            s_out, new_h, new_conv = ssm_mod.decode_ssm(
                cfg, lp["ssm"], h, layer_cache["ssm_h"],
                layer_cache["ssm_conv"])
            new_cache.update(ssm_h=new_h, ssm_conv=new_conv)
            a_out = 0.5 * (a_out + s_out)
        if cfg.post_attn_norm:
            a_out = apply_norm(cfg, a_out, _idx(lp, "post_attn"))
        x = x + a_out
        h2 = apply_norm(cfg, x, _idx(lp, "ln2"))
        if cfg.n_experts:
            ff, _ = moe_mod.apply_moe(cfg, lp["moe"], h2,
                                      capacity_factor=2.0)
        else:
            ff = mlp_mod.apply_mlp(cfg, lp["mlp"], h2)
        if cfg.post_attn_norm:
            ff = apply_norm(cfg, ff, _idx(lp, "post_mlp"))
        return x + ff, new_cache

    layer_cache = {}
    if "kv" in cache:
        layer_cache["k"] = cache["kv"]["k"]
        layer_cache["v"] = cache["kv"]["v"]
        if quantized:
            layer_cache["k_scale"] = cache["kv"]["k_scale"]
            layer_cache["v_scale"] = cache["kv"]["v_scale"]
    if "ssm" in cache:
        layer_cache["ssm_h"] = cache["ssm"]["h"]
        layer_cache["ssm_conv"] = cache["ssm"]["conv"]

    xs = {"params": params["blocks"], "window": windows,
          "cache": layer_cache}
    if unroll:
        updates = []
        for li in range(cfg.n_layers):
            per = jax.tree_util.tree_map(lambda a: a[li], xs)
            x, upd = body(x, per)
            updates.append(upd)
        new_layer_cache = jax.tree_util.tree_map(
            lambda *us: jnp.stack(us), *updates)
    else:
        x, new_layer_cache = jax.lax.scan(body, x, xs)

    new_cache = {}
    if "kv" in cache:
        new_cache["kv"] = {"k": new_layer_cache["k"],
                           "v": new_layer_cache["v"]}
        if quantized:
            new_cache["kv"]["k_scale"] = new_layer_cache["k_scale"]
            new_cache["kv"]["v_scale"] = new_layer_cache["v_scale"]
    if "ssm" in cache:
        new_cache["ssm"] = {"h": new_layer_cache["ssm_h"],
                            "conv": new_layer_cache["ssm_conv"]}

    x = apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params, x)
    return logits, new_cache
