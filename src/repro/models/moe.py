"""Mixture-of-Experts layer: top-k routing, group-limited one-hot dispatch.

GShard-style dispatch adapted to the mesh: tokens are split into groups of
``group_size`` aligned with the activation sharding — group axes are
(batch, seq-block), so every group lives on one shard and dispatch needs
NO cross-device sort or gather (a distributed argsort dispatch measured
~8x worse collective time on the 16x16 dry-run; see EXPERIMENTS.md §Perf).

Within each group, capacity is C_g = group_size*top_k*factor/E and the
(Ng, E, C_g) one-hot dispatch/combine tensors stay small because C_g
shrinks with the group size.  Tokens over capacity are dropped (standard
GShard semantics; the residual carries them).  Switch-style load-balance
auxiliary loss regularizes the router.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.models.common import fan_in_init, gated_act
from repro.sharding.context import constrain, constrain_expert


def init_moe(cfg, key, dtype):
    L, d, E, ff = cfg.n_layers, cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    gated = cfg.activation in ("silu", "geglu")
    p = {"router": fan_in_init(ks[0], (L, d, E), dtype)}
    if gated:
        p["wg"] = fan_in_init(ks[1], (L, E, d, ff), dtype)
    p["wu"] = fan_in_init(ks[2], (L, E, d, ff), dtype)
    p["wd"] = fan_in_init(ks[3], (L, E, ff, d), dtype)
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * ff
        p["shared_wg"] = fan_in_init(ks[4], (L, d, sf), dtype)
        p["shared_wu"] = fan_in_init(
            jax.random.fold_in(ks[4], 1), (L, d, sf), dtype)
        p["shared_wd"] = fan_in_init(
            jax.random.fold_in(ks[4], 2), (L, sf, d), dtype)
    return p


def group_capacity(group_size: int, n_experts: int, top_k: int,
                   factor: float = 1.25) -> int:
    c = int(group_size * top_k * factor / n_experts)
    return max(4, -(-c // 4) * 4)


MOE_COMBINE_DTYPE = (jnp.bfloat16
                     if os.environ.get("REPRO_MOE_BF16_COMBINE") == "1"
                     else jnp.float32)          # §Perf knob


def apply_moe(cfg, lp, x, *, capacity_factor: float = None,
              group_size: int = 256):
    if capacity_factor is None:
        capacity_factor = float(os.environ.get("REPRO_MOE_CAPACITY",
                                               "1.25"))
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar f32)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    gs = min(group_size, S)
    pad = (-S) % gs
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    Sp = S + pad
    M = Sp // gs                       # seq blocks (aligned w/ model axis)
    xg = xp.reshape(B, M, gs, d)
    valid = jnp.ones((B, Sp), bool).at[:, S:].set(False) \
        .reshape(B, M, gs) if pad else jnp.ones((B, M, gs), bool)

    logits = jnp.einsum("bmnd,de->bmne", xg.astype(jnp.float32),
                        lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                # (B,M,gs,E)
    top_w, top_i = jax.lax.top_k(probs, K)                 # (B,M,gs,K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    Cg = group_capacity(gs, E, K, capacity_factor)

    counts = jnp.zeros((B, M, E), jnp.float32)
    dispatch = constrain(jnp.zeros((B, M, gs, E, Cg), x.dtype))
    combine = constrain(jnp.zeros((B, M, gs, E, Cg), MOE_COMBINE_DTYPE))
    for k in range(K):                                      # K <= 4: unrolled
        oh = jax.nn.one_hot(top_i[..., k], E, dtype=jnp.float32) \
            * valid[..., None]                              # (B,M,gs,E)
        pos = jnp.cumsum(oh, axis=2) - oh + counts[:, :, None, :]
        pos_tok = jnp.sum(pos * oh, axis=-1)                # (B,M,gs)
        keep = (pos_tok < Cg) & (jnp.sum(oh, -1) > 0)
        ohk = oh * keep[..., None]
        counts = counts + jnp.sum(ohk, axis=2)
        slot_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), Cg,
                                 dtype=jnp.float32) \
            * keep[..., None]                               # (B,M,gs,Cg)
        disp_k = ohk[..., None] * slot_oh[..., None, :]     # (B,M,gs,E,Cg)
        dispatch = dispatch + disp_k.astype(x.dtype)
        combine = combine + (disp_k
                             * top_w[..., k, None, None]
                             ).astype(MOE_COMBINE_DTYPE)

    # Switch load-balance loss over valid tokens
    nv = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
    f_e = jnp.sum(counts, axis=(0, 1)) / (nv * K / E)
    P_e = jnp.sum(probs * valid[..., None], axis=(0, 1, 2)) / nv
    aux = jnp.sum(f_e * P_e)

    xe = constrain_expert(jnp.einsum("bmnec,bmnd->bmecd", dispatch, xg),
                          last_is_ff=False)
    if "wg" in lp:
        gate = constrain_expert(
            jnp.einsum("bmecd,edf->bmecf", xe, lp["wg"]), last_is_ff=True)
        up = constrain_expert(
            jnp.einsum("bmecd,edf->bmecf", xe, lp["wu"]), last_is_ff=True)
        act = gated_act(cfg.activation, gate, up)
    else:
        act = constrain_expert(jax.nn.gelu(
            jnp.einsum("bmecd,edf->bmecf", xe, lp["wu"]), approximate=True),
            last_is_ff=True)
    ye = constrain_expert(jnp.einsum("bmecf,efd->bmecd", act, lp["wd"]),
                          last_is_ff=False)
    out = jnp.einsum("bmnec,bmecd->bmnd", combine.astype(x.dtype), ye)
    out = out.reshape(B, Sp, d)[:, :S]

    if "shared_wg" in lp:
        gate = jnp.einsum("bsd,df->bsf", x, lp["shared_wg"])
        up = jnp.einsum("bsd,df->bsf", x, lp["shared_wu"])
        out = out + jnp.einsum("bsf,fd->bsd",
                               gated_act("silu", gate, up), lp["shared_wd"])

    return out, aux
