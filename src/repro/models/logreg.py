"""The paper's own experiment models: (strongly-)convex logistic regression.

loss(w) = BCE(sigmoid(x·w + b), y) [+ lambda/2 ||w||^2 for strong convexity]
Matches §E.1 equations (32)/(strongly convex J-hat).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_params(d_features: int, key=None, dtype=jnp.float32):
    if key is None:
        key = jax.random.PRNGKey(0)
    w = 0.01 * jax.random.normal(key, (d_features,), jnp.float32)
    return {"w": w.astype(dtype), "b": jnp.zeros((), dtype)}


def predict_logits(params, x):
    return x @ params["w"] + params["b"]


def per_example_loss(params, x, y, l2: float = 0.0):
    """x: (d,), y: scalar in {0,1}."""
    z = x @ params["w"] + params["b"]
    # numerically stable BCE-with-logits
    loss = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    if l2 > 0.0:
        loss = loss + 0.5 * l2 * jnp.sum(jnp.square(params["w"]))
    return loss


def batch_loss(params, xb, yb, l2: float = 0.0):
    z = xb @ params["w"] + params["b"]
    losses = jnp.maximum(z, 0.0) - z * yb + jnp.log1p(jnp.exp(-jnp.abs(z)))
    loss = jnp.mean(losses)
    if l2 > 0.0:
        loss = loss + 0.5 * l2 * jnp.sum(jnp.square(params["w"]))
    return loss


def accuracy(params, xb, yb):
    pred = (predict_logits(params, xb) > 0).astype(jnp.float32)
    return jnp.mean((pred == yb).astype(jnp.float32))
