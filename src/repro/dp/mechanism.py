"""Gaussian DP mechanism: clipping + noise (Algorithm 1 lines 17, 23–24).

Granularities:
  * example — per-sample gradient clipping (paper-faithful / Abadi et al.):
    per-example grads via ``jax.vmap(jax.grad(...))``, each clipped to C,
    summed, then batch noise N(0, C²σ² I) added once per round.
  * client  — the client's whole round update U_c is clipped (user-level
    DP; the LLM-scale adaptation, see DESIGN.md §3).

The fused Pallas kernel for the example-level hot path lives in
``repro.kernels.dp_clip`` and is verified against :func:`clip_accumulate`.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def tree_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_tree(tree, clip_norm: float):
    scale = 1.0 / jnp.maximum(1.0, tree_norm(tree) / clip_norm)
    return jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree)


def add_gaussian_noise(tree, rng, stddev: float):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(flat))
    noised = [l + stddev * jax.random.normal(k, l.shape, l.dtype)
              for l, k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, noised)


def clip_accumulate(per_example_grads, clip_norm: float):
    """Clip each example's gradient tree to ``clip_norm`` and sum.

    per_example_grads: pytree with a leading example axis on every leaf.
    Pure-jnp oracle for the ``dp_clip`` Pallas kernel.
    """
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)),
                     axis=tuple(range(1, l.ndim)))
             for l in jax.tree_util.tree_leaves(per_example_grads))
    norms = jnp.sqrt(sq)                                   # (n_examples,)
    scales = 1.0 / jnp.maximum(1.0, norms / clip_norm)

    def scale_sum(l):
        s = scales.reshape((-1,) + (1,) * (l.ndim - 1))
        return jnp.sum(l.astype(jnp.float32) * s, axis=0)

    return jax.tree_util.tree_map(scale_sum, per_example_grads)


def dp_sgd_round(loss_fn: Callable, params, batch, *, clip_norm: float,
                 sigma: float, rng, microbatch: int = 0
                 ) -> Tuple[Any, jnp.ndarray]:
    """One DP round over a batch: per-example clip, sum, noise.

    loss_fn(params, example) -> scalar.  batch: pytree with leading axis N.
    Returns (U, mean_loss) with U distributed as the paper's round update.
    """
    def one(example):
        return jax.value_and_grad(loss_fn)(params, example)

    def run(examples):
        losses, grads = jax.vmap(lambda e: one(e))(examples)
        return losses, clip_accumulate(grads, clip_norm)

    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if microbatch and n % microbatch == 0 and n > microbatch:
        nm = n // microbatch
        reshaped = jax.tree_util.tree_map(
            lambda l: l.reshape((nm, microbatch) + l.shape[1:]), batch)

        def body(carry, mb):
            losses, U_mb = run(mb)
            U_tot, loss_tot = carry
            U_tot = jax.tree_util.tree_map(jnp.add, U_tot, U_mb)
            return (U_tot, loss_tot + jnp.sum(losses)), None

        zero = jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), params)
        (U, loss_sum), _ = jax.lax.scan(body, (zero, jnp.float32(0.0)),
                                        reshaped)
        mean_loss = loss_sum / n
    else:
        losses, U = run(batch)
        mean_loss = jnp.mean(losses)

    U = add_gaussian_noise(U, rng, clip_norm * sigma)
    return U, mean_loss
