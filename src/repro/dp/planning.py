"""DP planning for FL runs: derive (sizes, round sigmas, T) from a budget.

Bridges the Theorem-4 accountant to FLConfig — given a grad budget K,
privacy target (epsilon, delta), and the client data-set size, returns a
ready-to-run FLConfig with the increasing sample-size sequence and the
per-round sigma, plus the constant-sequence comparison the paper makes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.configs.base import (DPConfig, FLConfig, SampleSequenceConfig,
                                StepSizeConfig)
from repro.dp.accountant import (SelectedParameters, privacy_budget_B,
                                 select_parameters)


def plan_dp_fl(*, n_clients: int, N_c: int, K: int, epsilon: float,
               sigma: float, s0c: int = 16, p: float = 1.0,
               clip_norm: float = 0.1, r0: Optional[float] = 1 / math.e,
               eta0: float = 0.15, beta: float = 0.001,
               granularity: str = "example") -> tuple:
    """Returns (FLConfig, SelectedParameters)."""
    sel = select_parameters(s0c=s0c, N_c=N_c, p=p, epsilon=epsilon,
                            sigma=sigma, K=K, r0=r0)
    fl = FLConfig(
        n_clients=n_clients,
        sample_seq=SampleSequenceConfig(kind="power", s0=s0c, p=p,
                                        q=sel.q, m=sel.m, N_c=N_c),
        step_size=StepSizeConfig(kind="inv_t", eta0=eta0, beta=beta,
                                 round_transform=True),
        dp=DPConfig(enabled=True, clip_norm=clip_norm, sigma=sel.sigma,
                    granularity=granularity, delta=sel.delta,
                    epsilon=epsilon),
        total_grads=K,
    )
    return fl, sel


def compare_constant(sel: SelectedParameters) -> dict:
    """The paper's constant-sequence comparison at equal privacy."""
    return {
        "rounds": {"increasing": sel.T, "constant": sel.T_constant,
                   "reduction": sel.round_reduction},
        "aggregated_noise": {
            "increasing": sel.aggregated_noise,
            "constant": sel.aggregated_noise_constant,
            "reduction": sel.aggregated_noise_constant
            / max(sel.aggregated_noise, 1e-9)},
        "budget_B": sel.budget_B,
        "delta": sel.delta,
    }
