"""Differential-privacy accountant — Theorems 3, 4, 6 of the paper.

The paper generalizes the moments accountant of Abadi et al. (2016) to
*increasing* sample-size sequences q_i = s_{i,c}/N_c = q (i+m)^p and makes
the constants explicit.  This module implements:

  * ``r_from_r0``          — equation (16): r(r0, σ)
  * ``r0_sigma``           — the fixed-point iteration for r0(σ) (D.3.1)
  * ``Theorem4Constants``  — A, B, D, K−, K+, K*, ρ, ρ̂ (γ/α-corrected,
                              i.e. the full Theorem 6 forms)
  * ``sigma_lower_bound``  — case-1 and case-2 σ bounds
  * ``select_parameters``  — the iterative parameter-selection procedure of
                              §3 / D.3.2 (reproduces Examples 1–5)
  * ``moments_epsilon``    — a *numerical* accountant from Lemma 4's explicit
                              moment bound: works for arbitrary {s_i}, used
                              to cross-check the closed forms.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

E = math.e
SQRT3M1_HALF = (math.sqrt(3.0) - 1.0) / 2.0


# ---------------------------------------------------------------------------
# r(r0, sigma) — equation (16)
# ---------------------------------------------------------------------------

def u0_u1(r0: float, sigma: float):
    if not 0.0 < r0 < sigma:
        # With r0 >= sigma the denominator sigma - r0 flips sign, u0/u1 go
        # negative, the < 1 guard in r_from_r0 passes vacuously, and a
        # finite but meaningless r leaks into Theorem4Constants /
        # select_parameters.  Equation (16) is only defined on 0 < r0 < σ.
        raise ValueError(
            f"equation (16) requires 0 < r0 < sigma; got r0={r0}, "
            f"sigma={sigma}")
    root = math.sqrt(r0 * sigma)
    u0 = 2.0 * root / (sigma - r0)
    u1 = 2.0 * E * root / ((sigma - r0) * sigma)
    return u0, u1


def r_from_r0(r0: float, sigma: float) -> float:
    u0, u1 = u0_u1(r0, sigma)
    if u0 >= 1.0 or u1 >= 1.0:
        raise ValueError(f"u0={u0:.4f}, u1={u1:.4f} must be < 1 "
                         f"(sigma too small for r0={r0})")
    return r0 * 8.0 * (1.0 / (1.0 - u0)
                       + (1.0 / (1.0 - u1)) * E ** 3 / sigma ** 3) \
        * math.exp(3.0 / sigma ** 2)


def r0_sigma(sigma: float, p: float = 1.0, *, tol: float = 1e-12,
             max_iter: int = 200) -> float:
    """Fixed point r0(σ) from D.3.1 (requires σ >= 1.137).

    Solves  r(r0, σ) = (√3−1)/2 · (3p+1)/((p+1)(2p+1)) · (1 − r0/σ)².
    """
    if sigma < 1.137:
        raise ValueError("r0(sigma) iteration requires sigma >= 1.137")
    target_coef = SQRT3M1_HALF * (3 * p + 1) / ((p + 1) * (2 * p + 1))
    r0 = 0.0
    for _ in range(max_iter):
        num = target_coef * (1.0 - r0 / sigma) ** 2
        u0, u1 = u0_u1(r0, sigma) if r0 > 0 else (0.0, 0.0)
        den = 8.0 * (1.0 / (1.0 - u0)
                     + (1.0 / (1.0 - u1)) * E ** 3 / sigma ** 3) \
            * math.exp(3.0 / sigma ** 2)
        new = num / den
        if abs(new - r0) < tol:
            return new
        r0 = new
    return r0


# ---------------------------------------------------------------------------
# Theorem 6 constants (γ, α corrected)
# ---------------------------------------------------------------------------

@dataclass
class Theorem4Constants:
    p: float
    r0: float
    sigma: float
    gamma: float = 0.0       # m/T
    alpha: Optional[float] = None

    def __post_init__(self):
        if self.alpha is None:
            self.alpha = self.r0 / self.sigma
        self.r = r_from_r0(self.r0, self.sigma)
        p, g, a = self.p, self.gamma, self.alpha
        self.rho = ((2 * p + 1) ** 2 / ((p + 1) * (3 * p + 1))
                    * (1 + g) ** (2 + 4 * p) / (1 - a) ** 2)
        self.rho_hat = (2 * p + 1) / (p + 1) ** 2 * (1 + g) ** (2 + 2 * p)
        rr = self.r * self.rho
        # equation (24): threshold τ on c1
        self.tau = (((2 * rr / self.rho_hat + 1.0) ** 2 - 1.0)
                    / (2.0 * rr))

    # -- A, B, D coefficients ------------------------------------------------
    @property
    def A(self) -> float:
        p, g = self.p, self.gamma
        return ((p + 1) ** (1.0 / (1 + 2 * p))
                / (1.0 / (self.r * self.rho)) ** ((1 + p) / (1 + 2 * p))
                * (1 + g) ** (1 + p))

    @property
    def B(self) -> float:
        p, g = self.p, self.gamma
        return ((1 + g) ** (-2.0 * (1 + p) ** 2 / (1 + 2 * p))
                * (p + 1) ** (1.0 / (1 + 2 * p))
                / self.tau ** ((1 + p) / (1 + 2 * p)))

    @property
    def D(self) -> float:
        p, g = self.p, self.gamma
        if p <= 0:
            return math.inf
        return ((self.r0 / self.sigma) ** ((1 + p) / p) / (p + 1)
                * (1 + g) ** (1 + p))

    # -- thresholds ------------------------------------------------------------
    def K_minus(self, epsilon: float, q: float, N_c: int) -> float:
        p = self.p
        return (self.B * epsilon ** ((1 + p) / (1 + 2 * p))
                * q ** (-1.0 / (1 + 2 * p)) * N_c)

    def K_plus(self, epsilon: float, q: float, N_c: int) -> float:
        p = self.p
        return (self.A * epsilon ** ((1 + p) / (1 + 2 * p))
                * q ** (-1.0 / (1 + 2 * p)) * N_c)

    def K_star(self, q: float, N_c: int) -> float:
        if self.p <= 0:
            return math.inf
        return self.D * q ** (-1.0 / self.p) * N_c


def theorem4_simple_B(p: float) -> float:
    """Theorem 4's headline B = (1/(1+p)) ((√3−1)/2 (2p+1))^{(1+p)/(1+2p)}
    (the r0(σ) fixed-point value, γ = 0)."""
    return (1.0 / (1 + p)) * (SQRT3M1_HALF * (2 * p + 1)) \
        ** ((1 + p) / (1 + 2 * p))


# ---------------------------------------------------------------------------
# σ lower bounds
# ---------------------------------------------------------------------------

def privacy_budget_B(epsilon: float, delta: float) -> float:
    return math.sqrt(2.0 * math.log(1.0 / delta) / epsilon)


def delta_from_budget(B: float, epsilon: float) -> float:
    return math.exp(-B * B * epsilon / 2.0)


def sigma_lower_bound_case1(epsilon: float, delta: float, *, p: float,
                            r0: float, sigma: float,
                            gamma: float = 0.0) -> float:
    """Case 1 (K <= K−): σ ≥ √(2 ln(1/δ)/ε) (1+γ)^{2+3p} / √(1 − r0/σ)."""
    return (privacy_budget_B(epsilon, delta)
            * (1 + gamma) ** (2 + 3 * p)
            / math.sqrt(1.0 - r0 / sigma))


def sigma_lower_bound_case2(epsilon: float, delta: float, *, p: float,
                            r0: float, sigma: float, K: float, K_plus: float,
                            gamma: float = 0.0) -> float:
    """Case 2 (K >= K+): the 1.21 · (K/K+)^{(1+2p)/(2+2p)} bound (eq 19)."""
    return ((K / K_plus) ** ((1 + 2 * p) / (2 + 2 * p)) * 1.21
            * privacy_budget_B(epsilon, delta)
            * (1 + gamma) ** (2 + 3 * p)
            / math.sqrt(1.0 - r0 / sigma))


# ---------------------------------------------------------------------------
# Parameter-selection procedure (§3 "Parameter selection", D.3.2)
# ---------------------------------------------------------------------------

@dataclass
class SelectedParameters:
    q: float
    m: float
    T: int
    gamma: float
    sigma: float
    r0: float
    epsilon: float
    delta: float
    budget_B: float
    K: int
    sizes: List[int]
    T_constant: int
    round_reduction: float
    aggregated_noise: float           # sqrt(T) * sigma
    aggregated_noise_constant: float  # sqrt(T_const) * B  (fair comparison)
    binding: str                      # which constraint bound q

    def summary(self) -> str:
        return (f"q={self.q:.3e} m={self.m:.2f} T={self.T} "
                f"gamma={self.gamma:.4f} sigma={self.sigma} "
                f"B={self.budget_B:.3f} delta={self.delta:.3e} "
                f"rounds {self.T_constant}->{self.T} "
                f"(x{self.round_reduction:.2f} fewer), noise "
                f"{self.aggregated_noise_constant:.0f}->"
                f"{self.aggregated_noise:.0f}")


def select_parameters(*, s0c: int, N_c: int, p: float, epsilon: float,
                      sigma: float, K: int, r0: Optional[float] = None,
                      n_gamma_iters: int = 6) -> SelectedParameters:
    """Case-1 selection: choose q ≤ min(q(K−), q(K*)), derive m, T, γ,
    iterate γ to a fixed point, then read off the achievable budget B/δ.

    ``r0=None`` uses the r0(σ) fixed point; Examples 3/5 of the paper use
    r0 = 1/e to relax the K* constraint — pass r0=1/e to reproduce them.
    """
    r0v = r0_sigma(sigma, p) if r0 is None else r0
    gamma = 0.0
    q = m = T = None
    binding = "?"
    for _ in range(n_gamma_iters):
        consts = Theorem4Constants(p=p, r0=r0v, sigma=sigma, gamma=gamma)
        # q small enough that K <= K−  =>  q <= (B ε^{(1+p)/(1+2p)} N_c/K)^{1+2p}
        q_kminus = (consts.B * epsilon ** ((1 + p) / (1 + 2 * p))
                    * N_c / K) ** (1 + 2 * p)
        # q small enough that K <= K*  =>  q <= (D N_c / K)^{p}
        q_kstar = (consts.D * N_c / K) ** p if p > 0 else math.inf
        if q_kminus <= q_kstar:
            q, binding = q_kminus, "K-"
        else:
            q, binding = q_kstar, "K*"
        m = (s0c / (N_c * q)) ** (1.0 / p) if p > 0 else 0.0
        s = N_c * q * (m ** p) if p > 0 else s0c   # = s0c by construction
        T = ((p + 1) * K / (N_c * q)) ** (1.0 / (1 + p))
        new_gamma = m / T
        if abs(new_gamma - gamma) < 1e-9:
            gamma = new_gamma
            break
        gamma = new_gamma

    T_int = int(round(T))
    bound_factor = (1 + gamma) ** (2 + 3 * p) / math.sqrt(1.0 - r0v / sigma)
    budget_B = sigma / bound_factor
    delta = delta_from_budget(budget_B, epsilon)

    sizes = [int(math.ceil(N_c * q * (i + m) ** p)) for i in range(T_int)]
    T_const = int(math.ceil(K / s0c))
    return SelectedParameters(
        q=q, m=m, T=T_int, gamma=gamma, sigma=sigma, r0=r0v,
        epsilon=epsilon, delta=delta, budget_B=budget_B, K=K, sizes=sizes,
        T_constant=T_const,
        round_reduction=T_const / max(T_int, 1),
        aggregated_noise=math.sqrt(T_int) * sigma,
        aggregated_noise_constant=math.sqrt(T_const) * budget_B,
        binding=binding)


# ---------------------------------------------------------------------------
# Numerical moments accountant (Lemma 4, explicit constants)
# ---------------------------------------------------------------------------

def moments_delta(sizes: Sequence[int], N_c: int, sigma: float,
                  epsilon: float, *, r0: Optional[float] = None,
                  lambda_max: int = 256) -> float:
    """δ = min_λ exp(Σ_i α_i(λ) − λ ε) using Lemma 4's bound

        α_i(λ) ≤ s²λ(λ+1)/(N(N−s)σ²) + (r/r0)·s³λ²(λ+1)/(N(N−s)²σ³).

    λ is capped by the lemma's validity condition λ ≤ σ² ln(N/(s σ)).
    """
    if r0 is None:
        r0 = max(s / N_c for s in sizes) * sigma
        r0 = min(max(r0, 1e-6), 1.0 / E)
    r = r_from_r0(r0, sigma)
    best = math.inf
    for lam in range(1, lambda_max + 1):
        ok = True
        total = 0.0
        for s in sizes:
            s = min(s, N_c - 1)
            if lam > sigma ** 2 * math.log(max(N_c / (s * sigma), E)):
                ok = False
                break
            t1 = s * s * lam * (lam + 1) / (N_c * (N_c - s) * sigma ** 2)
            t2 = (r / r0) * s ** 3 * lam ** 2 * (lam + 1) \
                / (N_c * (N_c - s) ** 2 * sigma ** 3)
            total += t1 + t2
        if not ok:
            break
        best = min(best, total - lam * epsilon)
    return math.exp(best) if best < math.inf else 1.0


def moments_epsilon(sizes: Sequence[int], N_c: int, sigma: float,
                    delta: float, *, r0: Optional[float] = None,
                    tol: float = 1e-4) -> float:
    """Smallest ε with moments_delta(...) <= δ (bisection)."""
    lo, hi = 1e-4, 200.0
    if moments_delta(sizes, N_c, sigma, hi, r0=r0) > delta:
        return math.inf
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if moments_delta(sizes, N_c, sigma, mid, r0=r0) <= delta:
            hi = mid
        else:
            lo = mid
        if hi - lo < tol:
            break
    return hi


# ---------------------------------------------------------------------------
# Per-client accounting (telemetry)
# ---------------------------------------------------------------------------

def per_client_accounting(sizes_rows: Sequence[Sequence[int]], N_c: int,
                          sigma: float, delta: float, *,
                          r0: Optional[float] = None
                          ) -> List[dict]:
    """Per-client (ε, σ, rounds-contributed) rows for a MetricsReport.

    ``sizes_rows[c]`` is the sequence of sample sizes client c *actually
    sent* (its participation record, not the planned schedule) — in the
    paper's local-DP regime each client's privacy spend depends only on
    its own mechanism invocations, so the moments accountant runs per
    client over that row.  Identical rows share one bisection via a
    cache, so fleets with a common schedule cost a single accountant
    pass.  An infinite ε (σ too small for δ at this N_c) is reported as
    ``None`` so the rows stay JSON-serializable.
    """
    cache: dict = {}
    rows: List[dict] = []
    for c, sizes in enumerate(sizes_rows):
        key = tuple(int(s) for s in sizes)
        if key not in cache:
            if not key or sigma <= 0:
                eps = 0.0 if not key else math.inf
            else:
                try:
                    eps = moments_epsilon(list(key), N_c, sigma, delta,
                                          r0=r0)
                except ValueError:
                    # sigma below Lemma 4's validity regime (u0/u1 >= 1):
                    # no finite moments bound — report as unbounded
                    eps = math.inf
            cache[key] = eps
        eps = cache[key]
        rows.append({
            "client": c,
            "rounds_contributed": len(key),
            "samples": int(sum(key)),
            "sigma": float(sigma),
            "delta": float(delta),
            "epsilon": None if math.isinf(eps) else float(eps),
        })
    return rows
