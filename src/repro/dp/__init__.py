from repro.dp.accountant import (SelectedParameters, Theorem4Constants,
                                 delta_from_budget, moments_delta,
                                 moments_epsilon, per_client_accounting,
                                 privacy_budget_B,
                                 r0_sigma, r_from_r0, select_parameters,
                                 sigma_lower_bound_case1,
                                 sigma_lower_bound_case2, theorem4_simple_B)
from repro.dp.mechanism import (add_gaussian_noise, clip_accumulate,
                                clip_tree, dp_sgd_round, tree_norm)

__all__ = [
    "SelectedParameters", "Theorem4Constants", "delta_from_budget",
    "moments_delta", "moments_epsilon", "per_client_accounting",
    "privacy_budget_B", "r0_sigma",
    "r_from_r0", "select_parameters", "sigma_lower_bound_case1",
    "sigma_lower_bound_case2", "theorem4_simple_B",
    "add_gaussian_noise", "clip_accumulate", "clip_tree", "dp_sgd_round",
    "tree_norm",
]
from repro.dp.planning import compare_constant, plan_dp_fl  # noqa: E402
__all__ += ["compare_constant", "plan_dp_fl"]
