"""Pluggable server-side aggregation strategies — the async-aggregation
zoo (ROADMAP: "an async-aggregation zoo under one protocol interface").

The paper's server (Algorithm 3) applies every arriving update on
dequeue and gates clients behind the round-completion wait gate.  The
async-FL literature defines a family around that point in design space:

  * ``PaperStrategy`` (default) — apply-on-dequeue, weight 1.  Keeps the
    repo's golden trajectories and three-way parity bit-exact.
  * ``FedAsyncStrategy`` — staleness-decayed alpha-mixing (Xie et al.,
    FedAsync; the FLGo ``fedasync`` server): an update sent against
    broadcast counter ``k_send`` and applied at server counter ``k`` is
    weighted ``alpha * s(tau)`` with ``tau = k - k_send`` and ``s`` one
    of ``constant`` / ``hinge`` / ``poly``.
  * ``FedBuffStrategy`` — buffered aggregation (Nguyen et al., FedBuff):
    arriving updates accumulate in a server-side buffer applied to the
    model only every ``buffer_size`` updates.

Everything EXCEPT the application of arriving update vectors to the
server model is strategy-invariant: the H-set bookkeeping, the
broadcast cascade, the wait gate, latency draws, availability, and the
telemetry census are identical across strategies, so a zoo run across
strategies under one seed sees the exact same message schedule — the
convergence differences in ``BENCH_cohort.json``'s aggregation-zoo grid
are attributable to the aggregation rule alone.

Engine contract (the reason this module is jit-compatible):

  * ``weight(tau)`` is the Python-float path the event simulator uses
    per message.
  * ``decay_weights(tau)`` is the jnp path: a ``[R]`` traced-int32
    staleness vector (one entry per sender-k ring slot) mapped to
    ``[R]`` float32 weights.  The host and device cohort engines
    evaluate the SAME expression on the same operands, which is what
    keeps host-vs-device bitwise parity on every strategy.
  * ``fingerprint()`` keys the device engine's compiled-segment cache.

Strategy hyperparameters are Python constants baked into the jitted
segment at trace time; the mutable strategy *buffers* (the sender-k
stratified rings, the FedBuff accumulator) are ``DeviceCohortState``
fields, covered by ``repro.sharding.cohort_pspecs`` and enforced by the
STRUCT-* analysis pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax.numpy as jnp


def ring_decay(strategy, server_k, R: int):
    """[R] decay weights for the sender-k ring strata at server counter
    ``server_k``: stratum r holds updates sent against broadcast counter
    ``r (mod R)``, so its staleness is ``(server_k - r) mod R`` — exact
    because the wait gate bounds true staleness by d - 1 < R.

    This is THE apply-time decay expression of the stratified engines:
    the host engine's ``_make_strat_apply`` and the device tick (where
    the weights feed the fused bucket-apply kernel as an operand) both
    call it, which is what keeps host-vs-device bitwise on every
    strategy.
    """
    tau = (server_k - jnp.arange(R, dtype=jnp.int32)) & (R - 1)
    return strategy.decay_weights(tau)


class AggregationStrategy:
    """Base class AND the paper's default apply-on-dequeue rule."""

    #: strategy id, used in fingerprints / benchmark rows
    kind: str = "paper"
    #: engines bucket update vectors per sender-k and decay at apply time
    stratified: bool = False
    #: engines accumulate applied vectors and flush every buffer_size
    buffered: bool = False

    def weight(self, tau: int) -> float:
        """Decay weight for one update applied at staleness ``tau``
        (event-simulator path, Python floats)."""
        return 1.0

    def decay_weights(self, tau):
        """[R] traced int32 staleness -> [R] f32 weights (cohort-engine
        path).  Host and device evaluate this same expression — parity."""
        return jnp.ones(tau.shape, jnp.float32)

    def fingerprint(self) -> Tuple[Any, ...]:
        """Hashable identity for the compiled-segment cache."""
        return (self.kind,)

    def __repr__(self) -> str:
        return f"{type(self).__name__}{self.fingerprint()[1:]}"


PaperStrategy = AggregationStrategy

#: FedAsync decay families (FLGo's fedasync server option vocabulary)
FEDASYNC_DECAYS = ("constant", "hinge", "poly")


@dataclass(frozen=True, repr=False)
class FedAsyncStrategy(AggregationStrategy):
    """Staleness-decayed alpha-mixing: apply ``alpha * s(tau) * eta * U``.

    ``s(tau)`` per ``decay`` (FLGo defaults):
      constant  s = 1
      hinge     s = 1 if tau <= hinge_b else 1 / (hinge_a*(tau-hinge_b)+1)
      poly      s = (tau + 1) ** -poly_a
    """
    alpha: float = 0.6
    decay: str = "poly"
    hinge_a: float = 10.0
    hinge_b: int = 6
    poly_a: float = 0.5

    kind = "fedasync"
    stratified = True

    def __post_init__(self):
        if self.decay not in FEDASYNC_DECAYS:
            raise ValueError(f"FedAsync decay {self.decay!r} not in "
                             f"{FEDASYNC_DECAYS}")

    def weight(self, tau: int) -> float:
        t = float(max(tau, 0))
        if self.decay == "constant":
            s = 1.0
        elif self.decay == "hinge":
            s = (1.0 if t <= self.hinge_b
                 else 1.0 / (self.hinge_a * (t - self.hinge_b) + 1.0))
        else:
            s = (t + 1.0) ** (-self.poly_a)
        return self.alpha * s

    def decay_weights(self, tau):
        tf = tau.astype(jnp.float32)
        alpha = jnp.float32(self.alpha)
        if self.decay == "constant":
            return jnp.full(tau.shape, alpha, jnp.float32)
        if self.decay == "hinge":
            a = jnp.float32(self.hinge_a)
            b = jnp.float32(self.hinge_b)
            return jnp.where(tf <= b, alpha,
                             alpha / (a * (tf - b) + 1.0))
        return alpha * jnp.power(tf + 1.0, -jnp.float32(self.poly_a))

    def fingerprint(self) -> Tuple[Any, ...]:
        return ("fedasync", self.alpha, self.decay, self.hinge_a,
                self.hinge_b, self.poly_a)


@dataclass(frozen=True, repr=False)
class FedBuffStrategy(AggregationStrategy):
    """Buffered aggregation: ``v -= buffer`` every ``buffer_size``
    arriving updates (instead of on every dequeue).  A partial buffer at
    run end is dropped, as in FedBuff."""
    buffer_size: int = 4

    kind = "fedbuff"
    buffered = True

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError("FedBuff buffer_size must be >= 1")

    def fingerprint(self) -> Tuple[Any, ...]:
        return ("fedbuff", self.buffer_size)


_BY_KIND = {"paper": PaperStrategy, "fedasync": FedAsyncStrategy,
            "fedbuff": FedBuffStrategy}


def get_strategy(spec=None) -> AggregationStrategy:
    """Resolve ``None`` | kind name | ``{"kind": ..., **hparams}`` |
    strategy instance to an ``AggregationStrategy``."""
    if spec is None:
        return PaperStrategy()
    if isinstance(spec, AggregationStrategy):
        return spec
    if isinstance(spec, str):
        kind, spec = spec, {}
    elif isinstance(spec, dict):
        spec = dict(spec)
        kind = spec.pop("kind", "paper")
    else:
        raise TypeError(f"cannot resolve aggregation strategy from "
                        f"{spec!r} (want None, a kind name, a dict, or "
                        f"an AggregationStrategy)")
    cls = _BY_KIND.get(kind)
    if cls is None:
        raise ValueError(f"unknown aggregation strategy {kind!r} "
                         f"(want one of {sorted(_BY_KIND)})")
    return cls(**spec)
