"""Client and server state machines — Algorithms 1–4 of the paper.

Transport-agnostic: the discrete-event simulator (``repro.core.simulator``)
or a real RPC layer delivers messages.  The computational payload is a
``Task`` (``repro.core.tasks``) so the same protocol drives the paper's
logistic-regression experiments and LLM-scale rounds.

Faithfulness notes:
* Server (Algorithm 3): applies U on dequeue (``v ← v − η̄_i U``), tracks
  received (i, c) pairs in H, broadcasts (v, k) once round k is complete
  from all clients, then increments k.
* Client (Algorithm 4 + DP lines 17/23/24 of Algorithm 1): runs s_{i,c}
  local SGD iterations per round, accumulates U, optionally clips per
  sample and adds batch Gaussian noise; ISRRECEIVE replaces the local
  model with v̂ − η̄_i · U (fresher global model minus own unaccounted
  current-round updates).
* Wait gate (Supp. B.2): the τ(t_glob) ≤ t_delay loop is replaced by the
  equivalent gate "block while i == k + d" once condition (3) holds.

The server's *application rule* — and only that — is pluggable: an
``AggregationStrategy`` (``repro.core.strategies``) selects the paper's
apply-on-dequeue default, FedAsync staleness-decayed mixing, or FedBuff
buffered aggregation.  H bookkeeping, the broadcast cascade, and the
wait gate are strategy-invariant, so every strategy sees the same
message schedule under a given seed.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

import jax

from repro.core.strategies import get_strategy


@dataclass
class UpdateMsg:
    round_idx: int
    client_id: int
    U: Any                      # pytree: sum of (clipped, noised) gradients
    k_send: int = 0             # sender's broadcast counter k at send time


@dataclass
class BroadcastMsg:
    v: Any                      # global model pytree
    k: int                      # completed-round counter


# ---------------------------------------------------------------------------
# Server — Algorithm 3
# ---------------------------------------------------------------------------

class Server:
    def __init__(self, v0, n_clients: int, round_stepsizes: Sequence[float],
                 strategy=None):
        self.v = v0
        self.n_clients = n_clients
        self.eta_bar = list(round_stepsizes)
        self.k = 0
        self.H: set = set()
        self.processed: List[Tuple[int, int]] = []   # audit log
        self.strategy = get_strategy(strategy)
        self._buf: Optional[Any] = None    # FedBuff accumulator pytree
        self._buf_n = 0                    # updates buffered since flush

    def eta(self, i: int) -> float:
        return self.eta_bar[min(i, len(self.eta_bar) - 1)]

    def receive(self, msg: UpdateMsg) -> List[BroadcastMsg]:
        """Process one queued client update; emit every broadcast now due.

        Under message reordering a round k+1 update can arrive before the
        last round-k update, so a single dequeue may complete *several*
        consecutive rounds at once.  Algorithm 3's check is therefore a
        cascade: fire round k, increment k, re-check with the already
        banked (k+1, c) pairs, and so on.  Firing at most one broadcast
        per dequeue would silently drop the k+1 broadcast and deadlock
        every client blocked on the wait gate (Supp. B.2).
        """
        eta = self.eta(msg.round_idx)
        strat = self.strategy
        if strat.buffered:
            # FedBuff: bank eta-weighted updates, flush every B arrivals
            contrib = jax.tree_util.tree_map(lambda u: eta * u, msg.U)
            self._buf = contrib if self._buf is None \
                else jax.tree_util.tree_map(
                    lambda b, c: b + c, self._buf, contrib)
            self._buf_n += 1
            if self._buf_n >= strat.buffer_size:
                self.v = jax.tree_util.tree_map(
                    lambda v, b: v - b, self.v, self._buf)
                self._buf, self._buf_n = None, 0
        elif strat.stratified:
            # FedAsync: staleness-decayed mixing against the pre-cascade k
            scale = eta * strat.weight(self.k - msg.k_send)
            self.v = jax.tree_util.tree_map(
                lambda v, u: v - scale * u, self.v, msg.U)
        else:
            # paper Algorithm 3: apply on dequeue, weight 1
            self.v = jax.tree_util.tree_map(
                lambda v, u: v - eta * u, self.v, msg.U)
        self.H.add((msg.round_idx, msg.client_id))
        self.processed.append((msg.round_idx, msg.client_id))
        fired: List[BroadcastMsg] = []
        while all((self.k, c) in self.H for c in range(self.n_clients)):
            for c in range(self.n_clients):
                self.H.discard((self.k, c))
            self.k += 1
            fired.append(BroadcastMsg(v=self.v, k=self.k))
        return fired


# ---------------------------------------------------------------------------
# Client — Algorithm 4 (+ Algorithm 1 DP lines)
# ---------------------------------------------------------------------------

class Client:
    def __init__(self, client_id: int, w0, task, sizes: Sequence[int],
                 round_stepsizes: Sequence[float], d: int, seed: int):
        self.id = client_id
        self.task = task
        self.w = w0
        self.U = task.zero_update()
        self.sizes = list(sizes)               # s_{i,c}
        self.eta_bar = list(round_stepsizes)
        self.d = d
        self.i = 0                             # current round
        self.h = 0                             # iterations done in round i
        self.k = 0                             # latest broadcast counter seen
        self.rng = jax.random.PRNGKey(seed)
        self.sent_rounds: List[int] = []
        # diagnostics for Theorem 1's invariant t_delay <= tau(t_glob)
        self.delay_trace: List[Tuple[int, int]] = []

    # -- protocol --------------------------------------------------------
    def eta(self, i: int) -> float:
        return self.eta_bar[min(i, len(self.eta_bar) - 1)]

    def s(self, i: int) -> int:
        return self.sizes[min(i, len(self.sizes) - 1)]

    @property
    def blocked(self) -> bool:
        """Wait gate: block while i == k + d (Supp. B.2)."""
        return self.i >= self.k + self.d

    def remaining_in_round(self) -> int:
        return self.s(self.i) - self.h

    def run(self, n_iters: int) -> None:
        """Advance n local SGD iterations (n <= remaining_in_round)."""
        assert not self.blocked and n_iters <= self.remaining_in_round()
        self.rng, sub = jax.random.split(self.rng)
        self.w, self.U = self.task.run_iterations(
            self.w, self.U, round_idx=self.i, client_id=self.id,
            start_h=self.h, n_iters=n_iters, eta=self.eta(self.i), rng=sub)
        self.h += n_iters

    def finish_round(self) -> UpdateMsg:
        """Round complete: draw DP batch noise, send (i, c, U), advance."""
        assert self.h == self.s(self.i)
        self.rng, sub = jax.random.split(self.rng)
        self.w, self.U = self.task.add_round_noise(
            self.w, self.U, eta=self.eta(self.i), rng=sub)
        msg = UpdateMsg(round_idx=self.i, client_id=self.id, U=self.U,
                        k_send=self.k)
        self.sent_rounds.append(self.i)
        self.i += 1
        self.h = 0
        self.U = self.task.zero_update()
        return msg

    def isr_receive(self, msg: BroadcastMsg) -> None:
        """Algorithm 4 ISRRECEIVE: accept only fresher global models."""
        if msg.k > self.k:
            self.k = msg.k
            eta = self.eta(self.i)
            self.w = jax.tree_util.tree_map(
                lambda v, u: v - eta * u, msg.v, self.U)

    # -- Theorem 1 bookkeeping --------------------------------------------
    def record_delay(self, global_sizes: Sequence[int]) -> Tuple[int, int]:
        """(t_glob, t_delay) at the current iteration (paper lines 12-13)."""
        s = global_sizes
        cum = 0
        for j in range(min(self.i + 1, len(s))):
            cum += s[j]
        t_glob = cum - (self.s(self.i) - self.h) - 1
        t_delay = sum(s[j] for j in range(self.k, min(self.i + 1, len(s)))) \
            - (self.s(self.i) - self.h)
        self.delay_trace.append((t_glob, t_delay))
        return t_glob, t_delay
