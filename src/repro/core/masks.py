"""General-recursion masks (Supp. C.1, recursion (9), D > 1).

Each client applies a diagonal 0/1 "filter" S_u^ξ to its gradient: the
model coordinates are partitioned into D near-equal groups; per iteration
one group u is drawn uniformly and only those coordinates are computed,
updated, and TRANSMITTED — cutting per-round communication by ~D at the
cost of gradient sparsification.  The correction factor d_ξ = D keeps the
update unbiased: d_ξ E[S_u^ξ | ξ] = D_ξ (equation (10)).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp


def make_partition(params_template, D: int, *, seed: int = 0):
    """Partition the flattened coordinate space into D near-equal groups.

    Returns a pytree of int32 leaves with values in [0, D) — the group id
    of every coordinate.
    """
    key = jax.random.PRNGKey(seed)
    leaves, treedef = jax.tree_util.tree_flatten(params_template)
    out = []
    for idx, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, idx)
        # random balanced assignment: shuffle repeated 0..D-1 pattern
        n = leaf.size
        base = jnp.tile(jnp.arange(D, dtype=jnp.int32), (n + D - 1) // D)[:n]
        perm = jax.random.permutation(k, n)
        out.append(base[perm].reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def mask_for_group(partition, u: int):
    """Boolean mask pytree selecting group u."""
    return jax.tree_util.tree_map(lambda g: g == u, partition)


def apply_masked_update(grad, partition, u: int, D: int):
    """d_ξ · S_u^ξ ∇f  — the masked, unbiasedness-corrected update."""
    return jax.tree_util.tree_map(
        lambda g, part: jnp.where(part == u, D * g.astype(jnp.float32),
                                  0.0).astype(g.dtype),
        grad, partition)


def masked_update_nbytes(update, partition, u: int) -> int:
    """Bytes a client actually transmits (masked coordinates only)."""
    total = 0
    for g, part in zip(jax.tree_util.tree_leaves(update),
                       jax.tree_util.tree_leaves(partition)):
        total += int(jnp.sum(part == u)) * g.dtype.itemsize
    return total


def expectation_check(grad, partition, D: int):
    """E_u[d S_u g] over the uniform u — should equal g exactly."""
    acc = jax.tree_util.tree_map(jnp.zeros_like, grad)
    for u in range(D):
        upd = apply_masked_update(grad, partition, u, D)
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(a.dtype) / D, acc, upd)
    return acc
