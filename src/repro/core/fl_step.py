"""Jitted, sharded FL round step for LLM-scale architectures.

Maps the paper's protocol onto the TPU mesh:
  * the leading batch axis C indexes FL *client cohorts* (multi-pod: one
    cohort per pod, vmapped with ``spmd_axis_name='pod'`` so per-client
    gradients stay pod-local);
  * within a cohort: data-parallel batch + tensor-parallel model;
  * per-client DP: the cohort's round update U_c is clipped to C and
    Gaussian noise N(0, C²σ²) added (Algorithm 1 lines 17/23 adapted to
    user-level DP, see DESIGN.md §3);
  * the server step ``w ← w − η̄ Σ_c U_c`` is the trailing cross-pod
    all-reduce — the paper's per-round communication, whose *count* the
    increasing sample-size sequence divides by T_const/T_incr.

``serve_step`` / ``prefill_step`` cover the inference shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_api


def tree_global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def tree_clip(tree, clip_norm: float):
    norm = tree_global_norm(tree)
    scale = (1.0 / jnp.maximum(1.0, norm / clip_norm)).astype(jnp.float32)
    return jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree)


def tree_add_noise(tree, rng, stddev: float):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(flat))
    out = [l + stddev * jax.random.normal(k, l.shape, l.dtype)
           for l, k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def make_train_step(cfg, run_cfg, *, n_client_shards: int,
                    client_axis: Optional[str], unroll: bool = False,
                    grad_pspecs=None):
    """Build train_step(params, momentum, batch, eta_bar, rng).

    batch: dict of arrays with leading (C, B_local, ...) axes.
    Returns (new_params, new_momentum, metrics).

    grad_pspecs: optional PartitionSpec tree matching params — pins each
    client's gradient to the parameter sharding, so GSPMD reduces partial
    gradients with reduce-scatter instead of a full all-reduce (measured
    305 TB -> see EXPERIMENTS.md §Perf, grok-1 iteration 1).
    """
    dp = run_cfg.fl.dp
    momentum_coef = 0.0  # paper uses plain SGD; momentum available via optim

    def per_client_update(params, client_batch, rng):
        loss, g = jax.value_and_grad(
            lambda p: model_api.train_loss(cfg, p, client_batch,
                                           remat=run_cfg.remat,
                                           unroll=unroll))(params)
        if grad_pspecs is not None:
            g = jax.lax.with_sharding_constraint(g, grad_pspecs)
        if dp.enabled:
            g = tree_clip(g, dp.clip_norm)
            g = tree_add_noise(g, rng, dp.clip_norm * dp.sigma)
        return g, loss

    def train_step(params, momentum, batch, eta_bar, rng):
        rngs = jax.random.split(rng, n_client_shards)
        if n_client_shards > 1:
            grads, losses = jax.vmap(
                per_client_update, in_axes=(None, 0, 0),
                spmd_axis_name=client_axis)(params, batch, rngs)
            # server aggregate: sum over clients (cross-pod all-reduce)
            U = jax.tree_util.tree_map(lambda g: jnp.sum(g, axis=0), grads)
            loss = jnp.mean(losses)
        else:
            squeezed = jax.tree_util.tree_map(lambda a: a[0], batch)
            U, loss = per_client_update(params, squeezed, rngs[0])

        if momentum is not None:
            momentum = jax.tree_util.tree_map(
                lambda m, u: momentum_coef * m + u.astype(m.dtype),
                momentum, U)
            upd = momentum
        else:
            upd = U
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32)
                          - eta_bar * u.astype(jnp.float32)).astype(p.dtype),
            params, upd)
        metrics = {"loss": loss.astype(jnp.float32),
                   "update_norm": tree_global_norm(U)}
        return new_params, momentum, metrics

    return train_step


def make_serve_step(cfg, run_cfg, *, seq_len: int, unroll: bool = False):
    def serve_step(params, cache, tokens, pos):
        return model_api.serve_step(cfg, params, cache, tokens, pos,
                                    seq_len=seq_len, unroll=unroll)
    return serve_step


def make_prefill_step(cfg, run_cfg, *, unroll: bool = False):
    def prefill_step(params, batch):
        return model_api.forward_prefill(cfg, params, batch,
                                         remat=run_cfg.remat, unroll=unroll)
    return prefill_step
