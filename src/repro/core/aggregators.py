"""Hierarchical aggregator tree (paper Supp. A remark).

"We may think of the server as a number of connected separate aggregators
that serve as proxies between the clients and server … Extra layers of
aggregators allows us to satisfy network throughput constraints (at the
price of added communication latency)."

An aggregator sums the U_{i,c} of its child clients per round before
forwarding ONE message upstream — the server's per-round inbound message
count drops from n_clients to n_aggregators.  On the TPU mapping this is
the reduction tree XLA builds for the cross-pod psum; here it is an
explicit protocol object usable in the simulator, with per-round byte
accounting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.protocol import UpdateMsg


class Aggregator:
    """Sums child updates per round; emits one upstream UpdateMsg."""

    def __init__(self, agg_id: int, child_ids: Sequence[int]):
        self.id = agg_id
        self.children = set(child_ids)
        # round -> {c: (U, k_send)}
        self.pending: Dict[int, Dict[int, Any]] = {}
        self.forwarded: List[int] = []

    def receive(self, msg: UpdateMsg) -> Optional[UpdateMsg]:
        assert msg.client_id in self.children, \
            f"client {msg.client_id} not assigned to aggregator {self.id}"
        bucket = self.pending.setdefault(msg.round_idx, {})
        bucket[msg.client_id] = (msg.U, msg.k_send)
        if set(bucket) == self.children:
            total = None
            for U, _ks in bucket.values():
                total = U if total is None else jax.tree_util.tree_map(
                    jnp.add, total, U)
            # forward the bucket's MINIMUM k_send — the conservative
            # (largest) staleness of any summed child update, so the
            # staleness-at-apply census never under-reports an
            # aggregator-tree run (k_send previously defaulted to 0,
            # i.e. garbage tau = server_k for every aggregate)
            k_send = min(ks for _U, ks in bucket.values())
            del self.pending[msg.round_idx]
            self.forwarded.append(msg.round_idx)
            # encode the aggregate as a synthetic "client" = aggregator id
            return UpdateMsg(round_idx=msg.round_idx,
                             client_id=self.id, U=total, k_send=k_send)
        return None


def build_tree(n_clients: int, fan_in: int) -> List[Aggregator]:
    """One aggregator per fan_in consecutive clients."""
    aggs = []
    for a, start in enumerate(range(0, n_clients, fan_in)):
        aggs.append(Aggregator(a, range(start,
                                        min(start + fan_in, n_clients))))
    return aggs


def tree_message_counts(n_clients: int, fan_in: int, T: int) -> dict:
    """Messages per link level for T rounds (throughput planning)."""
    n_aggs = -(-n_clients // fan_in)
    return {
        "client_to_aggregator": n_clients * T,
        "aggregator_to_server": n_aggs * T,
        "server_inbound_reduction": n_clients / n_aggs,
    }
