"""Step-size schedules η_t and round step sizes η̄_i (Supp. B.4, Lemma 2).

Paper schemes:
  constant  : η_t = η0
  inv_t     : η_t = η0 / (1 + β t)          (strongly convex experiments)
  inv_sqrt  : η_t = η0 / (1 + β sqrt(t))    (plain convex / non-convex)
  theorem5  : η̄_i = (12/μ) / (Σ_{j<i} s_j + 2 M1 + sqrt(((m+1)²/4 + Σ)/ln(·)))

``round_transform`` (the paper's "diminishing₂") freezes η within a round:
η̄_i = η_{t(i)} with t(i) = Σ_{j<i} s_j — Lemma 2 proves the resulting
{η̄_i} still satisfies the convergence preconditions.
"""
from __future__ import annotations

import math
from typing import List, Sequence

from repro.configs.base import StepSizeConfig
from repro.core.delay import Theorem5Delay


def eta_t(cfg: StepSizeConfig, t: float) -> float:
    if cfg.kind == "constant":
        return cfg.eta0
    if cfg.kind == "inv_t":
        return cfg.eta0 / (1.0 + cfg.beta * t)
    if cfg.kind == "inv_sqrt":
        return cfg.eta0 / (1.0 + cfg.beta * math.sqrt(t))
    raise ValueError(f"unknown step size kind {cfg.kind!r}")


def round_stepsizes(cfg: StepSizeConfig, sizes: Sequence[int]) -> List[float]:
    """η̄_i for each round i given the sample-size sequence."""
    out, cum = [], 0
    for s in sizes:
        out.append(eta_t(cfg, cum))
        cum += s
    return out


def theorem5_round_stepsizes(mu: float, sizes: Sequence[int], *,
                             m: int = 0, d: int = 1,
                             M1_extra: float = 0.0) -> List[float]:
    """η̄_i = (12/μ) / (Σ_{j<i} s_j + 2M1 + sqrt((M0+Σ)/ln(M0+Σ)))  (Thm 5)."""
    delay = Theorem5Delay(m=m, d=d, M1_extra=M1_extra)
    M0, M1 = delay.M0, delay.M1
    out, cum = [], 0
    for s in sizes:
        z = max(M0 + cum, math.e)
        denom = cum + 2.0 * M1 + math.sqrt(z / math.log(z))
        out.append(12.0 / (mu * denom))
        cum += s
    return out


def per_iteration_stepsizes(cfg: StepSizeConfig,
                            sizes: Sequence[int]) -> List[List[float]]:
    """The paper's "diminishing₁": fine-grained η_t within each round."""
    out, cum = [], 0
    for s in sizes:
        out.append([eta_t(cfg, cum + h) for h in range(s)])
        cum += s
    return out
