# The paper's primary contribution: the asynchronous FL protocol with
# increasing sample-size sequences, diminishing round step sizes,
# permissible-delay gating, and the DP-ready round computation.
from repro.core.delay import ConstantDelay, SqrtDelay, Theorem5Delay
from repro.core.protocol import BroadcastMsg, Client, Server, UpdateMsg
from repro.core.sequences import (communication_rounds_vs_constant,
                                  lemma1_sequence, rounds_for_budget,
                                  sample_size, sample_sizes,
                                  satisfies_condition3)
from repro.core.simulator import AsyncFLSimulator, run_sync_baseline
from repro.core.stepsizes import (eta_t, per_iteration_stepsizes,
                                  round_stepsizes, theorem5_round_stepsizes)
from repro.core.strategies import (AggregationStrategy, FedAsyncStrategy,
                                   FedBuffStrategy, PaperStrategy,
                                   get_strategy)
from repro.core.tasks import BatchModelTask, LogRegTask

__all__ = [
    "ConstantDelay", "SqrtDelay", "Theorem5Delay",
    "BroadcastMsg", "Client", "Server", "UpdateMsg",
    "communication_rounds_vs_constant", "lemma1_sequence",
    "rounds_for_budget", "sample_size", "sample_sizes",
    "satisfies_condition3",
    "AsyncFLSimulator", "run_sync_baseline",
    "eta_t", "per_iteration_stepsizes", "round_stepsizes",
    "theorem5_round_stepsizes",
    "AggregationStrategy", "FedAsyncStrategy", "FedBuffStrategy",
    "PaperStrategy", "get_strategy",
    "BatchModelTask", "LogRegTask",
]
