"""Discrete-event asynchronous-network simulator for the FL protocol.

Simulates the paper's deployment regime on virtual time:
  * heterogeneous client compute speeds (iterations / second),
  * message latencies drawn per message (out-of-order delivery arises
    naturally: a later-sent message may arrive earlier),
  * clients compute *lazily* between events, so a mid-round broadcast
    arrival replaces the local model exactly at the iteration it would
    have in a real deployment (ISRRECEIVE semantics),
  * the wait gate blocks a client that runs d rounds ahead (Supp. B.2).

Heterogeneity can come from a ``repro.scenarios`` Scenario (pass
``scenario=`` instead of ``latency_fn=``): latency is then drawn from
the same message-addressed threefry chain the cohort engines use — the
update from client c's round i and broadcast k's delivery to client c
land in the same latency-table bin in every engine (here in continuous
seconds, there quantized to ticks) — including per-client tables, whose
``table_id`` gather is part of the shared plan.  Availability models
with a continuous-time form integrate into the lazy-advance schedule:
diurnal windows exactly, and ``RenewalChurn`` as the true alternating
renewal process (per-client exponential on/off holding times) the
cohort engines approximate per tick.  Epoch-hash churn (``Churn``,
``RegionalChurn``) has no continuous form and is rejected — use the
cohort engines.

The simulator is the test harness for Theorem 1's consistency invariant
and the measurement rig for rounds/communication benchmarks.
"""
from __future__ import annotations

import heapq
import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import BroadcastMsg, Client, Server, UpdateMsg
from repro.telemetry import (STALE_BINS, PhaseTimer, broadcast_msg_bytes,
                             build_report, model_flat_dim, open_trace,
                             staleness_bin, update_msg_bytes)


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)          # update_arrival | broadcast_arrival
    payload: Any = field(compare=False)
    client_id: int = field(compare=False, default=-1)


class AsyncFLSimulator:
    def __init__(self, task, *, n_clients: int, sizes_per_client,
                 round_stepsizes: Sequence[float], d: int = 1,
                 speeds: Optional[Sequence[float]] = None,
                 latency_fn: Optional[Callable[[np.random.Generator], float]]
                 = None,
                 seed: int = 0, record_invariant: bool = False,
                 global_sizes: Optional[Sequence[int]] = None,
                 scenario=None, trace=None, dp_delta: float = 1e-5,
                 strategy=None):
        self.task = task
        self.n = n_clients
        self.rng = np.random.default_rng(seed)
        self._plan = self._windows = None
        if scenario is not None:
            if latency_fn is not None:
                raise ValueError("pass either scenario= or latency_fn=, "
                                 "not both")
            from repro.scenarios import get_scenario, scenario_plan
            scn = get_scenario(scenario)
            # windows() raises for availability models with no
            # continuous-time form (e.g. tick-hash churn)
            self._windows = scn.availability.windows(n_clients, seed)
            self._plan = scenario_plan(scn, C=n_clients, seed=seed)
            if speeds is None:
                speeds = scn.speeds(n_clients, seed)
        self.speeds = list(speeds) if speeds is not None else [1.0] * n_clients
        self.latency_fn = latency_fn or (lambda r: 0.05 + 0.05 * r.random())
        self.record_invariant = record_invariant
        self.global_sizes = global_sizes

        w0 = task.init_model()
        self.server = Server(w0, n_clients, round_stepsizes,
                             strategy=strategy)
        if isinstance(sizes_per_client[0], (list, tuple)):
            per_client = sizes_per_client
        else:
            per_client = [list(sizes_per_client)] * n_clients
        self._sizes_sched = [list(s) for s in per_client]
        self.clients = [
            Client(c, w0, task, per_client[c], round_stepsizes, d,
                   seed=seed * 1000 + c)
            for c in range(n_clients)
        ]
        self.now = 0.0
        self._seq = itertools.count()
        self.events: List[_Event] = []
        self.last_advance = [0.0] * n_clients
        self.total_messages = 0
        self.total_broadcasts = 0
        # telemetry: communication census + staleness-at-apply counters
        self.flat_dim = model_flat_dim(w0)
        self._upd_bytes = update_msg_bytes(self.flat_dim)
        self._bc_bytes = broadcast_msg_bytes(self.flat_dim)
        self.part = np.zeros(n_clients, dtype=np.int64)
        self.bytes_up = np.zeros(n_clients, dtype=np.int64)
        self.stale_hist = np.zeros(STALE_BINS, dtype=np.int64)
        self.dp_delta = dp_delta
        self._trace = open_trace(trace)
        self.history: List[Dict[str, float]] = []
        self.invariant_violations: List[Tuple[int, int, int]] = []
        for c in range(n_clients):
            self._schedule_round_complete(c)

    # -- scheduling helpers -------------------------------------------------
    def _push(self, t: float, kind: str, payload, client_id: int = -1):
        heapq.heappush(self.events,
                       _Event(t, next(self._seq), kind, payload, client_id))

    def _schedule_round_complete(self, c: int) -> None:
        cl = self.clients[c]
        if cl.blocked:
            return
        work_s = cl.remaining_in_round() / self.speeds[c]
        if self._windows is not None:
            t_done = self._windows.advance(c, self.now, work_s)
        else:
            t_done = self.now + work_s
        self._push(t_done, "round_complete", None, c)

    def _advance_client(self, c: int, t: float) -> None:
        """Lazily run client c's iterations up to virtual time t (only
        its availability-window on-time counts as compute)."""
        cl = self.clients[c]
        if self._windows is not None:
            dt = self._windows.on_time(c, self.last_advance[c], t)
        else:
            dt = t - self.last_advance[c]
        self.last_advance[c] = t
        if cl.blocked or dt <= 0:
            return
        n = min(cl.remaining_in_round(), int(math.floor(dt * self.speeds[c])))
        if n > 0:
            if self.record_invariant and self.global_sizes is not None:
                tg, td = cl.record_delay(self.global_sizes)
                # Theorem 1 invariant (via gate): t_delay stays bounded
            cl.run(n)

    # -- event handlers -------------------------------------------------------
    def _on_round_complete(self, ev: _Event) -> None:
        c = ev.client_id
        cl = self.clients[c]
        self._advance_client(c, ev.time)
        rem = cl.remaining_in_round()
        if cl.blocked:
            return
        if rem > 0:                       # rounding drift: finish exactly
            cl.run(rem)
        msg = cl.finish_round()
        self.total_messages += 1
        self.part[c] += 1
        self.bytes_up[c] += self._upd_bytes
        if self._plan is not None:
            # one batched draw per round, cached in the plan (the whole
            # fleet's round-i update latencies in a single device call)
            lat = self._plan.update_latencies_s(msg.round_idx)[c]
        else:
            lat = self.latency_fn(self.rng)
        if self._trace:
            self._trace.emit("update_sent", time=ev.time, client=c,
                             round=msg.round_idx, k_send=msg.k_send,
                             bytes=self._upd_bytes, latency_s=lat)
        self._push(ev.time + lat, "update_arrival", msg)
        self._schedule_round_complete(c)   # may be a no-op if now blocked

    def _on_update_arrival(self, ev: _Event) -> None:
        msg = ev.payload
        # staleness-at-apply: completed server rounds since the sender's
        # freshest-seen broadcast (bounded by d-1 via the wait gate)
        tau = self.server.k - msg.k_send
        self.stale_hist[staleness_bin(tau)] += 1
        if self._trace:
            self._trace.emit("update_applied", time=ev.time,
                             client=msg.client_id, round=msg.round_idx,
                             server_k=self.server.k, staleness=tau)
        for bcast in self.server.receive(msg):
            self.total_broadcasts += 1
            if self._plan is not None:
                lats = self._plan.broadcast_latencies_s(bcast.k)
            else:
                lats = [self.latency_fn(self.rng) for _ in range(self.n)]
            if self._trace:
                self._trace.emit("broadcast_fired", time=ev.time, k=bcast.k,
                                 bytes_per_client=self._bc_bytes,
                                 clients=self.n)
            for c in range(self.n):
                self._push(ev.time + lats[c], "broadcast_arrival", bcast, c)

    def _on_broadcast_arrival(self, ev: _Event) -> None:
        c = ev.client_id
        cl = self.clients[c]
        was_blocked = cl.blocked
        self._advance_client(c, ev.time)
        if self._trace:
            self._trace.emit("broadcast_applied", time=ev.time, client=c,
                             k=ev.payload.k, accepted=ev.payload.k > cl.k)
        cl.isr_receive(ev.payload)
        if was_blocked and not cl.blocked:
            self.last_advance[c] = ev.time
            self._schedule_round_complete(c)

    # -- main loop ------------------------------------------------------------
    def run(self, *, max_rounds: int, eval_every: int = 1,
            eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None
            ) -> Dict[str, Any]:
        """Run until the server has completed ``max_rounds`` broadcasts."""
        evals = eval_fn or (lambda w: self.task.metrics(w))
        next_eval = eval_every
        # kept on the simulator so the timeline CLI (python -m
        # repro.telemetry capture) can export the wall spans after run()
        timer = self.timer = PhaseTimer()
        run_t0 = time.perf_counter()
        while self.events and self.server.k < max_rounds:
            ev = heapq.heappop(self.events)
            self.now = ev.time
            if ev.kind == "round_complete":
                self._on_round_complete(ev)
            elif ev.kind == "update_arrival":
                self._on_update_arrival(ev)
            elif ev.kind == "broadcast_arrival":
                self._on_broadcast_arrival(ev)
            if self.server.k >= next_eval:
                with timer.phase("eval"):
                    m = evals(self.server.v)
                m.update(round=self.server.k, time=self.now,
                         messages=self.total_messages)
                self.history.append(m)
                next_eval = self.server.k + eval_every
        with timer.phase("eval"):
            final = evals(self.server.v)
        final.update(round=self.server.k, time=self.now,
                     messages=self.total_messages,
                     broadcasts=self.total_broadcasts)
        timer.add("run", time.perf_counter() - run_t0)
        report = self.telemetry_report(wall=timer.as_dict())
        if self._trace:
            self._trace.emit("report", **report.to_dict())
            self._trace.close()
        return {"final": final, "history": self.history,
                "model": self.server.v, "telemetry": report}

    def telemetry_report(self, wall=None):
        """MetricsReport from the counters accumulated so far."""
        src_task = self.task
        return build_report(
            engine="event", clients=self.n, flat_dim=self.flat_dim,
            rounds=self.server.k, messages=self.total_messages,
            broadcasts=self.total_broadcasts,
            participation=self.part, bytes_up=self.bytes_up,
            staleness_hist=self.stale_hist,
            virtual_time=self.now,
            dp_sigma=float(getattr(src_task, "dp_sigma", 0.0) or 0.0),
            dp_delta=self.dp_delta,
            n_examples=(int(src_task.X.shape[0])
                        if hasattr(src_task, "X") else None),
            sizes_per_client=self._sizes_sched, wall=wall)


def run_sync_baseline(task, *, n_clients: int, n_rounds: int,
                      sample_size: int, eta: float, seed: int = 0
                      ) -> Dict[str, Any]:
    """Original synchronous FL (constant step + sample size) baseline."""
    w = task.init_model()
    history = []
    key = jax.random.PRNGKey(seed)
    for r in range(n_rounds):
        updates = []
        for c in range(n_clients):
            key, sub = jax.random.split(key)
            _, U = task.run_iterations(
                w, task.zero_update(), round_idx=r, client_id=c,
                start_h=0, n_iters=sample_size, eta=eta, rng=sub)
            updates.append(U)
        total = updates[0]
        for U in updates[1:]:
            total = jax.tree_util.tree_map(jnp.add, total, U)
        w = jax.tree_util.tree_map(lambda p, u: p - eta * u, w, total)
        m = task.metrics(w)
        m["round"] = r + 1
        history.append(m)
    return {"final": history[-1], "history": history, "model": w}
