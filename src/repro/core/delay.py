"""Permissible delay functions τ(t) (§2, Supp. C.2.2).

The framework tolerates inconsistent reads up to τ(t) iterations stale;
for strongly-convex problems τ(t) ≈ sqrt(t / ln t) is admissible
(equation (14)).  Theorem 5's concrete instance:

    τ(t) = M1 + sqrt((t + M0) / (4 ln(t + M0)))

with M0 = (m+1)^2 / 4 and M1 = max(d+1, 2Lα/μ, s_0/2-ish term).
``t − τ(t)`` must be increasing — validated by property tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Theorem5Delay:
    """Callable τ(t) from Theorem 5's construction."""
    m: int = 0
    d: int = 1
    M1_extra: float = 0.0  # stands in for 2Lα/μ when curvature is known

    @property
    def M0(self) -> float:
        return (self.m + 1) ** 2 / 4.0

    @property
    def M1(self) -> float:
        z = (self.m + 1) / (16.0 * (self.d + 1) ** 2)
        ln_arg = max((self.m + 1) / (2.0 * (self.d + 1)), math.e)
        third = 0.5 * math.ceil(z / math.log(ln_arg))
        return max(self.d + 1, self.M1_extra, third)

    def __call__(self, t: float) -> float:
        z = t + self.M0
        return self.M1 + math.sqrt(z / (4.0 * math.log(max(z, math.e))))


@dataclass(frozen=True)
class SqrtDelay:
    """τ(t) = c * sqrt(t / ln t) — the admissible asymptotic envelope."""
    c: float = 1.0
    floor: float = 2.0

    def __call__(self, t: float) -> float:
        t = max(t, math.e)
        return max(self.floor, self.c * math.sqrt(t / math.log(t)))


@dataclass(frozen=True)
class ConstantDelay:
    """τ(t) = τ0 — matches the constant-step-size regime (13)."""
    tau0: float = 100.0

    def __call__(self, t: float) -> float:
        return self.tau0


def t_minus_tau_increasing(tau, t_max: int, step: int = 7) -> bool:
    prev = 0 - tau(0)
    for t in range(step, t_max, step):
        cur = t - tau(t)
        if cur < prev - 1e-9:
            return False
        prev = cur
    return True
