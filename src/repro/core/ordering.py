"""Theorem 1's iteration-ordering map ρ (Supp. B.1).

SETUP (Algorithm 2) assigns each of round i's s_i global iterations to a
client via coin flips: a(i, t) = c with probability p_c.  The map

    ρ(c, i, h) = Σ_{l<i} s_l + min{t' : h = |{t <= t' : a(i,t) = c}|}

labels every client-local iteration (c, i, h) with a global iteration
count t; the paper proves ρ is a bijection, which is what lets the
distributed execution be analyzed as ONE asynchronous SGD sequence
{w_t}.  We implement ρ and its inverse and property-test bijectivity.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def make_assignment(sizes: Sequence[int], p: Sequence[float], *,
                    seed: int = 0) -> List[np.ndarray]:
    """a(i, t): per-round arrays of client ids (Algorithm 2 lines 5-8)."""
    rng = np.random.default_rng(seed)
    pv = np.asarray(p, float)
    pv = pv / pv.sum()
    return [rng.choice(len(p), size=s, p=pv) for s in sizes]


def client_sizes(assignment: List[np.ndarray], n_clients: int
                 ) -> List[List[int]]:
    """s_{i,c} = |{t : a(i,t) = c}|."""
    return [[int(np.sum(a == c)) for a in assignment]
            for c in range(n_clients)]


def rho(assignment: List[np.ndarray], c: int, i: int, h: int) -> int:
    """Global iteration index of client c's h-th iteration in round i
    (0-based h; the paper's h counts completed iterations)."""
    base = sum(len(a) for a in assignment[:i])
    a = assignment[i]
    positions = np.flatnonzero(a == c)
    return base + int(positions[h])


def rho_inverse(assignment: List[np.ndarray], t: int
                ) -> Tuple[int, int, int]:
    """(c, i, h) with ρ(c, i, h) = t."""
    i = 0
    while t >= len(assignment[i]):
        t -= len(assignment[i])
        i += 1
    c = int(assignment[i][t])
    h = int(np.sum(assignment[i][:t] == c))
    return c, i, h


def is_bijection(assignment: List[np.ndarray], n_clients: int) -> bool:
    total = sum(len(a) for a in assignment)
    seen = set()
    for c in range(n_clients):
        for i, a in enumerate(assignment):
            for h in range(int(np.sum(a == c))):
                seen.add(rho(assignment, c, i, h))
    return seen == set(range(total))
