"""Task abstraction: the computation a client performs inside a round.

``LogRegTask`` reproduces the paper's experiments: per-iteration
single-sample SGD (Algorithm 1 lines 15-21), optional per-sample gradient
clipping (line 17) and round Gaussian noise (lines 23-24).  Iteration
chunks are jitted per power-of-two length to avoid a compile per distinct
segment length (the event simulator produces many lengths).

``BatchModelTask`` adapts any ``repro.models`` architecture: one "local
iteration" = one minibatch-SGD step (the paper's footnote ‡ licenses batch
SGD per round); DP clips the client's round update (user-level DP).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import logreg


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_tree(tree, clip: float):
    norm = global_norm(tree)
    scale = 1.0 / jnp.maximum(1.0, norm / clip)
    return jax.tree_util.tree_map(lambda l: l * scale, tree)


def validate_dp_knobs(dp_clip: float, dp_sigma: float, who: str) -> None:
    """Round noise is drawn with std dp_clip * dp_sigma (Algorithm 1
    line 23 scales the Gaussian by the clip bound), so dp_sigma > 0 with
    dp_clip == 0 silently produced ZERO noise — no privacy, no error.
    Shared by the tasks and both cohort engines."""
    if dp_sigma > 0.0 and dp_clip <= 0.0:
        raise ValueError(
            f"{who}: dp_sigma={dp_sigma} > 0 requires dp_clip > 0 — the "
            "round-noise std is dp_clip * dp_sigma, so dp_clip == 0 "
            "would add zero noise while appearing to be private")


class LogRegTask:
    """Paper experiment task (strongly-convex / plain-convex logreg).

    ``sample_seed``: when set, the per-iteration sample index is derived
    from ``fold_in(fold_in(fold_in(key(sample_seed), client), round), h)``
    instead of the client's streaming rng — the index is the folded key's
    first word mod n (one threefry application; a ``randint`` on the
    folded key would hash a second time and the derivation dominates the
    SGD block at cohort scale).  The draw then depends only on
    *(client, round, iteration)* — not on how the event simulator happens
    to chunk a round into ``run()`` calls — which makes trajectories
    reproducible across engines (see ``repro.cohort``).
    """

    def __init__(self, X, y, *, l2: float = 0.0, dp_clip: float = 0.0,
                 dp_sigma: float = 0.0, d_features: Optional[int] = None,
                 sample_seed: Optional[int] = None):
        self.X = jnp.asarray(X, jnp.float32)
        self.y = jnp.asarray(y, jnp.float32)
        self.l2 = float(l2)
        self.dp_clip = float(dp_clip)
        self.dp_sigma = float(dp_sigma)
        validate_dp_knobs(self.dp_clip, self.dp_sigma, "LogRegTask")
        self.d = d_features or self.X.shape[1]
        self.sample_seed = sample_seed
        self._chunk_fns: Dict[int, Any] = {}

    # -- model ------------------------------------------------------------
    def init_model(self, key=None):
        return logreg.init_params(self.d, key)

    def zero_update(self):
        return {"w": jnp.zeros((self.d,), jnp.float32),
                "b": jnp.zeros((), jnp.float32)}

    # -- per-chunk jitted runner -------------------------------------------
    def _chunk_fn(self, n: int):
        """Jitted n-iteration SGD chunk taking a (n,)-index array."""
        if n in self._chunk_fns:
            return self._chunk_fns[n]
        X, y, l2 = self.X, self.y, self.l2
        clip = self.dp_clip

        def run(w, U, eta, idx):
            def step2(carry, ix):
                w, U = carry
                g = jax.grad(logreg.per_example_loss)(w, X[ix], y[ix], l2)
                if clip > 0.0:
                    g = clip_tree(g, clip)
                U = jax.tree_util.tree_map(jnp.add, U, g)
                w = jax.tree_util.tree_map(lambda p, gg: p - eta * gg, w, g)
                return (w, U), None

            (w, U), _ = jax.lax.scan(step2, (w, U), idx)
            return w, U

        fn = jax.jit(run)
        self._chunk_fns[n] = fn
        return fn

    @staticmethod
    def _chunks(n: int):
        """Decompose n into descending power-of-two chunks (bounded jits)."""
        out, p = [], 1 << 14
        while n > 0 and p > 0:
            while p <= n:
                out.append(p)
                n -= p
            p >>= 1
        return out

    # -- Task interface ----------------------------------------------------
    def iteration_key_base(self, client_id: int, round_idx):
        """(client, round)-addressed key base for deterministic sampling."""
        base = jax.random.PRNGKey(self.sample_seed)
        return jax.random.fold_in(jax.random.fold_in(base, client_id),
                                  round_idx)

    def sample_indices(self, base, h, n: int):
        """(client, round)-keyed indices for iterations h .. h+n-1: first
        word of ``fold_in(base, h+j)`` mod n_data (single threefry)."""
        keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
            h + jnp.arange(n))
        return (keys[:, 0] % jnp.uint32(self.X.shape[0])).astype(jnp.int32)

    def run_iterations(self, w, U, *, round_idx, client_id, start_h,
                       n_iters, eta, rng):
        n_data = self.X.shape[0]
        h = int(start_h)
        for j, c in enumerate(self._chunks(int(n_iters))):
            if self.sample_seed is not None:
                base = self.iteration_key_base(client_id, round_idx)
                idx = self.sample_indices(base, h, c)
            else:
                rng, sub = jax.random.split(rng)
                keys = jax.random.split(sub, c)
                idx = jax.vmap(
                    lambda r: jax.random.randint(r, (), 0, n_data))(keys)
            w, U = self._chunk_fn(c)(w, U, jnp.float32(eta), idx)
            h += c
        return w, U

    def add_round_noise(self, w, U, *, eta, rng):
        if self.dp_sigma <= 0.0:
            return w, U
        keys = jax.random.split(rng, len(jax.tree_util.tree_leaves(U)))
        flat, treedef = jax.tree_util.tree_flatten(U)
        noise = [self.dp_clip * self.dp_sigma
                 * jax.random.normal(k, l.shape, jnp.float32)
                 for k, l in zip(keys, flat)]
        noise = jax.tree_util.tree_unflatten(treedef, noise)
        U = jax.tree_util.tree_map(jnp.add, U, noise)
        w = jax.tree_util.tree_map(lambda p, n: p + eta * n, w, noise)
        # note sign: Algorithm 1 line 24 writes ŵ = ŵ + η̄·n with U = U + n;
        # the server applies v − η̄ U, so the client pre-adds η̄·n so that a
        # later replacement ŵ = v̂ − η̄ U stays consistent.
        return w, U

    def metrics(self, w) -> Dict[str, float]:
        return {
            "loss": float(logreg.batch_loss(w, self.X, self.y, self.l2)),
            "accuracy": float(logreg.accuracy(w, self.X, self.y)),
        }


class BatchModelTask:
    """LLM-scale task: one local iteration = one minibatch-SGD step."""

    def __init__(self, cfg, params_template, data_fn, *, dp_clip: float = 0.0,
                 dp_sigma: float = 0.0, remat: bool = True):
        from repro.models import train_loss
        self.cfg = cfg
        self.data_fn = data_fn           # (client_id, round, h, rng) -> batch
        self.dp_clip = float(dp_clip)
        self.dp_sigma = float(dp_sigma)
        validate_dp_knobs(self.dp_clip, self.dp_sigma, "BatchModelTask")
        self.template = params_template
        self.remat = bool(remat)

        def step(w, U, batch, eta):
            loss, g = jax.value_and_grad(
                lambda p: train_loss(cfg, p, batch, remat=remat))(w)
            if self.dp_clip > 0.0:
                g = clip_tree(g, self.dp_clip)
            U = jax.tree_util.tree_map(jnp.add, U, g)
            w = jax.tree_util.tree_map(lambda p, gg: p - eta * gg, w, g)
            return w, U, loss

        self._step = jax.jit(step)
        self._eval_loss = jax.jit(
            lambda p, batch: train_loss(cfg, p, batch, remat=remat))
        self._eval_batch = None
        self.last_loss = None

    def init_model(self, key=None):
        """Default initial model: the params template (drivers that init
        fresh params per run may still override this attribute)."""
        return self.template

    def zero_update(self):
        return jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), self.template)

    def run_iterations(self, w, U, *, round_idx, client_id, start_h,
                       n_iters, eta, rng):
        for h in range(int(n_iters)):
            rng, sub = jax.random.split(rng)
            batch = self.data_fn(client_id, round_idx, start_h + h, sub)
            w, U, loss = self._step(w, U, batch, jnp.float32(eta))
            self.last_loss = float(loss)
        return w, U

    def add_round_noise(self, w, U, *, eta, rng):
        if self.dp_sigma <= 0.0:
            return w, U
        flat, treedef = jax.tree_util.tree_flatten(U)
        keys = jax.random.split(rng, len(flat))
        noise = [self.dp_clip * self.dp_sigma
                 * jax.random.normal(k, l.shape, jnp.float32)
                 for k, l in zip(keys, flat)]
        noise = jax.tree_util.tree_unflatten(treedef, noise)
        U = jax.tree_util.tree_map(jnp.add, U, noise)
        w = jax.tree_util.tree_map(
            lambda p, n: (p + eta * n.astype(p.dtype)).astype(p.dtype),
            w, noise)
        return w, U

    def metrics(self, w) -> Dict[str, float]:
        """Eval loss of ``w`` on a fixed probe batch.

        Previously returned ``{"loss": None}`` until the first local step
        and a *stale client-side train loss* after — the engines call
        ``metrics`` on the SERVER model at eval boundaries, so histories
        carried values that never reflected the evaluated params.  The
        probe batch is the deterministic (client 0, round 0, iteration 0)
        batch, identical across engines for the same data_fn.
        """
        if self._eval_batch is None:
            self._eval_batch = self.data_fn(0, 0, 0,
                                            jax.random.PRNGKey(0))
        out = {"loss": float(self._eval_loss(w, self._eval_batch))}
        if self.last_loss is not None:
            out["last_train_loss"] = self.last_loss
        return out
