"""Sample-size sequences {s_i} — the paper's central knob (§2.2, Supp. B.3).

Implemented kinds:
  constant : s_i = s0                                   (original FL baseline)
  linear   : s_i = s0 + ceil(a*i)                       (Θ(i), §E.2.2)
  power    : s_i = ceil(N_c * q * (i+m)^p)              (Theorem 4 / DP form)
  ilog     : s_i = ceil((m+i+1) / (16 (d+1)^2 ln((m+i+1)/(2(d+1)))))
             (Theorem 5's Θ(i/ln i) recipe for strongly-convex problems)

Also: condition (3)/(4) checking against a delay function τ, and Lemma 1's
generic recipe S(x) = (x/ω(x) · (g−1)/g)^{1/(g−1)}.
"""
from __future__ import annotations

import math
from typing import Callable, List, Sequence

from repro.configs.base import SampleSequenceConfig


def sample_size(cfg: SampleSequenceConfig, i: int) -> int:
    if cfg.kind == "constant":
        return int(cfg.s0)
    if cfg.kind == "linear":
        return int(cfg.s0 + math.ceil(cfg.a * i))
    if cfg.kind == "power":
        if cfg.N_c and cfg.q:
            return max(1, int(math.ceil(cfg.N_c * cfg.q * (i + cfg.m) ** cfg.p)))
        return max(1, int(math.ceil(cfg.s0 * ((i + cfg.m + 1)
                                              / (cfg.m + 1)) ** cfg.p)))
    if cfg.kind == "ilog":
        d = cfg.d
        z = cfg.m + i + 1
        denom = 16.0 * (d + 1) ** 2 * math.log(max(z / (2.0 * (d + 1)), math.e))
        return max(1, int(math.ceil(z / denom)))
    raise ValueError(f"unknown sample sequence kind {cfg.kind!r}")


def sample_sizes(cfg: SampleSequenceConfig, n_rounds: int) -> List[int]:
    return [sample_size(cfg, i) for i in range(n_rounds)]


def rounds_for_budget(cfg: SampleSequenceConfig, K: int) -> List[int]:
    """Shortest prefix {s_i} with sum >= K (K = total grad computations)."""
    sizes, total, i = [], 0, 0
    while total < K:
        s = sample_size(cfg, i)
        sizes.append(s)
        total += s
        i += 1
        if i > 10_000_000:
            raise RuntimeError("budget K unreachable (sequence too small)")
    return sizes


def cumulative(sizes: Sequence[int]) -> List[int]:
    out, tot = [], 0
    for s in sizes:
        tot += s
        out.append(tot)
    return out


def satisfies_condition3(sizes: Sequence[int], tau: Callable[[float], float],
                         d: int) -> bool:
    """Condition (3): for all i >= d+1, τ(Σ_{j<=i} s_j) >= Σ_{j=i-d..i} s_j."""
    cum = cumulative(sizes)
    for i in range(d + 1, len(sizes)):
        lhs = tau(cum[i])
        rhs = cum[i] - (cum[i - d - 1] if i - d - 1 >= 0 else 0)
        if lhs < rhs:
            return False
    return True


# ---------------------------------------------------------------------------
# Lemma 1: generic recipe from a delay function
# ---------------------------------------------------------------------------

def lemma1_sequence(n_rounds: int, *, g: float = 2.0, m: int = 0, d: int = 1,
                    gamma: Callable[[float], float] = None) -> List[int]:
    """s_i = ceil(S((m+i+1)/(d+1)) / (d+1)) with
    S(x) = (x/ω(x) · (g−1)/g)^{1/(g−1)}, ω(x) = γ((x(g−1)/g)^{g/(g−1)}).

    Default γ(z) = 4 ln(z) (clamped >= 1) matches Theorem 5 (g = 2).
    """
    if gamma is None:
        def gamma(z):
            return max(1.0, 4.0 * math.log(max(z, 1.0)))

    def S(x: float) -> float:
        base = x * (g - 1.0) / g
        omega = gamma(base ** (g / (g - 1.0)))
        return (max(base, 0.0) / omega) ** (1.0 / (g - 1.0))

    return [max(1, int(math.ceil(S((m + i + 1) / (d + 1)) / (d + 1))))
            for i in range(n_rounds)]


def max_constant_sample_size(eta: float, mu: float, d: int) -> int:
    """Supp. C.2.1: with constant step size η, delay bound (13) requires
    τ = (d+1)·s ≤ 1/(η μ), i.e. s ≤ 1/(η μ (d+1))."""
    return max(1, int(1.0 / (eta * mu * (d + 1))))


def communication_rounds_vs_constant(cfg: SampleSequenceConfig,
                                     K: int) -> dict:
    """Reduction metrics vs the constant-size baseline with the same s0.

    Returns T_incr, T_const, reduction factor — the paper's headline
    T ~ sqrt(K) claim is checked against this in benchmarks.
    """
    sizes = rounds_for_budget(cfg, K)
    t_incr = len(sizes)
    t_const = math.ceil(K / max(cfg.s0, 1))
    return {"T_increasing": t_incr, "T_constant": t_const,
            "reduction": t_const / max(t_incr, 1), "sizes": sizes}
