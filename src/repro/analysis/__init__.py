"""Parity sanitizer: repo-specific static analysis + trace invariants.

Rule families (see ``python -m repro.analysis --help``):
  PRNG-*    — PRNG address-space audit against the central salt
              registry (``repro.analysis.salts``)
  PURITY-*  — host-world constructs inside traced (jitted) functions
  STRUCT-*  — DeviceCohortState / sharding-spec completeness + dtype
              discipline
  INV-*     — protocol invariants model-checked over JSONL telemetry
              traces (``repro.analysis.invariants``)

Only the salt registry is imported eagerly: the engines import their
salts from here at module-import time, so ``repro.analysis`` must not
pull in the engine packages (keep this __init__ free of runner/
structure imports).
"""
from repro.analysis.base import Violation
from repro.analysis.salts import (AVAIL_SALT, LAT_SALT, NOISE_SALT,
                                  PHASE_SALT, REGION_SALT, RENEW_SALT,
                                  SPEED_SALT, TABLE_SALT, REGISTRY, Salt,
                                  salt_names)

__all__ = [
    "Violation", "Salt", "REGISTRY", "salt_names",
    "LAT_SALT", "TABLE_SALT", "AVAIL_SALT", "PHASE_SALT", "REGION_SALT",
    "RENEW_SALT", "SPEED_SALT", "NOISE_SALT",
]
