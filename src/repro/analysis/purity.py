"""Traced-code purity lint (rule family PURITY-*).

JAX traces Python once and replays compiled XLA; anything host-side that
sneaks into a traced function either crashes at trace time, silently
bakes a constant into the executable, or forces a hidden device→host
sync.  This pass statically identifies the repo's TRACED functions and
flags host-world constructs inside them:

  PURITY-NPRANDOM   ``np.random.*`` calls (untraced host randomness —
                    bakes one draw into the compiled code)
  PURITY-CLOCK      ``time.time`` / ``perf_counter`` / ``datetime.now``
  PURITY-ITEM       ``.item()`` (device→host sync inside the trace)
  PURITY-COERCE     ``float(x)`` / ``int(x)`` / ``bool(x)`` on a
                    non-constant (host coercion of a traced value)
  PURITY-BRANCH     Python ``if`` / ``while`` / ``for`` / ``assert``
                    whose condition derives from a traced argument
                    (use ``lax.cond`` / ``jnp.where``; branching on
                    closure constants is fine)

Traced functions are found structurally, not by module reachability —
the engine modules legitimately mix host-side setup (numpy seeds at
construction) with traced closures, so the unit of analysis is the
function:

  * decorated with ``@jax.jit`` / ``@jit`` / ``@partial(jax.jit, ...)``,
  * passed (by name or as a lambda) to a tracing consumer —
    ``jax.jit``, ``vmap``, ``grad``, ``lax.while_loop`` / ``cond`` /
    ``scan`` / ``fori_loop`` / ``switch`` / ``map``, ``pallas_call``,
  * nested inside a known traced-closure factory (``TRACED_MAKERS``:
    the repo convention that everything defined inside ``tick_plan`` /
    ``block_body`` / ``_build_segment`` runs under the jitted tick
    loops),
  * or nested inside / called by name from any of the above
    (same-module transitive closure).

``check_files`` additionally closes over *cross-module* calls: when a
traced function calls ``attn.attend_full(...)`` through a module alias
(``from repro.models import attention as attn``) or ``chunked_loss(...)``
through a from-import, and the target module is part of the analyzed
set, the callee is linted as traced too.  The callee's taint is seeded
from the call site — only parameters actually bound to tainted caller
expressions start tainted — so static config threaded alongside arrays
(window sizes, flags) does not trip PURITY-BRANCH.  Seeds accumulate to
a fixpoint across call sites; package ``__init__`` re-exports are
followed one level.

Taint for PURITY-BRANCH is a single forward pass: the traced function's
parameters are tainted, and a name assigned from an expression that
mentions a tainted name becomes tainted.  Closure constants (ring sizes,
dp flags) never taint, so the engines' ``if F > 0:`` staging branches
pass — exactly the static/traced split the device engine is built on.

Deliberate taint exceptions (each is static at trace time):

  * params listed in the jit decorator's ``static_argnames``,
  * config-object params (``cfg`` / ``config`` / ``hparams`` — plain
    dataclasses, never arrays),
  * array *metadata* attributes (``.shape`` / ``.ndim`` / ``.dtype`` /
    ``.size``) and everything derived from them (padding amounts),
  * ``is (not) None`` identity tests and ``in`` dict-membership tests
    on parameter pytrees.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.analysis.base import Violation

#: functions whose nested defs are traced by repo convention: closures
#: they build are installed inside the jitted tick loops / run_block
TRACED_MAKERS = {"tick_plan", "block_body", "_build_segment"}

#: callables whose function-valued arguments get traced
TRACING_CONSUMERS = {"jit", "vmap", "pmap", "grad", "value_and_grad",
                     "while_loop", "cond", "scan", "fori_loop", "switch",
                     "map", "pallas_call", "checkpoint", "remat",
                     "custom_vjp", "custom_jvp"}

CLOCK_CALLS = {"time", "perf_counter", "monotonic", "process_time",
               "now", "clock_gettime"}

#: attribute accesses that yield static trace-time metadata, not traced
#: values — shape-derived padding arithmetic stays untainted
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding",
                "weak_type"}

#: parameter names that are config dataclasses by repo convention —
#: branching on their fields is the static model-family dispatch
CONFIG_PARAMS = {"cfg", "config", "hparams"}

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


def _attr_last(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _attr_chain(node: ast.expr) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_jit_decorator(dec: ast.expr) -> bool:
    if _attr_last(dec) == "jit":
        return True
    if isinstance(dec, ast.Call):
        fn = _attr_last(dec.func)
        if fn == "jit":
            return True
        if fn == "partial" and dec.args \
                and _attr_last(dec.args[0]) == "jit":
            return True
    return False


class _FuncIndex(ast.NodeVisitor):
    """Collect every function def with its parent chain."""

    def __init__(self):
        self.funcs: List[FuncNode] = []
        self.parent: Dict[FuncNode, Optional[FuncNode]] = {}
        self.by_name: Dict[str, List[FuncNode]] = {}
        self._stack: List[FuncNode] = []

    def _enter(self, node: FuncNode) -> None:
        self.funcs.append(node)
        self.parent[node] = self._stack[-1] if self._stack else None
        name = getattr(node, "name", None)
        if name:
            self.by_name.setdefault(name, []).append(node)
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _enter
    visit_AsyncFunctionDef = _enter
    visit_Lambda = _enter


def _traced_roots(tree: ast.Module, index: _FuncIndex) -> Set[FuncNode]:
    roots: Set[FuncNode] = set()
    # decorator-based
    for fn in index.funcs:
        for dec in getattr(fn, "decorator_list", []):
            if _is_jit_decorator(dec):
                roots.add(fn)
        # nested inside a traced-closure factory
        p = index.parent[fn]
        while p is not None:
            if getattr(p, "name", None) in TRACED_MAKERS:
                roots.add(fn)
                break
            p = index.parent[p]
    # consumer-call based: jax.jit(f), lax.cond(p, f, g, ...), vmap(f)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _attr_last(node.func) not in TRACING_CONSUMERS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                roots.add(arg)
            elif isinstance(arg, ast.Name):
                roots.update(index.by_name.get(arg.id, []))
    return roots


def _transitive(roots: Set[FuncNode], index: _FuncIndex) -> Set[FuncNode]:
    """Roots + functions they call by bare name + their nested defs."""
    traced = set(roots)
    frontier = list(roots)
    while frontier:
        fn = frontier.pop()
        for node in ast.walk(fn):
            callee = None
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Name):
                callee = node.func.id
            if callee:
                for cand in index.by_name.get(callee, []):
                    if cand not in traced:
                        traced.add(cand)
                        frontier.append(cand)
            if node is not fn and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node not in traced:
                    traced.add(node)
                    frontier.append(node)
    return traced


def _params(fn: FuncNode) -> Set[str]:
    a = fn.args
    names = [p.arg for p in list(a.posonlyargs) + list(a.args)]
    # keyword-only params with literal defaults are static config knobs
    # by repo convention (window sizes, boolean flags) — branching on
    # them is the trace-time specialization the engines rely on
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None and isinstance(d, ast.Constant):
            continue
        names.append(p.arg)
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n != "self" and n not in CONFIG_PARAMS}


def _static_argnames(fn: FuncNode) -> Set[str]:
    """Param names the jit decorator marks static (trace-time Python)."""
    names: Set[str] = set()
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        f = _attr_last(dec.func)
        if f == "partial" and not (dec.args
                                   and _attr_last(dec.args[0]) == "jit"):
            continue
        if f not in ("jit", "partial"):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) \
                            and isinstance(c.value, str):
                        names.add(c.value)
    return names


def _names_in(expr: ast.expr) -> Set[str]:
    """Names that carry taint — skips static-metadata attribute reads
    (``x.shape`` mentions ``x`` but yields trace-time Python)."""
    out: Set[str] = set()

    def walk(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return
            walk(node.value)
            return
        if isinstance(node, ast.Name):
            out.add(node.id)
            return
        for c in ast.iter_child_nodes(node):
            walk(c)

    walk(expr)
    return out


def _test_is_static(expr: ast.expr) -> bool:
    """True when a branch test is decidable at trace time regardless of
    taint: ``is (not) None`` identity and ``in`` dict-membership checks
    (the repo's optional-arg and params-pytree idioms)."""
    if isinstance(expr, ast.BoolOp):
        return all(_test_is_static(v) for v in expr.values)
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.Not):
        return _test_is_static(expr.operand)
    if isinstance(expr, ast.Compare):
        return all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in expr.ops)
    return False


def _check_traced_fn(fn: FuncNode, path: str, traced: Set[FuncNode],
                     seed: Optional[Set[str]] = None
                     ) -> "Tuple[List[Violation], Set[str]]":
    """Lint one traced function; returns (violations, final taint set).

    With ``seed=None`` every non-static parameter starts tainted (the
    local-root case).  A seed set — from cross-module call-site binding
    — restricts the initial taint to the parameters actually fed traced
    values by some caller.
    """
    out: List[Violation] = []
    label = getattr(fn, "name", "<lambda>")
    if seed is None:
        tainted = _params(fn) - _static_argnames(fn)
    else:
        tainted = (set(seed) & _params(fn)) - _static_argnames(fn)

    def is_tainted(expr: ast.expr) -> bool:
        return bool(_names_in(expr) & tainted)

    def test_tainted(expr: ast.expr) -> bool:
        return not _test_is_static(expr) and is_tainted(expr)

    body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
    stmts: List[ast.stmt] = list(body)
    while stmts:
        st = stmts.pop(0)
        # don't descend into nested defs: they are traced functions of
        # their own (handled separately) with their own parameter taint
        children = [c for c in ast.iter_child_nodes(st)
                    if not isinstance(c, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.Lambda))]
        for node in children:
            if isinstance(node, ast.stmt):
                stmts.append(node)
        # taint propagation — a value that is itself a static test
        # (``flag = x is None``) yields trace-time Python, not an array
        if isinstance(st, ast.Assign) and not _test_is_static(st.value) \
                and is_tainted(st.value):
            for t in st.targets:
                tainted.update(_names_in(t))
        if isinstance(st, (ast.AugAssign, ast.AnnAssign)) \
                and st.value is not None \
                and not _test_is_static(st.value) and is_tainted(st.value):
            tainted.update(_names_in(st.target))
        # host-branching on traced values
        if isinstance(st, (ast.If, ast.While)) and test_tainted(st.test):
            out.append(Violation(
                "PURITY-BRANCH", path, st.lineno,
                f"Python {type(st).__name__.lower()} on traced value in "
                f"{label}() — use lax.cond/jnp.where"))
        if isinstance(st, ast.Assert) and test_tainted(st.test):
            out.append(Violation(
                "PURITY-BRANCH", path, st.lineno,
                f"assert on traced value in {label}()"))
        if isinstance(st, ast.For) and is_tainted(st.iter):
            out.append(Violation(
                "PURITY-BRANCH", path, st.lineno,
                f"Python for over traced value in {label}() — use "
                f"lax.scan/fori_loop"))
        # expression-level checks within this statement
        for node in ast.walk(st):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, ast.IfExp) and test_tainted(node.test):
                out.append(Violation(
                    "PURITY-BRANCH", path, node.lineno,
                    f"ternary on traced value in {label}() — use "
                    f"jnp.where"))
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if len(chain) >= 2 and chain[-2] == "random" \
                    and chain[0] in ("np", "numpy"):
                out.append(Violation(
                    "PURITY-NPRANDOM", path, node.lineno,
                    f"np.random.{chain[-1]} in traced {label}() — use "
                    f"jax.random on an addressed key"))
            elif len(chain) >= 2 and chain[0] in ("time", "datetime") \
                    and chain[-1] in CLOCK_CALLS:
                out.append(Violation(
                    "PURITY-CLOCK", path, node.lineno,
                    f"{'.'.join(chain)} in traced {label}() — wall "
                    f"clock cannot cross into compiled code"))
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                out.append(Violation(
                    "PURITY-ITEM", path, node.lineno,
                    f".item() in traced {label}() — host sync inside "
                    f"the trace"))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int", "bool") \
                    and node.args \
                    and not isinstance(node.args[0], ast.Constant) \
                    and is_tainted(node.args[0]):
                out.append(Violation(
                    "PURITY-COERCE", path, node.lineno,
                    f"{node.func.id}() on traced value in {label}() — "
                    f"host coercion forces a sync"))
    return out, tainted


class _ModuleInfo:
    """One analyzed file: its AST, traced set, and import bindings."""

    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.index = _FuncIndex()
        self.index.visit(tree)
        self.traced = _transitive(_traced_roots(tree, self.index),
                                  self.index)
        # dotted-name parts for suffix matching: src/repro/models/mlp.py
        # -> ("src", "repro", "models", "mlp")
        parts = path.replace("\\", "/").split("/")
        if parts and parts[-1].endswith(".py"):
            parts[-1] = parts[-1][:-3]
        self.parts = tuple(p for p in parts if p not in ("", "."))
        # local name -> dotted module (import a.b as x / from a import b)
        self.mod_aliases: Dict[str, str] = {}
        # local name -> (dotted module, original name) for from-imports
        self.from_names: Dict[str, "Tuple[str, str]"] = {}
        pkg = self.parts[:-1]
        if self.parts and self.parts[-1] == "__init__":
            pkg = self.parts[:-2] + self.parts[-2:-1]
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for al in node.names:
                    if al.asname:
                        self.mod_aliases[al.asname] = al.name
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:       # relative: anchor at this package
                    up = pkg[:len(pkg) - (node.level - 1)] if node.level > 1 \
                        else pkg
                    base = ".".join(up) + ("." + base if base else "")
                for al in node.names:
                    local = al.asname or al.name
                    if al.name == "*":
                        continue
                    # could be a submodule or a name in `base` — record
                    # both; resolution tries module-suffix first
                    self.mod_aliases.setdefault(
                        local, f"{base}.{al.name}" if base else al.name)
                    self.from_names[local] = (base, al.name)

    def top_level_fn(self, name: str) -> Optional[FuncNode]:
        cands = self.index.by_name.get(name, [])
        for f in cands:
            if self.index.parent[f] is None:
                return f
        return cands[0] if cands else None


def _resolve_module(dotted: str, modules: "List[_ModuleInfo]"
                    ) -> Optional[_ModuleInfo]:
    """Find the analyzed file whose path ends with the dotted module
    (``repro.models.attention`` matches src/repro/models/attention.py,
    and a package name matches its ``__init__.py``)."""
    want = tuple(dotted.split("."))
    for m in modules:
        if m.parts[-len(want):] == want:
            return m
        if m.parts[-1] == "__init__" and len(m.parts) > len(want) \
                and m.parts[-len(want) - 1:-1] == want:
            return m
    return None


def _resolve_call(info: _ModuleInfo, call: ast.Call,
                  modules: "List[_ModuleInfo]", _depth: int = 0
                  ) -> "Optional[Tuple[_ModuleInfo, FuncNode]]":
    """Map a call in ``info`` to a function def in another analyzed
    file, following module aliases, from-imports, and (one level)
    package ``__init__`` re-exports."""
    chain = _attr_chain(call.func)
    target: "Optional[Tuple[str, str]]" = None
    if len(chain) >= 2 and chain[0] in info.mod_aliases:
        mod = info.mod_aliases[chain[0]]
        if len(chain) > 2:
            mod = mod + "." + ".".join(chain[1:-1])
        target = (mod, chain[-1])
    elif len(chain) == 1 and chain[0] in info.from_names:
        target = info.from_names[chain[0]]
    if target is None:
        return None
    mod, name = target
    tinfo = _resolve_module(mod, modules)
    if tinfo is None or tinfo is info:
        return None
    fn = tinfo.top_level_fn(name)
    if fn is not None:
        return tinfo, fn
    # package __init__ re-export: follow `from X import name` one level
    if _depth == 0 and name in tinfo.from_names:
        sub, orig = tinfo.from_names[name]
        sinfo = _resolve_module(sub, modules)
        if sinfo is not None and sinfo is not info:
            sfn = sinfo.top_level_fn(orig)
            if sfn is not None:
                return sinfo, sfn
    return None


def _seed_from_call(call: ast.Call, callee: FuncNode,
                    caller_tainted: Set[str]) -> Set[str]:
    """Callee params bound to tainted caller expressions at this site."""
    a = callee.args
    pos = [p.arg for p in list(a.posonlyargs) + list(a.args)]
    seed: Set[str] = set()

    def hot(expr: ast.expr) -> bool:
        return bool(_names_in(expr) & caller_tainted)

    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            if hot(arg.value):      # can't bind positions — taint rest
                seed.update(pos[i:])
            break
        if hot(arg):
            seed.add(pos[i] if i < len(pos)
                     else (a.vararg.arg if a.vararg else pos[-1] if pos
                           else ""))
    kw_ok = set(pos) | {p.arg for p in a.kwonlyargs}
    for kw in call.keywords:
        if kw.arg is None:          # **expansion: conservatively all
            if hot(kw.value):
                seed.update(kw_ok)
        elif hot(kw.value):
            seed.add(kw.arg if kw.arg in kw_ok
                     else (a.kwarg.arg if a.kwarg else kw.arg))
    seed.discard("")
    return seed


def _cross_call_seeds(info: _ModuleInfo, fn: FuncNode, tainted: Set[str],
                      modules: "List[_ModuleInfo]"
                      ) -> "List[Tuple[_ModuleInfo, FuncNode, Set[str]]]":
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        hit = _resolve_call(info, node, modules)
        if hit is None:
            continue
        tinfo, tfn = hit
        out.append((tinfo, tfn, _seed_from_call(node, tfn, tainted)))
    return out


def check_file(path: str, source: Optional[str] = None) -> List[Violation]:
    """Single-file lint (no cross-module closure)."""
    return check_files([path], {path: source} if source is not None
                       else None)


def check_files(paths: Sequence[str],
                sources: Optional[Dict[str, str]] = None
                ) -> List[Violation]:
    out: List[Violation] = []
    modules: List[_ModuleInfo] = []
    for p in paths:
        src = (sources or {}).get(p)
        if src is None:
            src = open(p).read()
        try:
            tree = ast.parse(src, filename=p)
        except SyntaxError as e:
            out.append(Violation("PURITY-PARSE", p, e.lineno or 0,
                                 f"cannot parse: {e.msg}"))
            continue
        modules.append(_ModuleInfo(p, tree))

    # phase 1: per-file roots, full-param taint; collect cross-module
    # call seeds from every traced function's final taint
    seeds: Dict["Tuple[int, int]", Set[str]] = {}
    nodes: Dict["Tuple[int, int]", "Tuple[_ModuleInfo, FuncNode]"] = {}
    work: List["Tuple[int, int]"] = []

    def absorb(edges) -> None:
        for tinfo, tfn, seed in edges:
            if tfn in tinfo.traced:
                continue            # already linted with full taint
            key = (id(tinfo), id(tfn))
            nodes[key] = (tinfo, tfn)
            have = seeds.setdefault(key, set())
            if not have >= seed:
                have |= seed
                if key not in work:
                    work.append(key)

    for info in modules:
        for fn in sorted(info.traced, key=lambda f: f.lineno):
            viols, tainted = _check_traced_fn(fn, info.path, info.traced)
            out.extend(viols)
            absorb(_cross_call_seeds(info, fn, tainted, modules))

    # phase 2: fixpoint over call-site-seeded callees
    cross: Dict["Tuple[int, int]", List[Violation]] = {}
    while work:
        key = work.pop(0)
        tinfo, tfn = nodes[key]
        viols, tainted = _check_traced_fn(
            tfn, tinfo.path, tinfo.traced, seed=seeds[key])
        cross[key] = viols          # replace: seeds only grow
        absorb(_cross_call_seeds(tinfo, tfn, tainted, modules))
    for key in sorted(cross, key=lambda k: (nodes[k][0].path,
                                            nodes[k][1].lineno)):
        out.extend(cross[key])
    return out
