"""Traced-code purity lint (rule family PURITY-*).

JAX traces Python once and replays compiled XLA; anything host-side that
sneaks into a traced function either crashes at trace time, silently
bakes a constant into the executable, or forces a hidden device→host
sync.  This pass statically identifies the repo's TRACED functions and
flags host-world constructs inside them:

  PURITY-NPRANDOM   ``np.random.*`` calls (untraced host randomness —
                    bakes one draw into the compiled code)
  PURITY-CLOCK      ``time.time`` / ``perf_counter`` / ``datetime.now``
  PURITY-ITEM       ``.item()`` (device→host sync inside the trace)
  PURITY-COERCE     ``float(x)`` / ``int(x)`` / ``bool(x)`` on a
                    non-constant (host coercion of a traced value)
  PURITY-BRANCH     Python ``if`` / ``while`` / ``for`` / ``assert``
                    whose condition derives from a traced argument
                    (use ``lax.cond`` / ``jnp.where``; branching on
                    closure constants is fine)

Traced functions are found structurally, not by module reachability —
the engine modules legitimately mix host-side setup (numpy seeds at
construction) with traced closures, so the unit of analysis is the
function:

  * decorated with ``@jax.jit`` / ``@jit`` / ``@partial(jax.jit, ...)``,
  * passed (by name or as a lambda) to a tracing consumer —
    ``jax.jit``, ``vmap``, ``grad``, ``lax.while_loop`` / ``cond`` /
    ``scan`` / ``fori_loop`` / ``switch`` / ``map``, ``pallas_call``,
  * nested inside a known traced-closure factory (``TRACED_MAKERS``:
    the repo convention that everything defined inside ``tick_plan`` /
    ``block_body`` / ``_build_segment`` runs under the jitted tick
    loops),
  * or nested inside / called by name from any of the above
    (same-module transitive closure).

Taint for PURITY-BRANCH is a single forward pass: the traced function's
parameters are tainted, and a name assigned from an expression that
mentions a tainted name becomes tainted.  Closure constants (ring sizes,
dp flags) never taint, so the engines' ``if F > 0:`` staging branches
pass — exactly the static/traced split the device engine is built on.

Deliberate taint exceptions (each is static at trace time):

  * params listed in the jit decorator's ``static_argnames``,
  * config-object params (``cfg`` / ``config`` / ``hparams`` — plain
    dataclasses, never arrays),
  * array *metadata* attributes (``.shape`` / ``.ndim`` / ``.dtype`` /
    ``.size``) and everything derived from them (padding amounts),
  * ``is (not) None`` identity tests and ``in`` dict-membership tests
    on parameter pytrees.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Union

from repro.analysis.base import Violation

#: functions whose nested defs are traced by repo convention: closures
#: they build are installed inside the jitted tick loops / run_block
TRACED_MAKERS = {"tick_plan", "block_body", "_build_segment"}

#: callables whose function-valued arguments get traced
TRACING_CONSUMERS = {"jit", "vmap", "pmap", "grad", "value_and_grad",
                     "while_loop", "cond", "scan", "fori_loop", "switch",
                     "map", "pallas_call", "checkpoint", "remat",
                     "custom_vjp", "custom_jvp"}

CLOCK_CALLS = {"time", "perf_counter", "monotonic", "process_time",
               "now", "clock_gettime"}

#: attribute accesses that yield static trace-time metadata, not traced
#: values — shape-derived padding arithmetic stays untainted
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding",
                "weak_type"}

#: parameter names that are config dataclasses by repo convention —
#: branching on their fields is the static model-family dispatch
CONFIG_PARAMS = {"cfg", "config", "hparams"}

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


def _attr_last(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _attr_chain(node: ast.expr) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_jit_decorator(dec: ast.expr) -> bool:
    if _attr_last(dec) == "jit":
        return True
    if isinstance(dec, ast.Call):
        fn = _attr_last(dec.func)
        if fn == "jit":
            return True
        if fn == "partial" and dec.args \
                and _attr_last(dec.args[0]) == "jit":
            return True
    return False


class _FuncIndex(ast.NodeVisitor):
    """Collect every function def with its parent chain."""

    def __init__(self):
        self.funcs: List[FuncNode] = []
        self.parent: Dict[FuncNode, Optional[FuncNode]] = {}
        self.by_name: Dict[str, List[FuncNode]] = {}
        self._stack: List[FuncNode] = []

    def _enter(self, node: FuncNode) -> None:
        self.funcs.append(node)
        self.parent[node] = self._stack[-1] if self._stack else None
        name = getattr(node, "name", None)
        if name:
            self.by_name.setdefault(name, []).append(node)
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _enter
    visit_AsyncFunctionDef = _enter
    visit_Lambda = _enter


def _traced_roots(tree: ast.Module, index: _FuncIndex) -> Set[FuncNode]:
    roots: Set[FuncNode] = set()
    # decorator-based
    for fn in index.funcs:
        for dec in getattr(fn, "decorator_list", []):
            if _is_jit_decorator(dec):
                roots.add(fn)
        # nested inside a traced-closure factory
        p = index.parent[fn]
        while p is not None:
            if getattr(p, "name", None) in TRACED_MAKERS:
                roots.add(fn)
                break
            p = index.parent[p]
    # consumer-call based: jax.jit(f), lax.cond(p, f, g, ...), vmap(f)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _attr_last(node.func) not in TRACING_CONSUMERS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                roots.add(arg)
            elif isinstance(arg, ast.Name):
                roots.update(index.by_name.get(arg.id, []))
    return roots


def _transitive(roots: Set[FuncNode], index: _FuncIndex) -> Set[FuncNode]:
    """Roots + functions they call by bare name + their nested defs."""
    traced = set(roots)
    frontier = list(roots)
    while frontier:
        fn = frontier.pop()
        for node in ast.walk(fn):
            callee = None
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Name):
                callee = node.func.id
            if callee:
                for cand in index.by_name.get(callee, []):
                    if cand not in traced:
                        traced.add(cand)
                        frontier.append(cand)
            if node is not fn and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node not in traced:
                    traced.add(node)
                    frontier.append(node)
    return traced


def _params(fn: FuncNode) -> Set[str]:
    a = fn.args
    names = [p.arg for p in
             list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n != "self" and n not in CONFIG_PARAMS}


def _static_argnames(fn: FuncNode) -> Set[str]:
    """Param names the jit decorator marks static (trace-time Python)."""
    names: Set[str] = set()
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        f = _attr_last(dec.func)
        if f == "partial" and not (dec.args
                                   and _attr_last(dec.args[0]) == "jit"):
            continue
        if f not in ("jit", "partial"):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) \
                            and isinstance(c.value, str):
                        names.add(c.value)
    return names


def _names_in(expr: ast.expr) -> Set[str]:
    """Names that carry taint — skips static-metadata attribute reads
    (``x.shape`` mentions ``x`` but yields trace-time Python)."""
    out: Set[str] = set()

    def walk(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return
            walk(node.value)
            return
        if isinstance(node, ast.Name):
            out.add(node.id)
            return
        for c in ast.iter_child_nodes(node):
            walk(c)

    walk(expr)
    return out


def _test_is_static(expr: ast.expr) -> bool:
    """True when a branch test is decidable at trace time regardless of
    taint: ``is (not) None`` identity and ``in`` dict-membership checks
    (the repo's optional-arg and params-pytree idioms)."""
    if isinstance(expr, ast.BoolOp):
        return all(_test_is_static(v) for v in expr.values)
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.Not):
        return _test_is_static(expr.operand)
    if isinstance(expr, ast.Compare):
        return all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in expr.ops)
    return False


def _check_traced_fn(fn: FuncNode, path: str,
                     traced: Set[FuncNode]) -> List[Violation]:
    out: List[Violation] = []
    label = getattr(fn, "name", "<lambda>")
    tainted = _params(fn) - _static_argnames(fn)

    def is_tainted(expr: ast.expr) -> bool:
        return bool(_names_in(expr) & tainted)

    def test_tainted(expr: ast.expr) -> bool:
        return not _test_is_static(expr) and is_tainted(expr)

    body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
    stmts: List[ast.stmt] = list(body)
    while stmts:
        st = stmts.pop(0)
        # don't descend into nested defs: they are traced functions of
        # their own (handled separately) with their own parameter taint
        children = [c for c in ast.iter_child_nodes(st)
                    if not isinstance(c, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.Lambda))]
        for node in children:
            if isinstance(node, ast.stmt):
                stmts.append(node)
        # taint propagation
        if isinstance(st, ast.Assign) and is_tainted(st.value):
            for t in st.targets:
                tainted.update(_names_in(t))
        if isinstance(st, (ast.AugAssign, ast.AnnAssign)) \
                and st.value is not None and is_tainted(st.value):
            tainted.update(_names_in(st.target))
        # host-branching on traced values
        if isinstance(st, (ast.If, ast.While)) and test_tainted(st.test):
            out.append(Violation(
                "PURITY-BRANCH", path, st.lineno,
                f"Python {type(st).__name__.lower()} on traced value in "
                f"{label}() — use lax.cond/jnp.where"))
        if isinstance(st, ast.Assert) and test_tainted(st.test):
            out.append(Violation(
                "PURITY-BRANCH", path, st.lineno,
                f"assert on traced value in {label}()"))
        if isinstance(st, ast.For) and is_tainted(st.iter):
            out.append(Violation(
                "PURITY-BRANCH", path, st.lineno,
                f"Python for over traced value in {label}() — use "
                f"lax.scan/fori_loop"))
        # expression-level checks within this statement
        for node in ast.walk(st):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, ast.IfExp) and test_tainted(node.test):
                out.append(Violation(
                    "PURITY-BRANCH", path, node.lineno,
                    f"ternary on traced value in {label}() — use "
                    f"jnp.where"))
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if len(chain) >= 2 and chain[-2] == "random" \
                    and chain[0] in ("np", "numpy"):
                out.append(Violation(
                    "PURITY-NPRANDOM", path, node.lineno,
                    f"np.random.{chain[-1]} in traced {label}() — use "
                    f"jax.random on an addressed key"))
            elif len(chain) >= 2 and chain[0] in ("time", "datetime") \
                    and chain[-1] in CLOCK_CALLS:
                out.append(Violation(
                    "PURITY-CLOCK", path, node.lineno,
                    f"{'.'.join(chain)} in traced {label}() — wall "
                    f"clock cannot cross into compiled code"))
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                out.append(Violation(
                    "PURITY-ITEM", path, node.lineno,
                    f".item() in traced {label}() — host sync inside "
                    f"the trace"))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int", "bool") \
                    and node.args \
                    and not isinstance(node.args[0], ast.Constant) \
                    and is_tainted(node.args[0]):
                out.append(Violation(
                    "PURITY-COERCE", path, node.lineno,
                    f"{node.func.id}() on traced value in {label}() — "
                    f"host coercion forces a sync"))
    return out


def check_file(path: str, source: Optional[str] = None) -> List[Violation]:
    src = source if source is not None else open(path).read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation("PURITY-PARSE", path, e.lineno or 0,
                          f"cannot parse: {e.msg}")]
    index = _FuncIndex()
    index.visit(tree)
    traced = _transitive(_traced_roots(tree, index), index)
    out: List[Violation] = []
    for fn in sorted(traced, key=lambda f: f.lineno):
        out.extend(_check_traced_fn(fn, path, traced))
    return out


def check_files(paths: Sequence[str]) -> List[Violation]:
    out: List[Violation] = []
    for p in paths:
        out.extend(check_file(p))
    return out
