"""Structural completeness checks (rule family STRUCT-*).

PR 6 grew ``DeviceCohortState`` by seven telemetry fields and the
sharding specs had to be extended by hand — the reviewer was the only
check that ``sharding/specs.py`` still covered every field.  This pass
makes that mechanical:

  STRUCT-PSPEC   a ``DeviceCohortState`` field has no PartitionSpec in
                 ``repro.sharding.cohort_pspecs``
  STRUCT-STALE   ``cohort_pspecs`` carries a spec for a field that no
                 longer exists (dead spec — usually a rename half done)
  STRUCT-DTYPE   dtype discipline over a constructed state: every array
                 leaf must be int32 (counters/rings/census — the device
                 engine's whole protocol state is int32 so it lives
                 inside the jitted while_loop without widening) or
                 float32 (model/accumulator blocks); any int64/float64
                 leaf silently breaks host<->device bit parity

The checks introspect the real dataclasses/NamedTuples and a real
(tiny) engine state rather than a hand-maintained mirror list, so they
cannot drift from the code they audit.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Sequence

from repro.analysis.base import Violation

_WHERE = "repro.cohort.state.DeviceCohortState"


def check_state_coverage(fields: Sequence[str],
                         pspecs: Mapping[str, Any],
                         where: str = _WHERE) -> List[Violation]:
    """Pure core: every state field has a spec, every spec has a field."""
    out: List[Violation] = []
    for f in fields:
        if f not in pspecs:
            out.append(Violation(
                "STRUCT-PSPEC", where, 0,
                f"state field {f!r} has no PartitionSpec in "
                f"repro.sharding.cohort_pspecs — the [C, ...] block "
                f"would silently replicate (or crash) on a sharded "
                f"mesh; add it to sharding/specs.py"))
    for f in pspecs:
        if f not in fields:
            out.append(Violation(
                "STRUCT-STALE", where, 0,
                f"cohort_pspecs declares a spec for {f!r}, which is not "
                f"a state field — remove the dead spec"))
    return out


def check_state_dtypes(state_fields: Mapping[str, Any],
                       where: str = _WHERE) -> List[Violation]:
    """Pure core: int32/float32 discipline over realized array leaves."""
    import numpy as np
    out: List[Violation] = []
    for name, leaf in state_fields.items():
        dt = np.dtype(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        if np.issubdtype(dt, np.floating) and dt != np.float32:
            out.append(Violation(
                "STRUCT-DTYPE", where, 0,
                f"field {name!r} is {dt}, want float32 — f64 "
                f"accumulators diverge from the device engine's XLA "
                f"f32 path and break bit parity"))
        elif np.issubdtype(dt, np.integer) and dt != np.int32:
            out.append(Violation(
                "STRUCT-DTYPE", where, 0,
                f"field {name!r} is {dt}, want int32 — the jitted "
                f"while_loop carries every counter as i32; a widened "
                f"counter changes wraparound/census semantics"))
        elif not (np.issubdtype(dt, np.floating)
                  or np.issubdtype(dt, np.integer)):
            out.append(Violation(
                "STRUCT-DTYPE", where, 0,
                f"field {name!r} has non-numeric dtype {dt}"))
    return out


def _tiny_device_state() -> Dict[str, Any]:
    """A real (small) DeviceCohortState, as the engine constructs it."""
    from repro.cohort.device import DeviceCohortEngine
    from repro.cohort.tasks import as_cohort_task
    from repro.core.tasks import LogRegTask
    from repro.data import make_binary_dataset

    X, y = make_binary_dataset(24, 4, seed=0, noise=0.3)
    task = LogRegTask(X, y, l2=0.1, sample_seed=1)
    eng = DeviceCohortEngine(as_cohort_task(task, 4),
                             sizes_per_client=[2],
                             round_stepsizes=[0.1], d=1, seed=0)
    return eng.state._asdict()


def check_cohort_structure() -> List[Violation]:
    """Run both checks against the live repo modules."""
    from repro.cohort.state import DeviceCohortState
    from repro.sharding import cohort_mesh, cohort_pspecs

    pspecs = cohort_pspecs(cohort_mesh(), 8)
    out = check_state_coverage(DeviceCohortState._fields, pspecs)
    if not out:   # dtype pass needs a constructible state
        out.extend(check_state_dtypes(_tiny_device_state()))
    return out
