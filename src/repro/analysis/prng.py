"""PRNG address-space auditor (rule family PRNG-*).

Collects every PRNG key-creation call — ``jax.random.PRNGKey`` and
``numpy.random.default_rng`` in any import spelling — whose seed
expression XORs in a salt, and checks the salt against the central
registry (``repro.analysis.salts``):

  PRNG-UNDECLARED  raw integer salt literal (``PRNGKey(seed ^ 0x5BEED)``)
                   — register it in repro.analysis.salts and import it
  PRNG-UNKNOWN     a ``*_SALT``-style name that is not in the registry
  PRNG-LOCAL       a registered salt name bound locally (assignment or
                   import from somewhere other than the registry) — the
                   value can silently drift from the registry's
  PRNG-SITE        a registered salt key-created in a module outside its
                   declared site list (one salt, two meanings)
  PRNG-COLLISION   two registered salts share a numeric value
                   (from salts.check_registry)

Only XOR-salted roots are audited: unsalted roots (``PRNGKey(seed)``,
``default_rng(seed)``) are the engines' primary chains and are
documented at their definition sites; the registry exists to keep the
*derived* address spaces disjoint.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence

from repro.analysis.base import Violation, module_name
from repro.analysis.salts import REGISTRY

SALTS_MODULE = "repro.analysis.salts"
#: callables whose first argument seeds a PRNG stream
KEY_CREATORS = ("PRNGKey", "default_rng", "RandomState", "seed", "key")
#: of those, bare-name calls we accept only for these names (the rest
#: must be attribute calls like np.random.default_rng to count)
BARE_CREATORS = ("PRNGKey", "default_rng")


def _attr_last(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_key_creation(call: ast.Call) -> bool:
    name = _attr_last(call.func)
    if name is None or not call.args:
        return False
    if isinstance(call.func, ast.Name):
        return name in BARE_CREATORS
    if name in ("PRNGKey", "default_rng", "RandomState"):
        return True
    # np.random.seed(x) / jax.random.key(x)
    if name in ("seed", "key") and isinstance(call.func, ast.Attribute):
        owner = _attr_last(call.func.value)
        return owner == "random"
    return False


def _salt_like(name: str) -> bool:
    return name.isupper() and name.endswith("_SALT")


class _SaltImports(ast.NodeVisitor):
    """Where each registered-salt-looking name is bound in a module."""

    def __init__(self):
        self.origin: Dict[str, str] = {}   # name -> module it came from
        self.local: Dict[str, int] = {}    # name -> assignment line
        self.salts_aliases: List[str] = []  # names bound to the registry
                                            # module itself

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        for a in node.names:
            bound = a.asname or a.name
            if node.module and a.name == "salts" \
                    and node.module + ".salts" == SALTS_MODULE:
                self.salts_aliases.append(bound)
            elif _salt_like(a.name):
                self.origin[bound] = node.module or ""

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            if a.name == SALTS_MODULE:
                self.salts_aliases.append(a.asname or a.name)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if isinstance(t, ast.Name) and _salt_like(t.id):
                self.local[t.id] = node.lineno
        self.generic_visit(node)


def _xor_operands(expr: ast.expr) -> List[ast.BinOp]:
    """All BitXor BinOps anywhere inside ``expr``."""
    return [n for n in ast.walk(expr)
            if isinstance(n, ast.BinOp) and isinstance(n.op, ast.BitXor)]


def check_file(path: str, source: Optional[str] = None) -> List[Violation]:
    src = source if source is not None else open(path).read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation("PRNG-PARSE", path, e.lineno or 0,
                          f"cannot parse: {e.msg}")]
    mod = module_name(path)
    imports = _SaltImports()
    imports.visit(tree)
    out: List[Violation] = []

    def audit_salt_operand(op: ast.expr, line: int) -> None:
        # Attribute access through a registry-module alias is fine
        if isinstance(op, ast.Attribute) and _salt_like(op.attr):
            owner = op.value
            if isinstance(owner, ast.Name) \
                    and owner.id in imports.salts_aliases:
                check_registered(op.attr, line)
            else:
                out.append(Violation(
                    "PRNG-LOCAL", path, line,
                    f"salt {op.attr} accessed through "
                    f"{ast.unparse(owner)}, not the registry module "
                    f"({SALTS_MODULE})"))
            return
        if isinstance(op, ast.Constant) and isinstance(op.value, int):
            out.append(Violation(
                "PRNG-UNDECLARED", path, line,
                f"raw salt literal {op.value:#x} in a PRNG key creation "
                f"— declare it in {SALTS_MODULE} and import it"))
            return
        if isinstance(op, ast.Name) and _salt_like(op.id):
            name = op.id
            if name in imports.local:
                out.append(Violation(
                    "PRNG-LOCAL", path, line,
                    f"salt {name} assigned locally (line "
                    f"{imports.local[name]}) instead of imported from "
                    f"{SALTS_MODULE}"))
                return
            origin = imports.origin.get(name)
            if origin is None and mod != SALTS_MODULE:
                out.append(Violation(
                    "PRNG-UNKNOWN", path, line,
                    f"salt name {name} is not imported in this module"))
                return
            if origin is not None and origin != SALTS_MODULE:
                out.append(Violation(
                    "PRNG-LOCAL", path, line,
                    f"salt {name} imported from {origin}, not from "
                    f"{SALTS_MODULE}"))
                return
            check_registered(name, line)

    def check_registered(name: str, line: int) -> None:
        salt = REGISTRY.get(name)
        if salt is None:
            out.append(Violation(
                "PRNG-UNKNOWN", path, line,
                f"salt name {name} is not declared in {SALTS_MODULE}"))
            return
        if mod not in salt.sites:
            out.append(Violation(
                "PRNG-SITE", path, line,
                f"salt {name} key-created in {mod}, which is not in its "
                f"declared sites {list(salt.sites)} — if this module "
                f"legitimately feeds the same chain, add it to the "
                f"registry entry; otherwise declare a new salt"))

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_key_creation(node)):
            continue
        for xor in _xor_operands(node.args[0]):
            for op in (xor.left, xor.right):
                # the non-salt side is the seed variable; only constants
                # and *_SALT-style names are audited as salts
                if isinstance(op, ast.Constant) \
                        or (_attr_last(op) or "").endswith("_SALT"):
                    audit_salt_operand(op, node.lineno)
    return out


def check_files(paths: Sequence[str]) -> List[Violation]:
    out: List[Violation] = []
    for p in paths:
        out.extend(check_file(p))
    return out
