"""Central PRNG salt registry — the single source of truth for every
``PRNGKey(seed ^ SALT)`` / ``default_rng(seed ^ SALT)`` root in the repo.

The parity contract (host-cohort vs device bitwise under stochastic
latency, churn, and DP) rests on message-addressed threefry chains that
must never collide: two semantically distinct chains keyed off the same
``seed ^ salt`` root would draw correlated randomness, and the bug would
surface only as a statistically-odd trajectory, not as a test failure.
Every salt therefore lives HERE, with its chain semantics and the
modules allowed to key-create with it; ``repro.analysis.prng`` fails the
lint on any XOR-salted key creation that does not import its salt from
this registry, and on any numeric collision between registered salts.

Declaring a salt:

    MY_SALT = _declare("MY_SALT", 0x..., chain="what the chain draws",
                       sites=("repro.my.module",))

and import it at the use site (``from repro.analysis.salts import
MY_SALT``).  ``sites`` lists the modules that may create keys with it —
one semantic chain may legitimately have two roots (the DP-noise chain
is keyed identically by both cohort engines BECAUSE parity requires the
same noise), but a salt showing up in an undeclared module is exactly
the "one salt, two meanings" drift the auditor exists to stop.

This module is imported by ``repro.scenarios`` / ``repro.cohort`` at
engine-import time, so it must stay dependency-free (stdlib only).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Salt:
    name: str
    value: int
    chain: str                 # what the derived key chain draws
    sites: Tuple[str, ...]     # modules allowed to key-create with it


REGISTRY: Dict[str, Salt] = {}


def _declare(name: str, value: int, *, chain: str,
             sites: Tuple[str, ...]) -> int:
    if name in REGISTRY:
        raise ValueError(f"salt {name} declared twice")
    REGISTRY[name] = Salt(name, int(value), chain, tuple(sites))
    return int(value)


# -- scenario chains (repro.scenarios) --------------------------------------
LAT_SALT = _declare(
    "LAT_SALT", 0x1A7E9C,
    chain="message-addressed latency draws: update by (client, round), "
          "broadcast by (k, client) on fold_in branches 0/1",
    sites=("repro.scenarios.registry",))
TABLE_SALT = _declare(
    "TABLE_SALT", 0x7AB1E,
    chain="drawn per-client latency-table assignments: per-client "
          "fold_in uniforms inverted through the weight CDF "
          "(draw_table_ids, jit-rederivable on every host)",
    sites=("repro.scenarios.registry",))
AVAIL_SALT = _declare(
    "AVAIL_SALT", 0xA7A1B,
    chain="availability churn: per-(epoch, client) uniforms for Churn "
          "and the client factor of RegionalChurn",
    sites=("repro.scenarios.availability",))
PHASE_SALT = _declare(
    "PHASE_SALT", 0xD1A7,
    chain="numpy stream for diurnal per-client phase draws",
    sites=("repro.scenarios.availability",))
REGION_SALT = _declare(
    "REGION_SALT", 0x2E610,
    chain="regional-churn shared factor: per-(epoch, region) up-draws",
    sites=("repro.scenarios.availability",))
RENEW_SALT = _declare(
    "RENEW_SALT", 0x9E4A1,
    chain="renewal churn: per-(epoch, client) holding-time draws "
          "(_renewal_epoch_draw), consumed by BOTH the cohort tick "
          "masks and the event sim's renewal windows (path-wise "
          "alignment)",
    sites=("repro.scenarios.availability",))
SPEED_SALT = _declare(
    "SPEED_SALT", 0x5BEED,
    chain="numpy stream for the per-client fleet speed draw "
          "(SpeedModel.draw)",
    sites=("repro.scenarios.availability",))

# -- DP chain (repro.cohort) -------------------------------------------------
# ONE chain, keyed from two modules by design: the host and device
# engines must fold the SAME per-tick noise keys or host-vs-device DP
# parity breaks (tests/test_scenarios.py pins it bitwise).
NOISE_SALT = _declare(
    "NOISE_SALT", 0x5EED,
    chain="round-completion DP noise: fold_in(PRNGKey(seed ^ NOISE_SALT), "
          "tick), shared verbatim by both cohort engines (parity)",
    sites=("repro.cohort.engine", "repro.cohort.device"))


def salt_names() -> List[str]:
    return sorted(REGISTRY)


def check_registry() -> List["Violation"]:  # noqa: F821 (doc type)
    """Registry self-audit: numeric collisions between declared salts.

    (Exact collisions only: distinct salts land in distinct threefry
    key spaces even at hamming distance 1, so near-misses are fine.)
    """
    from repro.analysis.base import Violation
    out: List[Violation] = []
    by_value: Dict[int, List[str]] = {}
    for s in REGISTRY.values():
        by_value.setdefault(s.value, []).append(s.name)
    for value, names in sorted(by_value.items()):
        if len(names) > 1:
            out.append(Violation(
                "PRNG-COLLISION", "<registry>", 0,
                f"salts {sorted(names)} share value {value:#x}"))
    return out
