"""Trace invariant checker (rule family INV-*): model-check protocol
invariants over the JSONL telemetry traces the engines export
(``trace=`` kwarg, see ``repro.telemetry.trace``).

The traces are the protocol's observable behavior; the invariants below
are the properties Theorem 1 / the wait gate / the census contract
guarantee, so a violating trace is a protocol bug regardless of which
engine produced it — the checker is the FAVAS-style posture of reasoning
about staleness and conservation on the trace, not in the engine.

Event-simulator traces (one record per send/apply/broadcast):

  INV-TAU     staleness-at-apply τ = server_k − k_send satisfies
              0 ≤ τ ≤ d − 1 at EVERY apply (the wait gate, Supp. B.2)
  INV-ROUND   round conservation: every completed server round r
              consumed exactly C applied updates with round == r
              (Algorithm 3's H set fills at C, never past it)
  INV-TIME    event times nondecreasing; server_k nondecreasing

Cohort-engine traces (one ``segment`` summary per eval boundary):

  INV-MONO    all cumulative segment counters (round, tick, messages,
              broadcasts, bytes_up_total) nondecreasing, and the
              staleness histogram entrywise nondecreasing
  INV-LATCH   overflow high-water mark is a latch: it never regresses
              across segments, and never exceeds the report's
              ``overflow_slots`` capacity

Profiling layer (PR 9):

  INV-SPAN    op-census discipline: per-segment ``ops`` cost counters
              entrywise nondecreasing (they are cumulative), the final
              report's op census satisfies the ``costs.check_ops``
              relations against the message counts (complete_ticks ≤
              messages, far_ticks ≤ far_groups ≤ far_messages, ...),
              and — via ``check_perfetto`` — exported trace-event
              documents are well-formed with wall-clock slices
              non-overlapping per track

Final ``report`` record (all engines):

  INV-CENSUS  bytes-on-wire census consistent with message counts:
              Σ participation == messages, bytes_up[c] ==
              participation[c] · update_msg_bytes, bytes_down[c] ==
              broadcasts · broadcast_msg_bytes, Σ staleness_hist ≤
              messages, and (given d) all histogram mass sits in bins
              τ ≤ d − 1

``d`` (the paper's broadcast-lag gate) is a run parameter the trace
does not carry; pass it to enable the τ-bound checks.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.analysis.base import Violation

Record = Dict[str, Any]


def read_trace(source: Union[str, Iterable[str]]) -> List[Record]:
    """JSONL path (or iterable of lines) -> list of records."""
    if isinstance(source, str):
        with open(source) as fh:
            lines = fh.readlines()
    else:
        lines = list(source)
    out: List[Record] = []
    for i, ln in enumerate(lines, 1):
        ln = ln.strip()
        if not ln:
            continue
        try:
            rec = json.loads(ln)
        except json.JSONDecodeError as e:
            raise ValueError(f"trace line {i} is not valid JSON: {e}")
        if not isinstance(rec, dict) or "kind" not in rec:
            raise ValueError(f"trace line {i} has no 'kind' field")
        out.append(rec)
    return out


def _v(rule: str, where: str, line: int, msg: str) -> Violation:
    return Violation(rule, where, line, msg)


def check_trace(trace: Union[str, Sequence[Record], Iterable[str]], *,
                d: Optional[int] = None,
                where: str = "<trace>") -> List[Violation]:
    """Model-check one engine trace; returns all violations found."""
    if isinstance(trace, str):
        where = trace
        records = read_trace(trace)
    else:
        records = list(trace)
        if records and isinstance(records[0], str):
            records = read_trace(records)

    out: List[Violation] = []
    report: Optional[Record] = None
    applied_by_round: Dict[int, int] = {}
    n_sent = n_fired = 0
    sent_bytes_total = 0
    last_time: Optional[float] = None
    last_server_k: Optional[int] = None
    prev_seg: Optional[Record] = None

    for i, rec in enumerate(records, 1):
        kind = rec.get("kind")
        # -- event-record family -------------------------------------------
        if kind in ("update_sent", "update_applied", "broadcast_fired",
                    "broadcast_applied"):
            t = rec.get("time")
            if t is not None:
                if last_time is not None and t < last_time:
                    out.append(_v("INV-TIME", where, i,
                                  f"event time regressed: {t} after "
                                  f"{last_time}"))
                last_time = t
        if kind == "update_sent":
            n_sent += 1
            sent_bytes_total += int(rec.get("bytes", 0))
        elif kind == "update_applied":
            tau = rec.get("staleness")
            sk = rec.get("server_k")
            if tau is None or sk is None:
                out.append(_v("INV-TAU", where, i,
                              "update_applied record lacks "
                              "staleness/server_k"))
                continue
            if tau < 0:
                out.append(_v("INV-TAU", where, i,
                              f"negative staleness {tau} (apply from the "
                              f"future: k_send > server_k)"))
            if d is not None and tau > d - 1:
                out.append(_v(
                    "INV-TAU", where, i,
                    f"staleness {tau} exceeds the wait-gate bound "
                    f"d-1={d - 1} at apply (client {rec.get('client')}, "
                    f"round {rec.get('round')})"))
            if last_server_k is not None and sk < last_server_k:
                out.append(_v("INV-TIME", where, i,
                              f"server_k regressed: {sk} after "
                              f"{last_server_k}"))
            last_server_k = sk
            r = rec.get("round")
            if r is not None:
                applied_by_round[int(r)] = \
                    applied_by_round.get(int(r), 0) + 1
        elif kind == "broadcast_fired":
            n_fired += 1
        # -- cohort segment family ------------------------------------------
        elif kind == "segment":
            if prev_seg is not None:
                for fld in ("round", "tick", "time", "messages",
                            "broadcasts", "bytes_up_total"):
                    a, b = prev_seg.get(fld), rec.get(fld)
                    if a is not None and b is not None and b < a:
                        out.append(_v(
                            "INV-MONO", where, i,
                            f"segment counter {fld} regressed: "
                            f"{b} after {a}"))
                ha = prev_seg.get("staleness_hist")
                hb = rec.get("staleness_hist")
                if ha is not None and hb is not None:
                    if len(ha) != len(hb):
                        out.append(_v("INV-MONO", where, i,
                                      "staleness_hist length changed "
                                      "between segments"))
                    elif any(y < x for x, y in zip(ha, hb)):
                        out.append(_v(
                            "INV-MONO", where, i,
                            f"staleness_hist regressed entrywise: "
                            f"{hb} after {ha}"))
                pa = prev_seg.get("ops")
                pb = rec.get("ops")
                if pa is not None and pb is not None:
                    if len(pa) != len(pb):
                        out.append(_v("INV-SPAN", where, i,
                                      "op-census length changed between "
                                      "segments"))
                    elif any(y < x for x, y in zip(pa, pb)):
                        out.append(_v(
                            "INV-SPAN", where, i,
                            f"op-census cost counters regressed "
                            f"entrywise: {pb} after {pa} — they are "
                            f"cumulative by construction"))
                oa = prev_seg.get("overflow_hwm")
                ob = rec.get("overflow_hwm")
                if oa is not None and ob is not None and ob < oa:
                    out.append(_v(
                        "INV-LATCH", where, i,
                        f"overflow_hwm latch regressed: {ob} after {oa} "
                        f"— the high-water mark is monotone by "
                        f"construction"))
            prev_seg = rec
        elif kind == "report":
            report = rec
            out.extend(check_report(rec, d=d, where=where, line=i))

    # -- cross-record checks needing the report -----------------------------
    if report is not None:
        C = report.get("clients")
        rounds = report.get("rounds")
        if applied_by_round and C and rounds is not None:
            for r in range(int(rounds)):
                got = applied_by_round.get(r, 0)
                if got != C:
                    out.append(_v(
                        "INV-ROUND", where, 0,
                        f"round {r} completed with {got} applied "
                        f"updates, want exactly C={C} (Algorithm 3's H "
                        f"fills at C) — an update was double-applied or "
                        f"lost"))
            for r, got in sorted(applied_by_round.items()):
                if r >= int(rounds) and got > C:
                    out.append(_v(
                        "INV-ROUND", where, 0,
                        f"in-flight round {r} already has {got} > C="
                        f"{C} applied updates"))
        if n_sent and report.get("messages") is not None \
                and n_sent != report["messages"]:
            out.append(_v(
                "INV-CENSUS", where, 0,
                f"{n_sent} update_sent records but report.messages="
                f"{report['messages']}"))
        if n_sent and report.get("bytes_up") is not None:
            census = sum(report["bytes_up"])
            if sent_bytes_total != census:
                out.append(_v(
                    "INV-CENSUS", where, 0,
                    f"sum of update_sent bytes {sent_bytes_total} != "
                    f"Σ report.bytes_up {census}"))
        if n_fired and report.get("broadcasts") is not None \
                and n_fired != report["broadcasts"]:
            out.append(_v(
                "INV-CENSUS", where, 0,
                f"{n_fired} broadcast_fired records but "
                f"report.broadcasts={report['broadcasts']}"))
        if prev_seg is not None:
            for fld, rfld in (("messages", "messages"),
                              ("broadcasts", "broadcasts"),
                              ("overflow_hwm", "overflow_hwm")):
                a, b = prev_seg.get(fld), report.get(rfld)
                if a is not None and b is not None and a > b:
                    out.append(_v(
                        "INV-MONO", where, 0,
                        f"final segment {fld}={a} exceeds report "
                        f"{rfld}={b}"))
    return out


def check_report(report: Record, *, d: Optional[int] = None,
                 where: str = "<report>", line: int = 0
                 ) -> List[Violation]:
    """Internal consistency of one MetricsReport record/dict."""
    out: List[Violation] = []
    part = report.get("participation")
    bytes_up = report.get("bytes_up")
    bytes_down = report.get("bytes_down")
    messages = report.get("messages")
    broadcasts = report.get("broadcasts")
    ub = report.get("update_msg_bytes")
    bb = report.get("broadcast_msg_bytes")
    hist = report.get("staleness_hist")
    if part is not None and messages is not None \
            and sum(part) != messages:
        out.append(_v("INV-CENSUS", where, line,
                      f"Σ participation {sum(part)} != messages "
                      f"{messages}"))
    if part is not None and bytes_up is not None and ub is not None:
        for c, (p, b) in enumerate(zip(part, bytes_up)):
            if b != p * ub:
                out.append(_v(
                    "INV-CENSUS", where, line,
                    f"client {c}: bytes_up {b} != participation {p} × "
                    f"update_msg_bytes {ub}"))
    if bytes_down is not None and broadcasts is not None \
            and bb is not None:
        for c, b in enumerate(bytes_down):
            if b != broadcasts * bb:
                out.append(_v(
                    "INV-CENSUS", where, line,
                    f"client {c}: bytes_down {b} != broadcasts "
                    f"{broadcasts} × broadcast_msg_bytes {bb}"))
    if hist is not None:
        if any(x < 0 for x in hist):
            out.append(_v("INV-CENSUS", where, line,
                          f"negative staleness_hist bin: {hist}"))
        if messages is not None and sum(hist) > messages:
            out.append(_v(
                "INV-CENSUS", where, line,
                f"Σ staleness_hist {sum(hist)} > messages {messages} "
                f"(an update was census-applied more than once)"))
        if d is not None and d - 1 < len(hist) - 1:
            extra = sum(hist[d:])
            if extra:
                out.append(_v(
                    "INV-TAU", where, line,
                    f"{extra} applies with staleness >= d={d} in the "
                    f"histogram {hist} — the wait gate bounds τ ≤ "
                    f"d-1={d - 1}"))
    hwm = report.get("overflow_hwm")
    slots = report.get("overflow_slots")
    if hwm is not None and slots:
        if hwm > slots:
            out.append(_v(
                "INV-LATCH", where, line,
                f"overflow_hwm {hwm} exceeds capacity overflow_slots "
                f"{slots} — the err latch should have stopped the run"))
    ops = report.get("ops")
    if ops:
        from repro.telemetry.costs import check_ops
        for problem in check_ops(
                ops, messages=messages, broadcasts=broadcasts,
                far_messages=report.get("far_messages"),
                clients=report.get("clients"),
                ticks=report.get("ticks")):
            out.append(_v("INV-SPAN", where, line, problem))
    return out


def check_perfetto(doc: Union[str, Record], *,
                   where: str = "<perfetto>") -> List[Violation]:
    """INV-SPAN over an exported Chrome/Perfetto trace-event document
    (path or already-parsed dict): well-formed events, and "X" slices
    non-overlapping per (process, track)."""
    if isinstance(doc, str):
        where = doc
        with open(doc) as fh:
            doc = json.load(fh)
    from repro.telemetry.spans import validate_trace_events
    return [_v("INV-SPAN", where, 0, problem)
            for problem in validate_trace_events(doc)]
