"""Shared plumbing for the repo's static-analysis pass: the Violation
record every rule family emits, file collection, and module-path
derivation for site checks.

A ``Violation`` identifies one finding.  Its ``key()`` deliberately
excludes the line number so a baseline file survives unrelated edits
above a suppressed finding; CI runs with an EMPTY baseline — the key
machinery exists for local triage while fixing a newly-introduced rule,
never as a permanent suppression channel.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Iterable, List, Sequence


@dataclass(frozen=True)
class Violation:
    rule: str          # e.g. "PRNG-UNDECLARED"
    path: str          # file as given to the pass (or "<registry>")
    line: int          # 1-based; 0 when not tied to a source line
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def key(self) -> str:
        return f"{self.rule}|{os.path.basename(self.path)}|{self.message}"


def iter_py_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted list of .py files."""
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                out.extend(os.path.join(root, n) for n in names
                           if n.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
        else:
            raise FileNotFoundError(f"not a .py file or directory: {p}")
    return sorted(set(out))


def module_name(path: str) -> str:
    """Dotted module path for site checks: the part of ``path`` from the
    last ``repro`` component on (``.../src/repro/cohort/engine.py`` ->
    ``repro.cohort.engine``); bare stem for paths outside the package."""
    parts = os.path.normpath(path).split(os.sep)
    name = parts[-1][:-3] if parts[-1].endswith(".py") else parts[-1]
    if "repro" in parts[:-1]:
        i = len(parts) - 1 - parts[:-1][::-1].index("repro") - 1
        pkg = parts[i:-1]
        return ".".join(pkg + ([] if name == "__init__" else [name]))
    return name


def load_baseline(path: str) -> List[str]:
    """Baseline file: one ``Violation.key()`` per non-comment line."""
    keys: List[str] = []
    with open(path) as fh:
        for ln in fh:
            ln = ln.strip()
            if ln and not ln.startswith("#"):
                keys.append(ln)
    return keys


def apply_baseline(violations: Iterable[Violation],
                   baseline_keys: Sequence[str]) -> List[Violation]:
    allowed = set(baseline_keys)
    return [v for v in violations if v.key() not in allowed]
