"""fold_in address-chain auditor (rule family PRNG-FOLDIN-*).

Every salt-rooted key (``PRNGKey(seed ^ X_SALT)``) heads an address
chain: each ``jax.random.fold_in(key, addr)`` — direct or through
``jax.vmap(jax.random.fold_in, ...)`` — appends one coordinate to the
chain's address tuple.  Two *different* derivations folded into the
same chain position can alias a key stream; this pass audits the
argument tuples per chain:

  PRNG-FOLDIN-DUP    the same constant folded into one chain at two
                     distinct sites — both derivations alias a single
                     sub-stream
  PRNG-FOLDIN-MIXED  a chain with constant sub-stream branches that is
                     also folded by a runtime variable — the variable
                     can hit a branch constant and collide with it
  PRNG-FOLDIN-VAR    two different variable expressions folded into the
                     same chain — addresses drawn from unrelated
                     domains can coincide

Identical variable expressions folded at several sites are ALLOWED:
the host and device engines derive the same address on purpose (parity
twins), e.g. ``fold_in(self._bc_base, k)`` appearing in both the eager
and the jitted broadcast-draw path.

Chains are tracked per top-level scope (module body, each top-level
function, each class with all its methods): the same salt may
legitimately root chains with different address layouts in different
classes — e.g. AVAIL_SALT is folded by ``t // epoch_t`` in one churn
model and by the epoch index in another — and only same-scope reuse
shares a stream.  Like the PRNG-* audit, only XOR-salted roots are in
scope; unsalted roots are the engines' primary chains and are
documented at their definition sites.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.base import Violation
from repro.analysis.prng import (_attr_last, _is_key_creation, _salt_like,
                                 _xor_operands)

#: a chain identity: (salt name, *address coordinates folded so far)
Chain = Tuple[str, ...]
#: one fold site: (kind "const"|"var", address repr, line)
Site = Tuple[str, str, int]


def _salt_of(call: ast.Call) -> Optional[str]:
    """Salt name if ``call`` is a salt-rooted key creation, else None."""
    if not _is_key_creation(call):
        return None
    for xor in _xor_operands(call.args[0]):
        for op in (xor.left, xor.right):
            name = _attr_last(op)
            if name is not None and _salt_like(name):
                return name
    return None


def _fold_args(call: ast.Call) -> Optional[Tuple[ast.expr, ast.expr]]:
    """(key expr, addr expr) if ``call`` applies fold_in, else None.

    Covers the direct form ``fold_in(key, addr)`` and the vmapped form
    ``vmap(fold_in, ...)(keys, addrs)`` in any import spelling.
    """
    if _attr_last(call.func) == "fold_in" and len(call.args) >= 2:
        return call.args[0], call.args[1]
    if isinstance(call.func, ast.Call) \
            and _attr_last(call.func.func) == "vmap" \
            and call.func.args \
            and _attr_last(call.func.args[0]) == "fold_in" \
            and len(call.args) >= 2:
        return call.args[0], call.args[1]
    return None


def _addr_site(addr: ast.expr, line: int) -> Site:
    if isinstance(addr, ast.Constant):
        return ("const", repr(addr.value), line)
    return ("var", ast.unparse(addr), line)


def _chain_of(expr: ast.expr,
              tracked: Dict[str, Chain]) -> Optional[Chain]:
    """Resolve an expression to the chain it carries, or None.

    Names and attributes resolve through ``tracked``; inline
    ``PRNGKey(seed ^ SALT)`` and inline (possibly nested) fold_in
    calls resolve structurally.
    """
    if isinstance(expr, (ast.Name, ast.Attribute)):
        return tracked.get(ast.unparse(expr))
    if isinstance(expr, ast.Call):
        salt = _salt_of(expr)
        if salt is not None:
            return (salt,)
        fold = _fold_args(expr)
        if fold is not None:
            parent = _chain_of(fold[0], tracked)
            if parent is not None:
                kind, rep, _ = _addr_site(fold[1], expr.lineno)
                return parent + (rep,)
    return None


def _scopes(tree: ast.Module) -> List[List[ast.stmt]]:
    """Top-level scope units: each def/class subtree, plus the rest of
    the module body as one unit.  Nested closures stay with their
    enclosing top-level unit, so a key bound in a factory and folded
    inside the closure it returns resolves within one scope."""
    units: List[List[ast.stmt]] = []
    rest: List[ast.stmt] = []
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            units.append([stmt])
        else:
            rest.append(stmt)
    if rest:
        units.append(rest)
    return units


def _scope_sites(stmts: Sequence[ast.stmt]) -> Dict[Chain, Set[Site]]:
    nodes = [n for s in stmts for n in ast.walk(s)]
    # bind chains to names/attributes, to fixpoint: a derived key's
    # chain may be defined by an assignment seen before its parent's
    tracked: Dict[str, Chain] = {}
    assigns = [n for n in nodes if isinstance(n, ast.Assign)
               and len(n.targets) == 1
               and isinstance(n.targets[0], (ast.Name, ast.Attribute))]
    changed = True
    while changed:
        changed = False
        for a in assigns:
            target = ast.unparse(a.targets[0])
            if target in tracked:
                continue
            chain = _chain_of(a.value, tracked)
            if chain is not None:
                tracked[target] = chain
                changed = True
    sites: Dict[Chain, Set[Site]] = {}
    for n in nodes:
        if not isinstance(n, ast.Call):
            continue
        fold = _fold_args(n)
        if fold is None:
            continue
        chain = _chain_of(fold[0], tracked)
        if chain is None:
            continue
        sites.setdefault(chain, set()).add(_addr_site(fold[1], n.lineno))
    return sites


def _audit_chain(path: str, chain: Chain,
                 sites: Set[Site]) -> List[Violation]:
    out: List[Violation] = []
    label = " -> ".join(chain)
    consts: Dict[str, List[int]] = {}
    var_reps: Dict[str, List[int]] = {}
    for kind, rep, line in sites:
        (consts if kind == "const" else var_reps).setdefault(
            rep, []).append(line)
    for rep, lines in sorted(consts.items()):
        if len(set(lines)) > 1:
            lo, hi = min(lines), max(lines)
            out.append(Violation(
                "PRNG-FOLDIN-DUP", path, hi,
                f"constant {rep} folded into chain [{label}] at lines "
                f"{lo} and {hi} — both derivations alias one key "
                f"stream; give each branch its own constant"))
    if consts and var_reps:
        rep, lines = sorted(var_reps.items())[0]
        out.append(Violation(
            "PRNG-FOLDIN-MIXED", path, min(lines),
            f"chain [{label}] has constant sub-stream branch(es) "
            f"{sorted(consts)} but is also folded by variable {rep} — "
            f"a runtime address equal to a branch constant collides; "
            f"fold the variable on a dedicated constant branch"))
    if len(var_reps) > 1:
        (rep_a, lines_a), (rep_b, lines_b) = sorted(var_reps.items())[:2]
        out.append(Violation(
            "PRNG-FOLDIN-VAR", path, max(min(lines_a), min(lines_b)),
            f"chain [{label}] folded by two different variable "
            f"expressions, {rep_a} (line {min(lines_a)}) and {rep_b} "
            f"(line {min(lines_b)}) — addresses from unrelated domains "
            f"can coincide; branch the chain by constants first"))
    return out


def check_file(path: str, source: Optional[str] = None) -> List[Violation]:
    src = source if source is not None else open(path).read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError:
        return []   # prng.check_file already reports PRNG-PARSE
    out: List[Violation] = []
    for stmts in _scopes(tree):
        for chain, sites in sorted(_scope_sites(stmts).items()):
            out.extend(_audit_chain(path, chain, sites))
    return out


def check_files(paths: Sequence[str]) -> List[Violation]:
    out: List[Violation] = []
    for p in paths:
        out.extend(check_file(p))
    return out
