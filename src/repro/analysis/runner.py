"""Orchestration for ``python -m repro.analysis``: run every rule family
over a set of paths, apply the (normally empty) baseline, and report.

Rule families:
  * PRNG-*    salt-registry audit of PRNG key creations (AST)
  * PRNG-FOLDIN-*  fold_in argument-tuple discipline per salt chain
              (duplicate constants, const/variable mixing,
              conflicting variable addresses — AST)
  * PURITY-*  host-world constructs inside traced functions (AST)
  * STRUCT-*  DeviceCohortState vs sharding-spec completeness + dtype
              discipline (introspection; needs the repro package
              importable — skipped with ``structure=False``)
  * INV-*     protocol invariants over a JSONL telemetry trace
              (only when ``trace=`` is given)
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.analysis.base import (Violation, apply_baseline, iter_py_files,
                                 load_baseline)


def run_analysis(paths: Sequence[str], *,
                 baseline: Optional[str] = None,
                 structure: bool = True,
                 trace: Optional[str] = None,
                 trace_d: Optional[int] = None,
                 ) -> Tuple[List[Violation], List[Violation]]:
    """-> (all violations, violations remaining after the baseline)."""
    from repro.analysis import (foldin, invariants, prng, purity, salts,
                                structure as structure_mod)

    files = iter_py_files(paths) if paths else []
    violations: List[Violation] = []
    violations.extend(salts.check_registry())
    violations.extend(prng.check_files(files))
    violations.extend(foldin.check_files(files))
    violations.extend(purity.check_files(files))
    if structure:
        violations.extend(structure_mod.check_cohort_structure())
    if trace is not None:
        violations.extend(invariants.check_trace(trace, d=trace_d))
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    keys = load_baseline(baseline) if baseline else []
    return violations, apply_baseline(violations, keys)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Parity sanitizer: PRNG salt audit, sharding "
                    "completeness, traced-code purity, and protocol "
                    "trace invariants.")
    ap.add_argument("paths", nargs="*",
                    help=".py files or directories to lint "
                         "(e.g. src/repro)")
    ap.add_argument("--baseline", default=None,
                    help="file of Violation keys to tolerate "
                         "(CI ships an empty one)")
    ap.add_argument("--no-structure", action="store_true",
                    help="skip the DeviceCohortState/sharding "
                         "introspection checks")
    ap.add_argument("--trace", default=None,
                    help="also model-check a JSONL telemetry trace")
    ap.add_argument("--d", type=int, default=None, dest="trace_d",
                    help="the run's broadcast-lag gate d, enabling the "
                         "τ ≤ d-1 trace checks")
    ap.add_argument("--list-salts", action="store_true",
                    help="print the salt registry and exit")
    args = ap.parse_args(argv)

    if args.list_salts:
        from repro.analysis.salts import REGISTRY
        for s in sorted(REGISTRY.values(), key=lambda s: s.value):
            print(f"{s.value:#10x}  {s.name:<12} {s.chain}")
            for site in s.sites:
                print(f"{'':12}  {'':<12} site: {site}")
        return 0

    if not args.paths and args.trace is None:
        ap.error("give at least one path to lint (or --trace/"
                 "--list-salts)")

    all_v, new_v = run_analysis(
        args.paths, baseline=args.baseline,
        structure=not args.no_structure,
        trace=args.trace, trace_d=args.trace_d)
    for v in new_v:
        print(v.format())
    suppressed = len(all_v) - len(new_v)
    if suppressed:
        print(f"({suppressed} baselined finding(s) suppressed)")
    if new_v:
        print(f"FAILED: {len(new_v)} finding(s)")
        return 1
    print(f"OK: {len(iter_py_files(args.paths)) if args.paths else 0} "
          f"file(s) clean")
    return 0
