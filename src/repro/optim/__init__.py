from repro.optim.sgd import SGD, AdamState, AdamW, SGDState

__all__ = ["SGD", "AdamState", "AdamW", "SGDState"]
