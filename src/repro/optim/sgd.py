"""Optimizers (pure JAX pytree transforms; no optax dependency).

The paper's server update is plain SGD with round step sizes; momentum and
AdamW are provided for the non-convex architectures (§C.3 regime).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: Optional[Any]


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


@dataclass(frozen=True)
class SGD:
    momentum: float = 0.0
    nesterov: bool = False

    def init(self, params) -> SGDState:
        if self.momentum == 0.0:
            return SGDState(momentum=None)
        return SGDState(momentum=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(self, grads, state: SGDState, params, lr
               ) -> Tuple[Any, SGDState]:
        if state.momentum is None:
            new_params = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, state
        m = jax.tree_util.tree_map(
            lambda mm, g: self.momentum * mm + g.astype(jnp.float32),
            state.momentum, grads)
        upd = m
        if self.nesterov:
            upd = jax.tree_util.tree_map(
                lambda mm, g: self.momentum * mm + g.astype(jnp.float32),
                m, grads)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
            params, upd)
        return new_params, SGDState(momentum=m)


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
            count=jnp.zeros((), jnp.int32))

    def update(self, grads, state: AdamState, params, lr
               ) -> Tuple[Any, AdamState]:
        count = state.count + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        mu = jax.tree_util.tree_map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: self.b2 * v
            + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)

        def upd(p, m, v):
            step = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamState(mu=mu, nu=nu, count=count)
