"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000;
local+global alternating sliding window, logit softcap.  [arXiv:2408.00118]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        activation="geglu",
        norm="rmsnorm",
        post_attn_norm=True,
        embed_scale=True,
        sliding_window=4096,
        local_global_period=2,   # alternate local/global
        attn_softcap=50.0,
        logit_softcap=30.0,
        tie_embeddings=True,
        source="[arXiv:2408.00118]",
    )
