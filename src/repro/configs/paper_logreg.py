"""The paper's own experiment model: (strongly-)convex logistic regression
trained by asynchronous FL (Section 4 / Supp. E).  Not an assigned arch —
this is the faithful-reproduction config.
"""
from repro.configs.base import (DPConfig, FLConfig, ModelConfig,
                                SampleSequenceConfig, StepSizeConfig)


def config(d_features: int = 64) -> ModelConfig:
    # Represented as a degenerate "dense" model: a single linear layer is
    # handled by repro.models.logreg, keyed on family == "logreg".
    return ModelConfig(
        arch_id="paper-logreg",
        family="logreg",
        n_layers=1,
        d_model=d_features,
        vocab_size=2,
        source="[paper §4, Supp. E: LIBSVM binary / MNIST subsets]",
    )


def fl_config_fig1a() -> FLConfig:
    """Fig 1a: strongly convex, eta0=0.1, linear increasing sample sizes."""
    return FLConfig(
        n_clients=5,
        sample_seq=SampleSequenceConfig(kind="linear", s0=50, a=50.0),
        step_size=StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001,
                                 round_transform=True),
        total_grads=20_000,
    )


def fl_config_fig1b() -> FLConfig:
    """Fig 1b / Example 3: DP, sigma=8, s_i = 16 + ceil(1.322 i), K=25000."""
    return FLConfig(
        n_clients=5,
        sample_seq=SampleSequenceConfig(kind="power", s0=16, p=1.0,
                                        q=0.00013216327772100012,
                                        m=12.106237281566509, N_c=10_000),
        step_size=StepSizeConfig(kind="inv_t", eta0=0.15, beta=0.001,
                                 round_transform=True),
        dp=DPConfig(enabled=True, clip_norm=0.1, sigma=8.0,
                    granularity="example", delta=5.5e-8, epsilon=1.0),
        total_grads=25_000,
    )
