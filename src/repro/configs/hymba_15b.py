"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
vocab=32001, ssm_state=16; parallel attention+mamba heads in each layer;
sliding window on all but 3 global layers (first/middle/last).
[arXiv:2411.13676]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        activation="silu",
        norm="rmsnorm",
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv_width=4,
        ssm_chunk=128,
        sliding_window=1024,
        global_layers=(0, 15, 31),
        source="[arXiv:2411.13676]",
    )
