from repro.configs.base import (DPConfig, FLConfig, INPUT_SHAPES, ModelConfig,
                                RunConfig, SampleSequenceConfig, ShapeConfig,
                                StepSizeConfig, reduced)
from repro.configs.registry import ASSIGNED_ARCHS, get_config, list_archs

__all__ = [
    "DPConfig", "FLConfig", "INPUT_SHAPES", "ModelConfig", "RunConfig",
    "SampleSequenceConfig", "ShapeConfig", "StepSizeConfig", "reduced",
    "ASSIGNED_ARCHS", "get_config", "list_archs",
]
