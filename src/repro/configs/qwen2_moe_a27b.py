"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) per-expert d_ff=1408,
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=0,
        vocab_size=151936,
        qkv_bias=True,
        activation="silu",
        norm="rmsnorm",
        n_experts=60,
        n_shared_experts=4,
        moe_top_k=4,
        moe_d_ff=1408,
        router_aux_coef=0.001,
        source="[hf:Qwen/Qwen1.5-MoE-A2.7B]",
    )
