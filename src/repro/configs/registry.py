"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs.base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, fn: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = fn


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    return sorted(_REGISTRY)


def _populate() -> None:
    from repro.configs import (chameleon_34b, gemma2_2b, gemma_2b, grok1_314b,
                               hymba_15b, mamba2_780m, minitron_8b,
                               paper_logreg, qwen15_32b, qwen2_moe_a27b,
                               whisper_large_v3)
    register("qwen1.5-32b", qwen15_32b.config)
    register("whisper-large-v3", whisper_large_v3.config)
    register("chameleon-34b", chameleon_34b.config)
    register("mamba2-780m", mamba2_780m.config)
    register("gemma2-2b", gemma2_2b.config)
    register("hymba-1.5b", hymba_15b.config)
    register("gemma-2b", gemma_2b.config)
    register("minitron-8b", minitron_8b.config)
    register("qwen2-moe-a2.7b", qwen2_moe_a27b.config)
    register("grok-1-314b", grok1_314b.config)
    register("paper-logreg", paper_logreg.config)


_populate()

ASSIGNED_ARCHS = [
    "qwen1.5-32b", "whisper-large-v3", "chameleon-34b", "mamba2-780m",
    "gemma2-2b", "hymba-1.5b", "gemma-2b", "minitron-8b",
    "qwen2-moe-a2.7b", "grok-1-314b",
]
