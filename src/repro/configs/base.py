"""Config dataclasses for architectures, input shapes, FL protocol, and runs.

Every assigned architecture gets one module in ``repro/configs`` exporting
``config() -> ModelConfig`` with the exact dimensions from the assignment
table (source cited in the module docstring).  ``reduced()`` produces the
CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description consumed by ``repro.models``.

    Only the transformer/SSM backbone is described; modality frontends
    (audio conv stack, ViT) are stubs per the assignment carve-out.
    """

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    n_layers: int
    d_model: int
    vocab_size: int

    # Attention (unused for family == "ssm")
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: Optional[float] = None       # gemma2 / grok soft-capping
    sliding_window: Optional[int] = None       # window size for local layers
    local_global_period: Optional[int] = None  # e.g. 2 => alternate local/global
    global_layers: Tuple[int, ...] = ()        # explicit global-attn layers (hymba)

    # MLP
    d_ff: int = 0
    activation: str = "silu"                   # silu (swiglu) | geglu | gelu
    mlp_bias: bool = False

    # Output
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = False

    # Norm
    norm: str = "rmsnorm"                      # rmsnorm | layernorm
    norm_eps: float = 1e-6
    post_attn_norm: bool = False               # gemma2 style post-norms
    embed_scale: bool = False                  # gemma multiplies embeds by sqrt(d)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                          # per-expert hidden dim
    router_aux_coef: float = 0.01

    # SSM (mamba2 SSD) — also used by hybrid heads
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # Encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0                   # frame embeddings from the stub
    cross_attention: bool = False

    # VLM (chameleon) — early fusion, VQ image tokens share the vocab
    image_token_span: int = 0                  # tokens per image (stub metadata)

    source: str = ""                           # citation, e.g. [arXiv:xxxx]

    # ---- derived -----------------------------------------------------------
    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def layer_is_local(self, layer_idx: int) -> bool:
        """True if layer uses sliding-window attention."""
        if self.sliding_window is None:
            return False
        if self.global_layers:
            return layer_idx not in self.global_layers
        if self.local_global_period:
            # gemma2 pattern: local first, then global (local on even idx)
            return (layer_idx % self.local_global_period) != (
                self.local_global_period - 1)
        return True

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode => eligible for long_500k."""
        if self.family in ("ssm", "hybrid"):
            return True
        # dense archs qualify only with a sliding-window variant
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + backbone)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d            # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d        # unembed
        per_layer = 0
        if self.family != "ssm":
            q = self.n_heads * self.head_dim
            kv = self.n_kv_heads * self.head_dim
            per_layer += d * q + 2 * d * kv + q * d   # qkvo
            if self.qkv_bias:
                per_layer += q + 2 * kv
        if self.family in ("ssm", "hybrid"):
            di = self.ssm_d_inner
            per_layer += d * (2 * di + 2 * self.ssm_n_heads * self.ssm_state) \
                + di * d + di * self.ssm_conv_width + 2 * self.ssm_n_heads
        if self.n_experts:
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
            per_layer += self.n_shared_experts * 3 * d * self.moe_d_ff
            per_layer += d * self.n_experts  # router
        elif self.d_ff:
            mult = 3 if self.activation in ("silu", "geglu") else 2
            per_layer += mult * d * self.d_ff
        per_layer += 2 * d                   # norms
        n += self.n_layers * per_layer
        if self.cross_attention:
            q = self.n_heads * self.head_dim
            kv = self.n_kv_heads * self.head_dim
            n += self.n_layers * (d * q + 2 * d * kv + q * d)
            # encoder stack
            enc_per = 4 * d * self.head_dim * self.n_heads + 2 * d * self.d_ff
            n += self.n_encoder_layers * enc_per
        return n


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    d_model = min(cfg.d_model, d_model)
    head_dim = 32
    n_heads = max(2, min(4, cfg.n_heads)) if cfg.n_heads else 0
    n_kv = max(1, min(n_heads, max(1, cfg.n_kv_heads * n_heads
                                   // max(cfg.n_heads, 1)))) if n_heads else 0
    upd = dict(
        n_layers=min(cfg.n_layers, n_layers),
        d_model=d_model,
        vocab_size=min(cfg.vocab_size, vocab),
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim if n_heads else 0,
        d_ff=min(cfg.d_ff, 4 * d_model) if cfg.d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=32,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_d_ff=min(cfg.moe_d_ff, 2 * d_model) if cfg.moe_d_ff else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 64) if cfg.encoder_seq_len else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        global_layers=tuple(g for g in cfg.global_layers if g < n_layers) or (
            (0,) if cfg.global_layers else ()),
    )
    return dataclasses.replace(cfg, **upd)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# FL protocol configuration (the paper's knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SampleSequenceConfig:
    """Sample-size sequence s_i.

    kinds:
      constant:   s_i = s0
      linear:     s_i = s0 + ceil(a * i)                     (Θ(i), paper E.2.2)
      power:      s_i = ceil(N_c * q * (i + m)^p)            (Theorem 4 form)
      ilog:       s_i = ceil((m+i+1)/(16 (d+1)^2 ln((m+i+1)/(2(d+1)))))  (Thm 5)
    """
    kind: str = "linear"
    s0: int = 16
    a: float = 1.0
    p: float = 1.0
    m: float = 0.0
    q: float = 0.0
    N_c: int = 0
    d: int = 1  # permissible-delay slack (condition (3))


@dataclass(frozen=True)
class StepSizeConfig:
    """eta_t schemes from the paper's experiments + Lemma 2 round transform.

    kinds: constant | inv_t (eta0/(1+beta t)) | inv_sqrt (eta0/(1+beta sqrt t))
           | theorem5 (12/(mu (t + E_t)))
    round_transform: use round step sizes eta_bar_i = eta_{t(i)} (diminishing_2)
    """
    kind: str = "inv_t"
    eta0: float = 0.1
    beta: float = 0.001
    mu: float = 0.0
    round_transform: bool = True


@dataclass(frozen=True)
class DPConfig:
    enabled: bool = False
    clip_norm: float = 0.1
    sigma: float = 8.0
    granularity: str = "example"  # example | client
    delta: float = 1e-6
    epsilon: float = 0.0          # target (0 => derived)


@dataclass(frozen=True)
class FLConfig:
    n_clients: int = 5
    client_weights: Optional[Tuple[float, ...]] = None  # p_c, default uniform
    sample_seq: SampleSequenceConfig = field(default_factory=SampleSequenceConfig)
    step_size: StepSizeConfig = field(default_factory=StepSizeConfig)
    dp: DPConfig = field(default_factory=DPConfig)
    d: int = 1                    # gate i <= k + d
    total_grads: int = 20_000     # K
    seed: int = 0
    engine: str = "event"         # event (repro.core.simulator) |
    #                               cohort (repro.cohort, batched, host
    #                               tick loop) | device (repro.cohort,
    #                               jitted on-device tick loop)
    cohort_block: int = 64        # iteration credit per cohort tick
    scenario: Optional[Any] = None  # repro.scenarios preset name
    #                               (uniform | mobile_diurnal |
    #                               iot_straggler | geo_regional |
    #                               sensor_renewal | registered) or a
    #                               frozen Scenario instance (per-client
    #                               latency tables, regional/renewal
    #                               churn, ring_cap); None keeps each
    #                               engine's legacy default network
    aggregation: Optional[Any] = None  # repro.core.strategies spec:
    #                               None keeps the paper's apply-on-
    #                               dequeue server; "fedasync"/"fedbuff"
    #                               (or a strategy instance / {"kind":
    #                               ...} dict) select the zoo, accepted
    #                               by all three engines


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    fl: FLConfig = field(default_factory=FLConfig)
    shape: str = "train_4k"
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    use_pallas: bool = False      # kernels validated in interpret mode only
