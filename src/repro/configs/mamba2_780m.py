"""mamba2-780m [ssm] — 48L d_model=1536 attention-free, vocab=50280,
ssm_state=128; SSD (state-space duality).  [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv_width=4,
        ssm_chunk=128,
        norm="rmsnorm",
        tie_embeddings=True,
        source="[arXiv:2405.21060]",
    )
