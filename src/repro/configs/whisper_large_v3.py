"""whisper-large-v3 [audio] — 32L decoder, d_model=1280 20H (kv=20) d_ff=5120
vocab=51866; encoder-decoder with conv/mel frontend STUB (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-large-v3",
        family="encdec",
        n_layers=32,             # decoder layers
        n_encoder_layers=32,
        encoder_seq_len=1500,    # mel frames after conv stub
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        qkv_bias=True,           # whisper uses biases
        mlp_bias=True,
        activation="gelu",
        norm="layernorm",
        norm_eps=1e-5,
        cross_attention=True,
        rope_theta=0.0,          # whisper uses learned positions; we use sinusoidal stub
        source="[arXiv:2212.04356]",
    )
