"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early fusion: VQ image tokens share the text vocabulary, so the frontend stub
is the VQ tokenizer — input_specs provides interleaved discrete tokens plus a
modality mask.  [arXiv:2405.09818]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        activation="silu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        image_token_span=1024,   # VQ tokens per image (stub metadata)
        source="[arXiv:2405.09818]",
    )
