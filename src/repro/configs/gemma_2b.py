"""gemma-2b [dense] — 18L d_model=2048 8H head_dim=256 (MQA kv=1)
d_ff=16384 vocab=256000; GeGLU.  [arXiv:2403.08295]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        activation="geglu",
        norm="rmsnorm",
        embed_scale=True,
        tie_embeddings=True,
        source="[arXiv:2403.08295]",
    )
