"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000; pruned nemotron.  [arXiv:2407.14679]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256000,
        activation="silu",   # nemotron uses squared-relu; silu variant kept simple
        norm="rmsnorm",
        source="[arXiv:2407.14679]",
    )
