"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, 8 experts top-2.  [hf:xai-org/grok-1]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=0,
        vocab_size=131072,
        activation="gelu",
        norm="rmsnorm",
        attn_softcap=30.0,
        logit_softcap=30.0,
        n_experts=8,
        n_shared_experts=0,
        moe_top_k=2,
        moe_d_ff=32768,
        router_aux_coef=0.001,
        source="[hf:xai-org/grok-1]",
    )
