# Vectorized cohort engine: the async FL protocol (Algorithms 1-4) over a
# batched client population — stacked [C, D] state, one vmapped scan per
# tick, segment-sum server aggregation, fused Pallas clip+noise at round
# completion (kernels/cohort_dp).
from repro.cohort.engine import CohortEngine
from repro.cohort.simulator import CohortSimulator, make_simulator
from repro.cohort.state import BroadcastRing, CohortState, UpdateBuckets
from repro.cohort.tasks import CohortLogRegTask, as_cohort_task

__all__ = [
    "CohortEngine", "CohortSimulator", "make_simulator",
    "CohortState", "UpdateBuckets", "BroadcastRing",
    "CohortLogRegTask", "as_cohort_task",
]
