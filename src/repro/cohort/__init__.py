# Vectorized cohort engines: the async FL protocol (Algorithms 1-4) over
# a batched client population — stacked [C, D] state, one vmapped scan
# per tick, segment-sum server aggregation, fused Pallas clip+noise at
# round completion (kernels/cohort_dp).  Two implementations: the
# host-loop engine (engine.py, Python control flow per tick) and the
# device-resident engine (device.py, one jitted lax.while_loop, host
# sync only at eval boundaries).
from repro.cohort.device import DeviceCohortEngine
from repro.cohort.engine import CohortEngine
from repro.cohort.flat import CohortBatchModelTask, PyTreeFlattener
from repro.cohort.simulator import (CohortSimulator, DeviceCohortSimulator,
                                    make_simulator)
from repro.cohort.state import (BroadcastRing, CohortState,
                                DeviceCohortState, UpdateBuckets)
from repro.cohort.tasks import CohortLogRegTask, as_cohort_task

__all__ = [
    "CohortEngine", "DeviceCohortEngine",
    "CohortSimulator", "DeviceCohortSimulator", "make_simulator",
    "CohortState", "DeviceCohortState", "UpdateBuckets", "BroadcastRing",
    "CohortLogRegTask", "CohortBatchModelTask", "PyTreeFlattener",
    "as_cohort_task",
]
