"""Tick-driven cohort engine: Algorithms 1–4 over stacked client state.

Virtual time is quantized into ticks of dt = block / max(speed).  Each
tick every unblocked client earns ``speed * dt`` iteration credit and the
whole population advances in ONE vmapped scan (``CohortTask.run_block``)
— the per-client Python objects and heapq of ``repro.core.simulator``
become a handful of [C, D] array ops, which is what makes thousands of
clients per process feasible.

Ordering within a tick mirrors the event simulator:
  1. the batched server applies the arrival bucket for this tick
     (one pre-weighted [D] vector — segment-sum over the finishing
     cohort instead of C sequential tree_maps), updates the H counts,
     and fires broadcasts for every round that just completed;
  2. due broadcasts are ISRRECEIVE'd in ascending k with a masked
     where(): w ← v̂ − eta_i · U for clients whose freshest-seen k
     increases (stale broadcasts drop out per client, exactly
     Algorithm 4's guard);
  3. the cohort advances: n_c = min(remaining, floor(credit)) masked
     iterations per client, wait-gated clients (i == k + d) excluded;
  4. finishing clients clip/noise their round update with the fused
     ``kernels/cohort_dp`` kernel, their eta-weighted updates are
     bucket-summed by (latency-quantized) arrival tick, and they advance
     to the next round.

Fidelity: with d = 1 broadcasts only ever reach blocked clients (U = 0,
so ISRRECEIVE is an exact model replacement) and trajectories match the
event simulator bit-for-bit given a ``sample_seed`` task — the parity
test pins this.  With d > 1, latency quantization reorders same-tick
arrivals; every such schedule is one the asynchronous protocol admits,
so Theorem 1's guarantees still apply, but traces are not message-level
identical to the event engine.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.salts import NOISE_SALT
from repro.cohort.state import (FRAC_BITS, BroadcastRing, CohortState,
                                UpdateBuckets, default_max_ticks,
                                next_pow2, pad_sizes, speed_accrual)
from repro.core.strategies import get_strategy, ring_decay
from repro.kernels.cohort_dp import cohort_clip_noise
from repro.scenarios import get_scenario, scenario_plan
from repro.telemetry import (STALE_BINS, PhaseTimer, build_report,
                             open_trace, staleness_bin, update_msg_bytes)
from repro.telemetry.costs import (OP_BLOCK_TICKS, OP_BUCKET_APPLIES,
                                   OP_CASCADE_TICKS, OP_COMPLETE_TICKS,
                                   OP_DELIVER_ROWS, OP_DELIVER_TICKS,
                                   OP_FAR_GROUPS, OP_FAR_TICKS,
                                   OP_RING_SCATTERS, OP_TICKS, zero_ops)


def _commit(x, dtype=None):
    """Explicit host->device transfer of a host value.

    The steady-segment ticks run under ``jax.transfer_guard("disallow")``
    (parity with ``DeviceCohortEngine.run``), where a dtype-converting
    ``jnp.asarray`` counts as an IMPLICIT transfer and raises; numpy does
    the conversion (IEEE round-to-nearest, bit-identical to XLA's
    convert_element_type) and ``device_put`` commits it explicitly.
    """
    return jax.device_put(np.asarray(x, dtype))


@jax.jit
def _isr_receive(w, U, v, eta, take):
    """Masked Algorithm 4 ISRRECEIVE: w ← v̂ − eta_i · U on take rows."""
    return jnp.where(take[:, None], v[None, :] - eta[:, None] * U, w)


@jax.jit
def _weighted_sum(rows, wgt):
    return jnp.sum(rows * wgt[:, None], axis=0)


@jax.jit
def _apply_contrib(v, contrib):
    return v - contrib


@jax.jit
def _zero_rows(rows, mask):
    return jnp.where(mask[:, None], 0.0, rows)


@jax.jit
def _add_scaled_rows(w, delta, eta, mask):
    """w += eta * delta on masked rows (client-side noise consistency)."""
    return w + jnp.where(mask[:, None], eta[:, None] * delta, 0.0)


def _make_strat_apply(strategy, R: int):
    """Stratified (FedAsync) apply: decay each sender-k row of the
    [R, D] bucket by its staleness against the pre-cascade server_k.
    The device engine consumes the SAME ``ring_decay`` weights (as the
    fused bucket-apply kernel's operand), so the two engines' decayed
    sums are bitwise equal."""
    @jax.jit
    def apply(v, total, server_k):
        dec = ring_decay(strategy, server_k, R)
        return v - jnp.sum(total * dec[:, None], axis=0)
    return apply


def _make_strat_insert(R: int):
    """Stratified bucket insert: merge one finishing group into an
    [R, D] sender-k bucket row-by-row with the device engine's exact
    masked-sum + guarded-add expression (rows with no arrivals keep
    their old value bitwise, not old + 0)."""
    @jax.jit
    def insert(cur, sent, eta, in_g, kmod):
        for r in range(R):
            in_r = in_g & (kmod == r)
            vec = jnp.sum(
                sent * (eta * in_r.astype(jnp.float32))[:, None], axis=0)
            cur = cur.at[r].set(
                jnp.where(jnp.any(in_r), cur[r] + vec, cur[r]))
        return cur
    return insert


class CohortEngine:
    def __init__(self, ctask, *, sizes_per_client,
                 round_stepsizes: Sequence[float], d: int = 1,
                 speeds: Optional[Sequence[float]] = None,
                 latency_fn: Optional[Callable] = None, seed: int = 0,
                 block: int = 64, dp_sigma: float = 0.0,
                 dp_clip: float = 0.0, dp_round_clip: float = 0.0,
                 use_dp_kernel: bool = True,
                 interpret: Optional[bool] = None,
                 scenario=None, trace=None, dp_delta: float = 1e-5,
                 strategy=None):
        self.ctask = ctask
        C = ctask.C
        self.C = C
        self.d_gate = int(d)
        self.block = int(block)
        self.rng = np.random.default_rng(seed)
        # network/heterogeneity model: a Scenario (or preset name) drives
        # latency, availability, and — when the caller gives no explicit
        # speeds — the fleet speed draw, all on the shared threefry chain
        # (repro.scenarios).  An explicit latency_fn callable keeps the
        # legacy host-side np-rng path; the two are mutually exclusive.
        if scenario is not None and latency_fn is not None:
            raise ValueError("pass either scenario= or latency_fn=, "
                             "not both")
        scn = (get_scenario(scenario) if scenario is not None
               else None if latency_fn is not None
               else get_scenario("uniform"))
        if speeds is None and scn is not None:
            speeds = scn.speeds(C, seed)
        self.speeds = np.asarray(speeds if speeds is not None
                                 else np.ones(C), np.float64)
        assert len(self.speeds) == C
        self.latency_fn = latency_fn or (lambda r: 0.05 + 0.05 * r.random())
        self.dt = self.block / float(self.speeds.max())
        self._plan = (scenario_plan(scn, C=C, seed=seed, dt=self.dt)
                      if scn is not None else None)
        # integer fixed-point credit accrual (see repro.cohort.state):
        # keeps the tick schedule bit-identical with the device engine
        self.accrual = speed_accrual(self.speeds, self.block)

        self.sizes = pad_sizes(sizes_per_client, C)
        self.etas = np.asarray(round_stepsizes, np.float64)

        v0 = ctask.init_flat()
        self.state = CohortState(
            w=jnp.tile(v0[None, :], (C, 1)),
            U=jnp.zeros((C, ctask.D), jnp.float32),
            v=v0,
            i=np.zeros(C, np.int64), h=np.zeros(C, np.int64),
            k=np.zeros(C, np.int64), credit=np.zeros(C, np.int64))
        self.updates = UpdateBuckets()
        self.bcasts = BroadcastRing()

        # round-completion DP (noise_scale = clip * sigma, as in
        # LogRegTask.add_round_noise; dp_round_clip > 0 additionally clips
        # the whole round update = user-level DP)
        from repro.core.tasks import validate_dp_knobs
        validate_dp_knobs(dp_clip, dp_sigma, "CohortEngine")
        self.dp_sigma = float(dp_sigma)
        self.dp_clip = float(dp_clip)
        self.dp_round_clip = float(dp_round_clip)
        self.use_dp_kernel = bool(use_dp_kernel)
        # interpret=None: infer from the backend — interpret-mode Pallas
        # on CPU (byte-identical to the historical default there), the
        # compiled kernel on a real TPU/GPU
        self.interpret = ((jax.default_backend() == "cpu")
                          if interpret is None else bool(interpret))
        self.noise_base = jax.random.PRNGKey(seed ^ NOISE_SALT)

        # server-side aggregation strategy (repro.core.strategies):
        # the paper default applies [D] arrival buckets on dequeue;
        # FedAsync stratifies buckets by sender-k into [R, D] rings and
        # decays at apply; FedBuff accumulates and flushes every B.
        # R matches the device engine's sender-k ring width.
        self.strategy = get_strategy(strategy)
        self.R = next_pow2(self.d_gate + 2)
        if self.strategy.stratified:
            self._strat_apply = _make_strat_apply(self.strategy, self.R)
            self._strat_insert = _make_strat_insert(self.R)
            self._strat_zero = jnp.zeros((self.R, ctask.D), jnp.float32)
        if self.strategy.buffered:
            self._buf_zero = jnp.zeros((ctask.D,), jnp.float32)
            self._buf_vec = self._buf_zero
            self._buf_cnt = 0

        self.total_messages = 0
        self.total_broadcasts = 0
        self._h_counts: Dict[int, int] = {}     # Algorithm 3's H, per round
        # telemetry: same integer counters the device engine keeps
        # in-loop — the parity contract pins them bitwise equal
        self._upd_bytes = update_msg_bytes(ctask.D)
        self.part = np.zeros(C, dtype=np.int64)
        self.bytes_up = np.zeros(C, dtype=np.int64)
        self.stale_hist = np.zeros(STALE_BINS, dtype=np.int64)
        self.ovf_hwm = 0
        self.far_messages = 0
        # op census (repro.telemetry.costs): numpy mirror of the device
        # engine's in-loop [N_OPS] vector, incremented at the exact same
        # protocol points — the parity contract pins it bitwise equal
        self.ops = zero_ops()
        self.dp_delta = float(dp_delta)
        self._trace = open_trace(trace)
        self.history: List[Dict[str, float]] = []

    # -- host-side gathers --------------------------------------------------
    def _eta_of(self, i: np.ndarray) -> np.ndarray:
        return self.etas[np.minimum(i, len(self.etas) - 1)]

    def _s_of(self, i: np.ndarray) -> np.ndarray:
        cols = np.minimum(i, self.sizes.shape[1] - 1)
        return self.sizes[np.arange(self.C), cols]

    def _latency_ticks(self, n: int) -> np.ndarray:
        """Legacy host-callable path only (explicit latency_fn=): a
        Python loop over self.rng.  Scenario-driven engines draw
        message-addressed ticks from the shared threefry chain instead
        (one vectorized [C] device call, bit-identical to the device
        engine) — see ``_update_ticks`` / ``_bcast_ticks``."""
        lats = np.array([self.latency_fn(self.rng) for _ in range(n)])
        return np.maximum(1, np.ceil(lats / self.dt)).astype(np.int64)

    def _update_ticks(self, idx: np.ndarray, i: np.ndarray) -> np.ndarray:
        """Arrival-tick offsets of the finishing clients ``idx``."""
        if self._plan is not None:
            return self._plan.host_update_ticks(i)[idx]
        return self._latency_ticks(len(idx))

    def _bcast_ticks(self, k: int) -> np.ndarray:
        """Per-client arrival-tick offsets of broadcast ``k``."""
        if self._plan is not None:
            return self._plan.host_broadcast_ticks(k)
        return self._latency_ticks(self.C)

    def _avail(self, t: int) -> Optional[np.ndarray]:
        return self._plan.host_avail(t) if self._plan is not None else None

    # -- one tick -----------------------------------------------------------
    def step(self) -> None:
        st = self.state
        st.tick += 1
        t = st.tick
        self.ops[OP_TICKS] += 1

        # 1) server: apply this tick's arrival bucket, maybe broadcast.
        # far + near in THIS order — the device engine applies
        # overflow + ring_slot the same way (bit parity).
        far, near, pairs = self.updates.pop(t)
        strat = self.strategy
        if far is not None and near is not None:
            total = far + near
        else:
            total = far if far is not None else near
        if total is not None:
            self.ops[OP_BUCKET_APPLIES] += 1
            if strat.stratified:
                # FedAsync: total is [R, D] by sender k; decay rows by
                # staleness against the pre-cascade server_k
                st.v = self._strat_apply(
                    st.v, total, _commit(st.server_k, np.int32))
            elif strat.buffered:
                # FedBuff: bank this tick's arrivals, flush every B
                self._buf_vec = self._buf_vec + total
                self._buf_cnt += len(pairs)
                if self._buf_cnt >= strat.buffer_size:
                    st.v = _apply_contrib(st.v, self._buf_vec)
                    self._buf_vec = self._buf_zero
                    self._buf_cnt = 0
            else:
                st.v = _apply_contrib(st.v, total)
        for r, _c, ks in pairs:
            self._h_counts[r] = self._h_counts.get(r, 0) + 1
            # staleness-at-apply, binned against the PRE-cascade server_k
            # (the device engine reads st.server_k at the same point)
            self.stale_hist[staleness_bin(st.server_k - ks)] += 1
        k_pre_cascade = st.server_k
        while self._h_counts.get(st.server_k, 0) >= self.C:
            del self._h_counts[st.server_k]
            st.server_k += 1
            self.total_broadcasts += 1
            at = t + self._bcast_ticks(st.server_k)
            self.bcasts.push(st.server_k, st.v, at)
        if st.server_k > k_pre_cascade:
            self.ops[OP_CASCADE_TICKS] += 1

        # 2) deliver due broadcasts, ascending k, freshest-wins per client
        # op census: clients whose freshest-seen k advances this tick ==
        # the rows the device engine's delivery gather replaces
        k_before = st.k.copy()
        due = self.bcasts.due(t)
        for b in due:
            take = (b["at"] <= t) & (b["k"] > st.k)
            if take.any():
                eta = _commit(self._eta_of(st.i), np.float32)
                st.w = _isr_receive(st.w, st.U, b["v"], eta,
                                    _commit(take))
                st.k[take] = b["k"]
        if due:
            self.bcasts.retire(t)
        deliver_rows = int(np.sum(st.k > k_before))
        self.ops[OP_DELIVER_ROWS] += deliver_rows
        if deliver_rows:
            self.ops[OP_DELIVER_TICKS] += 1

        # 3) advance the cohort: one vmapped masked block.  Availability
        #    gates compute, credit accrual AND round completion — an off
        #    client accrues nothing and sends nothing this tick.
        active = ~st.blocked(self.d_gate)
        avail = self._avail(t)
        if avail is not None:
            active &= avail
        st.credit[active] += self.accrual[active]
        s_i = self._s_of(st.i)
        n = np.minimum(s_i - st.h, st.credit >> FRAC_BITS)
        n[~active] = 0
        np.maximum(n, 0, out=n)
        nmax = int(n.max())
        if nmax > 0:
            self.ops[OP_BLOCK_TICKS] += 1
            st.credit -= n << FRAC_BITS
            eta = _commit(self._eta_of(st.i), np.float32)
            st.w, st.U = self.ctask.run_block(
                st.w, st.U, _commit(st.i, np.int32),
                _commit(st.h, np.int32), _commit(n, np.int32),
                eta, next_pow2(nmax))
            st.h += n

        # 4) round completions: clip/noise, enqueue, advance round
        done = active & (st.h >= s_i)
        if done.any():
            self._finish_rounds(done)

    def _finish_rounds(self, done: np.ndarray) -> None:
        st = self.state
        idx = np.flatnonzero(done)
        self.ops[OP_COMPLETE_TICKS] += 1
        self.total_messages += len(idx)
        self.part[idx] += 1
        self.bytes_up[idx] += self._upd_bytes
        eta = self._eta_of(st.i)
        done_dev = _commit(done)
        wgt_all = _commit(eta * done, np.float32)

        arrive = np.full(self.C, -1, np.int64)
        arrive[idx] = st.tick + self._update_ticks(idx, st.i)
        groups = np.unique(arrive[idx])

        if self.dp_sigma > 0.0 or self.dp_round_clip > 0.0:
            # commit the tick explicitly: steady segments run under
            # jax.transfer_guard("disallow") and a bare Python int here
            # would be an implicit host->device transfer
            key = jax.random.fold_in(self.noise_base,
                                     _commit(st.tick, np.int32))
            noised, agg = cohort_clip_noise(
                st.U, key, wgt_all, done_dev,
                clip=self.dp_round_clip,
                noise_scale=self.dp_clip * self.dp_sigma,
                use_kernel=self.use_dp_kernel, interpret=self.interpret)
            # client-side consistency (Algorithm 1 line 24): w += eta *
            # (sent − raw) so a later ŵ = v̂ − eta·U replacement stays
            # consistent with the noise the server absorbed.
            st.w = _add_scaled_rows(st.w, noised - st.U,
                                    _commit(eta, np.float32), done_dev)
            sent = noised
        else:
            sent, agg = st.U, None

        # arrival offsets past the plan's ring boundary go to the FAR
        # tier — mirrors the device engine's overflow bucket so the
        # delivery-time float add order matches (see UpdateBuckets)
        ring = (self._plan.ring_ticks if self._plan is not None
                else None)
        strat = self.strategy
        # FedAsync buckets are stratified by sender k (mod R): the k each
        # finishing client will stamp on its message is st.k, pinned here
        # BEFORE the round advance below
        kmod = (st.k & (self.R - 1)) if strat.stratified else None
        far_groups = 0
        for g in groups:
            in_g = arrive == g
            far = ring is not None and int(g) - st.tick >= ring
            members = np.flatnonzero(in_g)
            # op census: a near group is one distinct ring-slot scatter,
            # a far group one overflow-bucket insert — the device engine
            # counts the same masked writes inside do_complete / do_far
            if far:
                far_groups += 1
                self.far_messages += len(members)
            else:
                self.ops[OP_RING_SCATTERS] += 1
            pairs_list = [(int(st.i[c]), int(c), int(st.k[c]))
                          for c in members]
            if strat.stratified:
                cur = self.updates.get(int(g), far=far)
                if cur is None:
                    cur = self._strat_zero
                cur = self._strat_insert(
                    cur, sent, _commit(eta, np.float32),
                    _commit(in_g), _commit(kmod, np.int32))
                self.updates.put(int(g), cur, pairs_list, far=far)
                continue
            if agg is not None and len(groups) == 1:
                vec = agg                       # fused kernel aggregate
            else:
                vec = _weighted_sum(sent, _commit(eta * in_g, np.float32))
            self.updates.add(int(g), vec, pairs_list, far=far)
        if far_groups:
            self.ops[OP_FAR_TICKS] += 1
            self.ops[OP_FAR_GROUPS] += far_groups
        # far-tier occupancy high-water mark == the device engine's peak
        # count of occupied overflow slots (one slot per pending far tick)
        self.ovf_hwm = max(self.ovf_hwm, len(self.updates.far_contrib))

        st.i[done] += 1
        st.h[done] = 0
        st.credit[done] = np.minimum(st.credit[done],
                                     self.block << FRAC_BITS)
        st.U = _zero_rows(sent, done_dev)

    # -- main loop ----------------------------------------------------------
    def run(self, *, max_rounds: int, eval_every: int = 1,
            eval_fn: Optional[Callable] = None,
            max_ticks: Optional[int] = None) -> Dict[str, Any]:
        """Run until the server completes ``max_rounds`` broadcasts.

        Same result schema as ``AsyncFLSimulator.run``.
        """
        if eval_fn is not None:
            evals = lambda vec: eval_fn(self.ctask.unflatten(vec))  # noqa: E731
        else:
            evals = self.ctask.metrics
        st = self.state
        if max_ticks is None:
            tail = (self._plan.max_lat_ticks
                    if self._plan is not None else 1)
            duty = self._plan.duty if self._plan is not None else 1.0
            max_ticks = default_max_ticks(self.sizes, self.speeds,
                                          self.block, max_rounds,
                                          lat_tail_ticks=tail, duty=duty)
        next_eval = eval_every
        # kept on the engine so the timeline CLI (python -m
        # repro.telemetry capture) can export the wall spans after run()
        timer = self.timer = PhaseTimer()
        import time
        run_t0 = time.perf_counter()
        # First segment runs unguarded (jit compiles may stage host
        # constants); once warm, steady-segment ticks run under
        # transfer_guard("disallow") like DeviceCohortEngine.run — any
        # implicit host->device transfer inside a tick is a perf bug.
        # Phase accounting matches the device engine (first_segment /
        # steady / eval), with block_until_ready closing each segment so
        # async tick dispatch can't be charged to the eval that follows.
        guarded = False
        seg_t0 = run_t0
        while st.server_k < max_rounds:
            if st.tick >= max_ticks:
                raise RuntimeError(
                    f"cohort engine stalled: {st.tick} ticks, "
                    f"server_k={st.server_k} < {max_rounds} "
                    f"(in flight: {len(self.updates)} updates, "
                    f"{len(self.bcasts.pending)} broadcasts)")
            if guarded:
                with jax.transfer_guard("disallow"):
                    self.step()
            else:
                self.step()
            if st.server_k >= next_eval:
                jax.block_until_ready(st.v)
                timer.add("first_segment" if not guarded else "steady",
                          time.perf_counter() - seg_t0)
                with timer.phase("eval"):
                    m = evals(st.v)
                    m.update(round=st.server_k, time=st.tick * self.dt,
                             messages=self.total_messages)
                    self.history.append(m)
                    next_eval = st.server_k + eval_every
                    self._emit_segment()
                guarded = True
                seg_t0 = time.perf_counter()
        jax.block_until_ready(st.v)
        timer.add("first_segment" if not guarded else "steady",
                  time.perf_counter() - seg_t0)
        with timer.phase("eval"):
            final = evals(st.v)
        final.update(round=st.server_k, time=st.tick * self.dt,
                     messages=self.total_messages,
                     broadcasts=self.total_broadcasts,
                     overflow_hwm=self.ovf_hwm,
                     far_messages=self.far_messages)
        timer.add("run", time.perf_counter() - run_t0)
        report = self.telemetry_report(wall=timer.as_dict())
        if self._trace:
            self._trace.emit("report", **report.to_dict())
            self._trace.close()
        return {"final": final, "history": self.history,
                "model": self.ctask.unflatten(st.v), "telemetry": report}

    # -- telemetry ----------------------------------------------------------
    def _emit_segment(self) -> None:
        if not self._trace:
            return
        st = self.state
        self._trace.emit(
            "segment", engine="host", round=int(st.server_k),
            tick=int(st.tick), time=int(st.tick) * self.dt,
            messages=self.total_messages,
            broadcasts=self.total_broadcasts,
            bytes_up_total=int(self.bytes_up.sum()),
            staleness_hist=self.stale_hist,
            overflow_hwm=self.ovf_hwm,
            ops=self.ops.copy())

    def telemetry_report(self, wall=None):
        """MetricsReport from the counters accumulated so far."""
        st = self.state
        src_task = getattr(self.ctask, "task", None)
        return build_report(
            engine="host", clients=self.C, flat_dim=self.ctask.D,
            rounds=int(st.server_k), messages=self.total_messages,
            broadcasts=self.total_broadcasts,
            participation=self.part, bytes_up=self.bytes_up,
            staleness_hist=self.stale_hist,
            overflow_hwm=self.ovf_hwm, far_messages=self.far_messages,
            ticks=int(st.tick), ops=self.ops,
            dp_sigma=self.dp_sigma, dp_delta=self.dp_delta,
            n_examples=(int(src_task.X.shape[0])
                        if hasattr(src_task, "X") else None),
            sizes_per_client=self.sizes, wall=wall)
