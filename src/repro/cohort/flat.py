"""Flat-params cohort adapter: any pytree task as a ``[C, D]`` block task.

``CohortLogRegTask`` hand-flattens its two leaves (w, b); model-scale
tasks (``BatchModelTask``) carry an arbitrary parameter pytree, so the
cohort engines need a generic ravel/unravel with a fixed memory layout.
Two pieces:

* ``PyTreeFlattener`` — records a template's treedef + leaf shapes +
  dtypes once, then maps pytree <-> flat ``[D]`` f32 vector with static
  offsets (jit-traceable both ways).  Accumulation happens in f32; leaf
  dtypes of 32 bits or fewer (f32/bf16/f16) round-trip **bit-exactly**
  because f32 is a superset of their value sets.

* ``CohortBatchModelTask`` — the whole-population view of a
  ``BatchModelTask``: ``block_body`` embeds the minibatch
  forward/backward, optional update clip, and update-accumulate inside
  the vmapped scan the cohort engines drive, over flat ``[C, D]`` blocks.
  Per-(client, round, iteration) batches are addressed by the same
  ``fold_in`` chain ``CohortLogRegTask.sample_idx`` uses —
  ``fold_in(fold_in(fold_in(base, client), round), h + j)`` — so a cohort
  trajectory is reproducible against the event simulator driving the
  *same* ``BatchModelTask`` through a ``SeedAddressedBatcher``
  (``repro.data.federated``), regardless of how either engine chunks a
  round.

Memory model: the engines hold the population as one ``[C, D]`` f32
residency for models plus one for update accumulators (2 * C * D * 4
bytes), sharded over local devices via ``repro.sharding.cohort_*`` —
choose C and the model size so both blocks fit, and keep ``block`` small
(a model-scale "iteration" is a full minibatch step, so a handful of
iterations per round is the Bonawitz-style regime).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.tasks import BatchModelTask, clip_tree
from repro.models import train_loss


class PyTreeFlattener:
    """Static pytree <-> flat f32 vector codec (shapes fixed at init).

    ``flatten`` ravels every leaf to f32 and concatenates in treedef
    order; ``unflatten`` slices at the recorded static offsets, reshapes,
    and casts back to each leaf's original dtype.  Both directions are
    pure jnp with static indices, so they trace inside jit/vmap/scan.
    """

    def __init__(self, template):
        leaves, self.treedef = jax.tree_util.tree_flatten(template)
        if not leaves:
            raise ValueError("PyTreeFlattener needs a template with at "
                             "least one array leaf")
        self.shapes: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(l.shape) for l in leaves)
        self.dtypes = tuple(jnp.asarray(l).dtype for l in leaves)
        for dt in self.dtypes:
            # enforce the exactness contract up front: int/bool leaves
            # (and >32-bit floats) would silently corrupt through the
            # f32 round trip (e.g. int32 values above 2**24)
            if not (jnp.issubdtype(dt, jnp.floating)
                    and jnp.dtype(dt).itemsize <= 4):
                raise TypeError(
                    f"PyTreeFlattener leaves must be <=32-bit floats "
                    f"(f32/bf16/f16) for an exact f32 round trip; got "
                    f"{jnp.dtype(dt).name}")
        self.sizes = tuple(int(math.prod(s)) for s in self.shapes)
        offs, o = [], 0
        for s in self.sizes:
            offs.append(o)
            o += s
        self.offsets = tuple(offs)
        self.D = o

    def flatten(self, tree) -> jnp.ndarray:
        """tree -> [D] f32 (f32 is exact for <=32-bit float leaves)."""
        leaves = self.treedef.flatten_up_to(tree)
        return jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32) for l in leaves])

    def unflatten(self, vec, dtype=None):
        """[D] vector -> tree.  ``dtype=None`` restores each leaf's
        template dtype; pass e.g. ``jnp.float32`` to keep accumulator
        trees in f32 regardless of the template."""
        leaves = [
            jnp.reshape(vec[o:o + s], shape).astype(dtype or dt)
            for o, s, shape, dt in zip(self.offsets, self.sizes,
                                       self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


class CohortBatchModelTask:
    """Whole-population view of ``BatchModelTask`` (model-scale rounds).

    Mirrors the ``CohortLogRegTask`` interface (``run_block`` /
    ``block_body`` / ``init_flat`` / ``metrics``) so both cohort engines
    drive it unchanged; one local iteration is one minibatch-SGD step on
    the task's architecture.  Requires the task's ``data_fn`` to be
    seed-addressed (``batch_from_key``; see
    ``repro.data.federated.SeedAddressedBatcher``) — a host-callable
    batcher cannot produce batches inside the vmapped scan, and a
    stream-addressed one would break event-simulator reproducibility.
    """

    #: compiled block fns kept per task, LRU — must cover every
    #: power-of-two the host engine can request (next_pow2(nmax) <=
    #: next_pow2(2 * block)), or a recurring size would recompile a
    #: model-sized jit every few ticks (see CohortLogRegTask)
    MAX_BLOCK_FNS = 16

    def __init__(self, task: BatchModelTask, n_clients: int, *,
                 seed: int = 0):
        batcher = task.data_fn
        if not hasattr(batcher, "batch_from_key"):
            raise TypeError(
                "CohortBatchModelTask needs a seed-addressed batcher "
                "(data_fn with a batch_from_key method, e.g. "
                "repro.data.SeedAddressedBatcher); a host-callable "
                f"batcher like {type(batcher).__name__} cannot run "
                "inside the vmapped block")
        self.task = task
        self.C = int(n_clients)
        self.flattener = PyTreeFlattener(task.template)
        self.D = self.flattener.D
        # batch addressing shares the batcher's base key, so the event
        # simulator (task.data_fn(c, i, h)) and the cohort block draw the
        # SAME batch for the same (client, round, iteration)
        self.base_keys = jax.vmap(
            lambda c: jax.random.fold_in(batcher.base, c))(
                jnp.arange(self.C))
        self._block_fns: Dict[int, Any] = {}

    # -- flat layout -------------------------------------------------------
    def flatten(self, tree):
        return self.flattener.flatten(tree)

    def unflatten(self, vec):
        return self.flattener.unflatten(vec)

    def init_flat(self):
        return self.flattener.flatten(self.task.init_model())

    def metrics(self, vec) -> Dict[str, float]:
        return self.task.metrics(self.flattener.unflatten(vec))

    # -- batched compute ---------------------------------------------------
    def run_block(self, w, U, i, h, n, eta, block: int):
        """Advance every client by up to ``block`` minibatch steps.

        Same contract as ``CohortLogRegTask.run_block``: w, U are [C, D]
        blocks, i/h/n are [C] int32, eta is [C] f32, and steps j >= n[c]
        are masked no-ops.
        """
        fn = self._block_fns.pop(block, None)   # pop+reinsert: LRU order
        if fn is None:
            fn = jax.jit(self.block_body(block))
        self._block_fns[block] = fn
        while len(self._block_fns) > self.MAX_BLOCK_FNS:
            self._block_fns.pop(next(iter(self._block_fns)))
        return fn(w, U, i, h, n, eta)

    def block_body(self, block: int):
        """The ``run_block`` computation, un-jitted (the device engine
        embeds it directly in its jitted tick; see
        ``CohortLogRegTask.block_body``)."""
        task = self.task
        cfg, remat, clip = task.cfg, task.remat, task.dp_clip
        batch_from_key = task.data_fn.batch_from_key
        flt = self.flattener
        base_keys = self.base_keys

        def per_client(w_c, U_c, rk_c, h_c, n_c, eta_c):
            params = flt.unflatten(w_c)
            upd = flt.unflatten(U_c, dtype=jnp.float32)

            def body(carry, j):
                p, u = carry
                batch = batch_from_key(
                    jax.random.fold_in(rk_c, h_c + j))
                g = jax.grad(
                    lambda q: train_loss(cfg, q, batch, remat=remat))(p)
                if clip > 0.0:
                    g = clip_tree(g, clip)
                act = (j < n_c).astype(jnp.float32)
                g = jax.tree_util.tree_map(lambda l: act * l, g)
                u = jax.tree_util.tree_map(jnp.add, u, g)
                # cast back to the leaf dtype: keeps the scan carry
                # stable for sub-f32 templates (identity for f32, where
                # trajectories are event-engine-exact)
                p = jax.tree_util.tree_map(
                    lambda a, gg: (a - eta_c * gg).astype(a.dtype), p, g)
                return (p, u), None

            (params, upd), _ = jax.lax.scan(body, (params, upd),
                                            jnp.arange(block))
            return flt.flatten(params), flt.flatten(upd)

        def run(w, U, i, h, n, eta):
            # one threefry per (client, round) hoisted out of the scan,
            # exactly CohortLogRegTask.sample_idx's derivation
            round_keys = jax.vmap(jax.random.fold_in)(base_keys, i)
            return jax.vmap(per_client)(w, U, round_keys, h, n, eta)

        return run
