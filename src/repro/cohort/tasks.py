"""Batched (cohort) task adapters: per-round client compute with a leading
client axis.

A cohort task exposes the same round computation as ``repro.core.tasks``
but over flat ``[C, D]`` state blocks, advanced for the *whole population*
in one jitted ``vmap``-of-``scan`` call (``run_block``).  Per-iteration
sample draws are addressed by ``(client, round, iteration)`` via
``fold_in`` — the same derivation ``LogRegTask`` uses in its
``sample_seed`` mode — so a cohort trajectory is bit-reproducible against
the event simulator regardless of how either engine chunks a round.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.tasks import BatchModelTask, LogRegTask, clip_tree
from repro.models import logreg


class CohortLogRegTask:
    """Whole-population view of ``LogRegTask`` (the paper's experiments)."""

    #: compiled block fns kept, LRU (the cache was unbounded — a
    #: long-lived task accumulated one jit per distinct block size).
    #: The host engine requests next_pow2(nmax) <= next_pow2(2 * block)
    #: — log2(2 * block) + 1 distinct sizes — so 16 covers every block
    #: the engines accept without thrash; LRU keeps recurring sizes hot.
    MAX_BLOCK_FNS = 16

    def __init__(self, task: LogRegTask, n_clients: int, *, seed: int = 0):
        self.task = task
        self.C = int(n_clients)
        self.d_feat = task.d
        self.D = task.d + 1                     # w (d) then b (1), flat
        base_seed = task.sample_seed if task.sample_seed is not None \
            else seed
        base = jax.random.PRNGKey(base_seed)
        self.base_keys = jax.vmap(
            lambda c: jax.random.fold_in(base, c))(jnp.arange(self.C))
        self._block_fns: Dict[int, Any] = {}

    # -- flat layout -------------------------------------------------------
    def flatten(self, m):
        return jnp.concatenate([m["w"].astype(jnp.float32),
                                m["b"].astype(jnp.float32)[None]])

    def unflatten(self, vec):
        return {"w": vec[:self.d_feat], "b": vec[self.d_feat]}

    def init_flat(self):
        return self.flatten(self.task.init_model())

    def metrics(self, vec) -> Dict[str, float]:
        return self.task.metrics(self.unflatten(vec))

    # -- batched compute ---------------------------------------------------
    def run_block(self, w, U, i, h, n, eta, block: int):
        """Advance every client by up to ``block`` local SGD iterations.

        w, U: [C, D] device blocks; i, h, n: [C] int32 (round, in-round
        offset, iterations to take this call); eta: [C] f32 round step
        sizes.  Steps j >= n[c] are masked no-ops, so one compiled block
        size serves heterogeneous per-client counts.
        """
        fn = self._block_fns.pop(block, None)   # pop+reinsert: LRU order
        if fn is None:
            fn = jax.jit(self.block_body(block))
        self._block_fns[block] = fn
        while len(self._block_fns) > self.MAX_BLOCK_FNS:
            self._block_fns.pop(next(iter(self._block_fns)))
        return fn(w, U, i, h, n, eta)

    def block_body(self, block: int):
        """The ``run_block`` computation, un-jitted.

        The device-resident engine embeds this directly into its jitted
        tick function (`repro.cohort.device`), where an extra jit wrapper
        would only add trace indirection; host callers go through
        ``run_block``, which jits and caches per block size.

        """
        X, y, l2 = self.task.X, self.task.y, self.task.l2
        clip, n_data = self.task.dp_clip, self.task.X.shape[0]
        d = self.d_feat
        base_keys = self.base_keys

        def sample_idx(i, h):
            """[C, block] indices, LogRegTask.sample_indices' derivation:
            one threefry per (client, round, iteration), index = first
            key word mod n.  Batched OUTSIDE the SGD scan: per-step
            hashing inside the scan serializes block tiny dispatches and
            was ~2/3 of run_block wall time at C=4096."""
            round_keys = jax.vmap(jax.random.fold_in)(base_keys, i)

            def one(rk_c, h_c):
                ks = jax.vmap(lambda j: jax.random.fold_in(rk_c, h_c + j))(
                    jnp.arange(block))
                return (ks[:, 0] % jnp.uint32(n_data)).astype(jnp.int32)

            return jax.vmap(one)(round_keys, h)

        def per_client(w_c, U_c, idx_c, n_c, eta_c):
            params = {"w": w_c[:d], "b": w_c[d]}
            upd = {"w": U_c[:d], "b": U_c[d]}

            def body(carry, inp):
                p, u = carry
                idx, j = inp
                g = jax.grad(logreg.per_example_loss)(p, X[idx], y[idx], l2)
                if clip > 0.0:
                    g = clip_tree(g, clip)
                act = (j < n_c).astype(jnp.float32)
                g = jax.tree_util.tree_map(lambda l: act * l, g)
                u = jax.tree_util.tree_map(jnp.add, u, g)
                p = jax.tree_util.tree_map(lambda a, gg: a - eta_c * gg,
                                           p, g)
                return (p, u), None

            (params, upd), _ = jax.lax.scan(body, (params, upd),
                                            (idx_c, jnp.arange(block)))
            w_out = jnp.concatenate([params["w"], params["b"][None]])
            u_out = jnp.concatenate([upd["w"], upd["b"][None]])
            return w_out, u_out

        def run(w, U, i, h, n, eta):
            return jax.vmap(per_client)(w, U, sample_idx(i, h), n, eta)

        return run


def as_cohort_task(task, n_clients: int, *, seed: int = 0):
    """Adapt a ``repro.core.tasks`` task (or pass through a cohort task)."""
    if hasattr(task, "run_block"):
        return task
    if isinstance(task, LogRegTask):
        return CohortLogRegTask(task, n_clients, seed=seed)
    if isinstance(task, BatchModelTask):
        from repro.cohort.flat import CohortBatchModelTask
        return CohortBatchModelTask(task, n_clients, seed=seed)
    raise TypeError(f"no cohort adapter for {type(task).__name__}; "
                    "provide an object with run_block/init_flat/metrics")
