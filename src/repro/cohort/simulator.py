"""``CohortSimulator`` / ``DeviceCohortSimulator`` — drop-in batched
engines behind the ``AsyncFLSimulator`` interface.

Same constructor vocabulary and ``run()`` result schema as
``repro.core.simulator.AsyncFLSimulator``, so benchmarks and examples can
switch engines via a flag (``FLConfig.engine``).  Construct them with the
same ``LogRegTask`` (give the task a ``sample_seed`` for bit-reproducible
parity between engines) or with any object implementing the cohort-task
interface (``run_block`` / ``block_body`` / ``init_flat`` / ``metrics``).

The device simulator differs in one knob: network latency is a spec
(float seconds or an ``(lo, hi)`` uniform range) instead of a host
callable — see ``repro.cohort.device``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

from repro.cohort.device import DeviceCohortEngine
from repro.cohort.engine import CohortEngine
from repro.cohort.tasks import as_cohort_task


class CohortSimulator:
    def __init__(self, task, *, n_clients: int, sizes_per_client,
                 round_stepsizes: Sequence[float], d: int = 1,
                 speeds: Optional[Sequence[float]] = None,
                 latency_fn: Optional[Callable] = None, seed: int = 0,
                 block: int = 64, dp_round_clip: float = 0.0,
                 use_dp_kernel: bool = True,
                 interpret: Optional[bool] = None,
                 scenario=None, trace=None, dp_delta: float = 1e-5,
                 strategy=None):
        self.task = task
        self.ctask = as_cohort_task(task, n_clients, seed=seed)
        # a pre-adapted cohort task keeps DP knobs on its wrapped task
        src_task = getattr(task, "task", task)
        self.engine = CohortEngine(
            self.ctask, sizes_per_client=sizes_per_client,
            round_stepsizes=round_stepsizes, d=d, speeds=speeds,
            latency_fn=latency_fn, seed=seed, block=block,
            dp_sigma=getattr(src_task, "dp_sigma", 0.0),
            dp_clip=getattr(src_task, "dp_clip", 0.0),
            dp_round_clip=dp_round_clip,
            use_dp_kernel=use_dp_kernel, interpret=interpret,
            scenario=scenario, trace=trace, dp_delta=dp_delta,
            strategy=strategy)

    @property
    def server_model(self):
        return self.ctask.unflatten(self.engine.state.v)

    @property
    def total_messages(self) -> int:
        return self.engine.total_messages

    @property
    def total_broadcasts(self) -> int:
        return self.engine.total_broadcasts

    def run(self, *, max_rounds: int, eval_every: int = 1,
            eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
            max_ticks: Optional[int] = None) -> Dict[str, Any]:
        return self.engine.run(max_rounds=max_rounds,
                               eval_every=eval_every, eval_fn=eval_fn,
                               max_ticks=max_ticks)


class DeviceCohortSimulator:
    """Front-end for the device-resident engine (``repro.cohort.device``):
    one jitted tick loop, host sync only at eval boundaries."""

    def __init__(self, task, *, n_clients: int, sizes_per_client,
                 round_stepsizes: Sequence[float], d: int = 1,
                 speeds: Optional[Sequence[float]] = None,
                 latency=None, seed: int = 0, block: int = 64,
                 dp_round_clip: float = 0.0, use_dp_kernel: bool = True,
                 interpret: Optional[bool] = None, scenario=None,
                 trace=None, dp_delta: float = 1e-5, strategy=None,
                 dp_rng: str = "operand", fuse_ticks: bool = True):
        self.task = task
        self.ctask = as_cohort_task(task, n_clients, seed=seed)
        src_task = getattr(task, "task", task)
        self.engine = DeviceCohortEngine(
            self.ctask, sizes_per_client=sizes_per_client,
            round_stepsizes=round_stepsizes, d=d, speeds=speeds,
            latency=latency, seed=seed, block=block,
            dp_sigma=getattr(src_task, "dp_sigma", 0.0),
            dp_clip=getattr(src_task, "dp_clip", 0.0),
            dp_round_clip=dp_round_clip,
            use_dp_kernel=use_dp_kernel, interpret=interpret,
            scenario=scenario, trace=trace, dp_delta=dp_delta,
            strategy=strategy, dp_rng=dp_rng, fuse_ticks=fuse_ticks)

    @property
    def server_model(self):
        return self.ctask.unflatten(self.engine.state.v)

    @property
    def total_messages(self) -> int:
        return self.engine.total_messages

    @property
    def total_broadcasts(self) -> int:
        return self.engine.total_broadcasts

    def run(self, *, max_rounds: int, eval_every: int = 1,
            eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
            max_ticks: Optional[int] = None) -> Dict[str, Any]:
        return self.engine.run(max_rounds=max_rounds,
                               eval_every=eval_every, eval_fn=eval_fn,
                               max_ticks=max_ticks)


def make_simulator(engine, task, **kw):
    """Engine switch used by benchmarks/examples.

    ``engine`` is ``'event' | 'cohort' | 'device'``, or an ``FLConfig``
    whose ``engine`` / ``cohort_block`` / ``scenario`` fields select and
    tune the engine.  ``scenario`` (a preset name or ``Scenario``) is
    accepted by all three engines.
    """
    if not isinstance(engine, str):
        cfg = engine
        engine = cfg.engine
        if engine in ("cohort", "device"):
            kw.setdefault("block", cfg.cohort_block)
        if getattr(cfg, "scenario", None) is not None:
            kw.setdefault("scenario", cfg.scenario)
        if getattr(cfg, "aggregation", None) is not None:
            kw.setdefault("strategy", cfg.aggregation)
    if engine == "cohort":
        return CohortSimulator(task, **kw)
    if engine == "device":
        if kw.pop("latency_fn", None) is not None:
            raise ValueError(
                "engine='device' takes latency=<spec>, not a host "
                "latency_fn callable (see repro.cohort.device)")
        return DeviceCohortSimulator(task, **kw)
    if engine == "event":
        from repro.core.simulator import AsyncFLSimulator
        kw.pop("block", None)
        return AsyncFLSimulator(task, **kw)
    raise ValueError(
        f"unknown engine {engine!r} (want 'event'|'cohort'|'device')")
