"""Stacked client-population state and message buffers for the cohort engine.

``CohortState`` holds the whole population as arrays with a leading client
axis: models and round-update accumulators live on device as flat
``[C, D]`` blocks (D = flattened model dim), while the small per-client
protocol counters (round i, in-round iteration h, freshest broadcast k,
fractional iteration credit) stay host-side — they drive Python control
flow every tick and would cost a device sync each if they lived in jnp.

Messages are metadata + payload, split the same way:
  * ``UpdateBuckets`` — because the server only ever *sums* arriving
    updates (v ← v − Σ eta_i U), in-flight update payloads are pre-weighted
    and bucket-summed by arrival tick into one [D] vector per tick
    (segment-sum semantics without dynamic scatter); the (round, client)
    pairs the server's H set needs are kept as host metadata.
  * ``BroadcastRing`` — pending (v, k) broadcasts with per-client arrival
    ticks.  The wait gate bounds how far clients lag the server, so only a
    handful are ever outstanding.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

import numpy as np


@dataclass
class CohortState:
    """Population state: device blocks + host counters (leading axis C)."""
    w: Any                 # [C, D] client models (device)
    U: Any                 # [C, D] round-update accumulators (device)
    v: Any                 # [D] server model (device)
    i: np.ndarray          # [C] current round (host)
    h: np.ndarray          # [C] iterations done in round i (host)
    k: np.ndarray          # [C] freshest broadcast counter seen (host)
    credit: np.ndarray     # [C] fractional iteration credit (host)
    server_k: int = 0      # completed-round counter (Algorithm 3's k)
    tick: int = 0

    def blocked(self, d: int) -> np.ndarray:
        """Wait gate, vectorized: block while i >= k + d (Supp. B.2)."""
        return self.i >= self.k + d


@dataclass
class UpdateBuckets:
    """In-flight client->server updates, bucket-summed by arrival tick."""
    contrib: Dict[int, Any] = field(default_factory=dict)   # tick -> [D]
    meta: Dict[int, List[Tuple[int, int]]] = field(default_factory=dict)

    def add(self, tick: int, vec, pairs: List[Tuple[int, int]]) -> None:
        if tick in self.contrib:
            self.contrib[tick] = self.contrib[tick] + vec
        else:
            self.contrib[tick] = vec
        self.meta.setdefault(tick, []).extend(pairs)

    def pop(self, tick: int):
        """-> ([D] contribution or None, [(round, client), ...])."""
        return (self.contrib.pop(tick, None), self.meta.pop(tick, []))

    def __len__(self) -> int:
        return sum(len(m) for m in self.meta.values())


@dataclass
class BroadcastRing:
    """Outstanding server->client broadcasts (few: gate bounds the lag)."""
    pending: List[dict] = field(default_factory=list)

    def push(self, k: int, v, arrive_ticks: np.ndarray) -> None:
        self.pending.append({"k": k, "v": v, "at": arrive_ticks})

    def due(self, tick: int):
        """Broadcasts with any arrival <= tick, ascending k (ISRRECEIVE
        drops stale ones per client via the k-comparison)."""
        return sorted((b for b in self.pending if (b["at"] <= tick).any()),
                      key=lambda b: b["k"])

    def retire(self, tick: int) -> None:
        horizon = np.iinfo(np.int64).max
        for b in self.pending:
            b["at"][b["at"] <= tick] = horizon
        self.pending = [b for b in self.pending
                        if (b["at"] < horizon).any()]
