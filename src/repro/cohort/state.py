"""Stacked client-population state and message buffers for the cohort engines.

``CohortState`` holds the whole population as arrays with a leading client
axis: models and round-update accumulators live on device as flat
``[C, D]`` blocks (D = flattened model dim), while the small per-client
protocol counters (round i, in-round iteration h, freshest broadcast k,
iteration credit) stay host-side — they drive Python control flow every
tick and would cost a device sync each if they lived in jnp.

Messages are metadata + payload, split the same way:
  * ``UpdateBuckets`` — because the server only ever *sums* arriving
    updates (v ← v − Σ eta_i U), in-flight update payloads are pre-weighted
    and bucket-summed by arrival tick into one [D] vector per tick
    (segment-sum semantics without dynamic scatter); the (round, client)
    pairs the server's H set needs are kept as host metadata.
  * ``BroadcastRing`` — pending (v, k) broadcasts with per-client arrival
    ticks.  The wait gate bounds how far clients lag the server, so only a
    handful are ever outstanding.

``DeviceCohortState`` is the fully on-device counterpart used by the
device-resident engine (``repro.cohort.device``): the same population
blocks plus the counters AND the message buffers as fixed-capacity ring
arrays, one pytree, so a single jitted tick function can advance the
whole protocol under ``lax.while_loop`` with no host round trips.

Iteration credit is integer fixed point (``FRAC_BITS`` fractional bits)
in BOTH engines: float credit would accumulate differently in the host
engine's float64 numpy and the device engine's float32 XLA, and a single
divergent ``floor(credit)`` changes the tick schedule.  Integer credit
makes the two engines' schedules — and hence, with deterministic
latency, their trajectories — bit-identical.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Tuple

import numpy as np

FRAC_BITS = 16   # fixed-point fractional bits of the iteration credit


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (ring capacities, block sizes)."""
    p = 1
    while p < n:
        p <<= 1
    return p


def speed_accrual(speeds, block: int) -> np.ndarray:
    """Per-tick integer credit earned by each client.

    dt = block / max(speed), so client c earns ``speed_c / max(speed) *
    block`` iterations per tick; quantized to FRAC_BITS so both engines
    accrue the exact same integers.
    """
    s = np.asarray(speeds, np.float64)
    ispeed = np.maximum(1, np.round(s / s.max() * (1 << FRAC_BITS)))
    return ispeed.astype(np.int64) * int(block)


def pad_sizes(sizes_per_client, n_clients: int) -> np.ndarray:
    """Per-client round sizes as a dense [C, L] array, s(i) = s[min(i, L-1)].

    Shared by both cohort engines so their schedules stay identical.
    """
    if isinstance(sizes_per_client[0], (list, tuple)):
        per_client = [list(s) for s in sizes_per_client]
    else:
        per_client = [list(sizes_per_client)] * n_clients
    L = max(len(s) for s in per_client)
    sizes = np.empty((n_clients, L), np.int64)
    for c, s in enumerate(per_client):
        sizes[c, :len(s)] = s
        sizes[c, len(s):] = s[-1]
    return sizes


def default_max_ticks(sizes: np.ndarray, speeds: np.ndarray, block: int,
                      max_rounds: int, *, lat_tail_ticks: int = 1,
                      duty: float = 1.0) -> int:
    """Stall-detection tick budget, shared by both cohort engines.

    dt is sized for the FASTEST client (dt = block / max speed), so the
    slowest one earns only block * min/max credit per tick and needs
    speed_ratio times more ticks than s/block suggests; the budget must
    also cover the LARGEST round of an increasing schedule, not round 0.
    Scenario terms: every round waits one update + one broadcast trip,
    so the budget carries 2x the latency table's TAIL tick count (not
    the mean — a heavy-tailed table otherwise trips the guard), and an
    availability duty cycle < 1 stretches every compute tick by 1/duty.
    """
    speed_ratio = float(speeds.max() / speeds.min())
    compute = int(sizes.max()) / block * speed_ratio / max(duty, 1e-3)
    per_round = int(math.ceil(compute)) + 8 + 2 * int(lat_tail_ticks)
    return max(1000, max_rounds * per_round * 16)


@dataclass
class CohortState:
    """Population state: device blocks + host counters (leading axis C)."""
    w: Any                 # [C, D] client models (device)
    U: Any                 # [C, D] round-update accumulators (device)
    v: Any                 # [D] server model (device)
    i: np.ndarray          # [C] current round (host)
    h: np.ndarray          # [C] iterations done in round i (host)
    k: np.ndarray          # [C] freshest broadcast counter seen (host)
    credit: np.ndarray     # [C] fixed-point iteration credit (host, i64)
    server_k: int = 0      # completed-round counter (Algorithm 3's k)
    tick: int = 0

    def blocked(self, d: int) -> np.ndarray:
        """Wait gate, vectorized: block while i >= k + d (Supp. B.2)."""
        return self.i >= self.k + d


class DeviceCohortState(NamedTuple):
    """Whole protocol state on device — counters, models, message rings.

    The dict-backed ``UpdateBuckets``/``BroadcastRing`` become
    fixed-capacity power-of-two rings (capacities chosen in
    ``repro.cohort.device``):

      * update ring, L slots (L > max latency ticks): ``upd_vec[t % L]``
        accumulates the pre-weighted [D] contribution arriving at tick t;
        ``upd_cnt[t % L, r % R]`` counts the arriving (round r, client)
        pairs that feed Algorithm 3's H bookkeeping.
      * H-count ring, R slots: per-round receive counts.  The wait gate
        keeps in-flight update rounds inside [server_k, server_k + d], so
        R >= next_pow2(d + 2) slots never collide.
      * broadcast ring, B slots of ((v snapshot, k), per-client arrival
        tick): an undelivered broadcast j gates every client at rounds
        <= j + d - 1, hence at most d + 1 distinct k outstanding and
        B >= next_pow2(d + 2) suffices.
      * overflow bucket, Q slots of (arrival tick, pre-weighted [D]
        vector, [R] round counts): update arrivals whose latency offset
        reaches past the L-slot ring (heavy-tailed tables under the
        ``Scenario.ring_cap`` boundary).  Entries merge by exact arrival
        tick, so correctness (and host<->device bit parity) is
        preserved while L stays bounded; ``ovf_at == 0`` marks a free
        slot and ``err`` latches capacity exhaustion (the segment stops
        and the host raises).
    """
    w: Any                 # [C, D] f32 client models
    U: Any                 # [C, D] f32 round-update accumulators
    v: Any                 # [D]    f32 server model
    i: Any                 # [C]    i32 current round
    h: Any                 # [C]    i32 iterations done in round i
    k: Any                 # [C]    i32 freshest broadcast counter seen
    credit: Any            # [C]    i32 fixed-point iteration credit
    server_k: Any          # []     i32 completed-round counter
    tick: Any              # []     i32
    upd_vec: Any           # [L, D] f32 pre-weighted arrival buckets
    upd_cnt: Any           # [L, R] i32 arriving (round, client) counts
    h_counts: Any          # [R]    i32 Algorithm 3's H, per round mod R
    bc_v: Any              # [B, D] f32 broadcast model snapshots
    bc_k: Any              # [B]    i32 broadcast round counters
    bc_at: Any             # [B, C] i32 per-client arrival ticks
    ovf_vec: Any           # [Q, D] f32 far-arrival overflow vectors
    ovf_at: Any            # [Q]    i32 overflow arrival ticks (0 = free)
    ovf_cnt: Any           # [Q, R] i32 overflow (round, client) counts
    err: Any               # []     i32 overflow-capacity error latch
    messages: Any          # []     i32 client->server updates sent
    broadcasts: Any        # []     i32 server broadcasts fired
    # telemetry (repro.telemetry): census + staleness counters kept
    # inside the jitted tick loop, synced to host only at eval segments.
    # ``upd_ks[t % L, k % R]`` / ``ovf_ks[q, k % R]`` count arrivals by
    # the SENDER's broadcast counter k at send time; staleness-at-apply
    # is decoded at pop as (server_k - k) mod R, exact because the wait
    # gate bounds it by d - 1 < R.
    part: Any              # [C]    i32 updates sent per client
    bytes_up: Any          # [C]    i32 uplink bytes per client
    stale_hist: Any        # [S]    i32 staleness-at-apply histogram
    upd_ks: Any            # [L, R] i32 arrival counts by sender k mod R
    ovf_ks: Any            # [Q, R] i32 overflow counts by sender k mod R
    ovf_hwm: Any           # []     i32 overflow occupancy high-water mark
    far_msgs: Any          # []     i32 updates routed to the far tier
    # aggregation-strategy buffers (repro.core.strategies): sized [1,...]
    # dummies under the default paper strategy, real buffers otherwise.
    # ``upd_kvec``/``ovf_kvec`` are the sender-k STRATIFIED counterparts
    # of ``upd_vec``/``ovf_vec`` — FedAsync must decay each arriving
    # vector by its own staleness at apply time, so pre-summing across
    # sender-k (the paper path) would lose the needed resolution.
    # ``buf_vec``/``buf_cnt`` are FedBuff's accumulator and its arrival
    # count since the last flush.
    upd_kvec: Any          # [L, R, D] f32 arrival buckets by sender k
    ovf_kvec: Any          # [Q, R, D] f32 overflow buckets by sender k
    buf_vec: Any           # [D]       f32 FedBuff flush accumulator
    buf_cnt: Any           # []        i32 updates buffered since flush
    # op census (repro.telemetry.costs): which tick-loop operations ran
    # — branch hits, delivery rows, ring scatters — one cumulative i32
    # vector indexed by costs.OP_NAMES, threaded through the same
    # lax.cond operand tuples as the census so the float math is
    # untouched; host engine mirrors it bitwise.
    ops: Any               # [N_OPS]   i32 op-census counters
    # fused-loop iteration census (repro.cohort.device fuse_ticks):
    # [loop_iters, block_iters] — while_loop iterations executed and how
    # many of them contained at least one block tick.  Protocol-neutral:
    # the ops census above still counts TICKS, this counts ITERATIONS
    # after tick coalescing, so block_iters <= loop_iters <= ticks.
    iters: Any             # [2]       i32 [loop_iters, block_iters]


@dataclass
class UpdateBuckets:
    """In-flight client->server updates, bucket-summed by arrival tick.

    Buckets are split into NEAR (arrival offset inside the device
    engine's update ring) and FAR (offsets past it, the device engine's
    overflow bucket) tiers.  The split changes nothing semantically —
    both tiers deliver at their exact arrival tick — but it pins the
    float summation order: the host engine applies ``v -= far + near``
    exactly like the device engine's ``v -= overflow + ring_slot``, so
    host-cohort vs device stays bit-identical under heavy-tailed
    latency tables.
    """
    contrib: Dict[int, Any] = field(default_factory=dict)   # tick -> [D]
    far_contrib: Dict[int, Any] = field(default_factory=dict)
    meta: Dict[int, List[Tuple[int, int, int]]] = field(default_factory=dict)

    def add(self, tick: int, vec, pairs: List[Tuple[int, int, int]],
            far: bool = False) -> None:
        """``pairs`` are (round, client, k_send) triples — round/client
        feed Algorithm 3's H set, k_send the staleness-at-apply census."""
        bucket = self.far_contrib if far else self.contrib
        if tick in bucket:
            bucket[tick] = bucket[tick] + vec
        else:
            bucket[tick] = vec
        self.meta.setdefault(tick, []).extend(pairs)

    def get(self, tick: int, far: bool = False):
        """Current bucket payload at ``tick`` (None when empty) — the
        read half of the get-modify-``put`` cycle the stratified
        (sender-k bucketed) strategies use: their [R, D] buckets must be
        merged row-by-row with the device engine's exact masked-add
        expression, not with the opaque ``add`` merge."""
        return (self.far_contrib if far else self.contrib).get(tick)

    def put(self, tick: int, vec, pairs: List[Tuple[int, int, int]],
            far: bool = False) -> None:
        """Overwrite the bucket payload at ``tick`` and append pairs."""
        (self.far_contrib if far else self.contrib)[tick] = vec
        self.meta.setdefault(tick, []).extend(pairs)

    def pop(self, tick: int):
        """-> ([D] far contribution or None, [D] near contribution or
        None, [(round, client, k_send), ...])."""
        return (self.far_contrib.pop(tick, None),
                self.contrib.pop(tick, None), self.meta.pop(tick, []))

    def __len__(self) -> int:
        return sum(len(m) for m in self.meta.values())


@dataclass
class BroadcastRing:
    """Outstanding server->client broadcasts (few: gate bounds the lag)."""
    pending: List[dict] = field(default_factory=list)

    def push(self, k: int, v, arrive_ticks: np.ndarray) -> None:
        self.pending.append({"k": k, "v": v, "at": arrive_ticks})

    def due(self, tick: int):
        """Broadcasts with any arrival <= tick, ascending k (ISRRECEIVE
        drops stale ones per client via the k-comparison)."""
        return sorted((b for b in self.pending if (b["at"] <= tick).any()),
                      key=lambda b: b["k"])

    def retire(self, tick: int) -> None:
        horizon = np.iinfo(np.int64).max
        for b in self.pending:
            b["at"][b["at"] <= tick] = horizon
        self.pending = [b for b in self.pending
                        if (b["at"] < horizon).any()]
