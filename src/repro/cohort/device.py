"""Device-resident cohort engine: the whole tick loop as ONE jitted
``lax.while_loop`` over an on-device ``DeviceCohortState``.

``CohortEngine`` (the host-loop engine) batches the heavy [C, D] compute,
but its per-tick control flow lives in Python: every tick costs a handful
of separate device dispatches plus host<->device syncs of the protocol
counters, so at scale wall clock is dominated by dispatch/sync, not by
the hardware.  This engine moves the complete tick — server bucket
apply, H-count merge, broadcast-cascade firing, masked ISRRECEIVE,
credit accrual, block advance, fused clip+noise round completion — into
a single jitted tick function iterated by ``lax.while_loop`` until the
next eval boundary.  The host syncs exactly once per eval segment (one
scalar read of ``server_k``).

The Python-dict ``UpdateBuckets``/``BroadcastRing`` become fixed-capacity
power-of-two ring arrays inside the state pytree (see
``repro.cohort.state.DeviceCohortState`` for the capacity arguments),
and the client axis of every [C, ...] block is sharded over the local
devices via ``repro.sharding.cohort_shardings``, with the state buffer
donated across segments.

The update ring is bounded: its length L (and the unrolled per-slot
bucket scatter) covers latency offsets only up to the plan's
``ring_ticks`` boundary (``Scenario.ring_cap``), and draws quantizing
past it go to an explicit Q-slot OVERFLOW BUCKET — (arrival tick,
pre-weighted [D] vector, [R] round counts) entries merged by exact
arrival tick.  Heavy-tailed tables (``iot_straggler``-class Pareto
tails) therefore no longer scale compile time/memory with
``next_pow2(max latency ticks)``.  The host engine splits its arrival
buckets at the same plan boundary and applies ``v -= far + near`` in
the same order, so the split is invisible to the bit-parity contract;
if the bucket ever exhausts (more distinct far arrival ticks in flight
than Q slots), the segment stops with an error latch and ``run``
raises with the knob to turn.

Fidelity: ticks use the same quantization and the same integer
fixed-point credit (``state.FRAC_BITS``) as the host engine, and sample
draws are (client, round, iteration) addressed, so the two cohort
engines are **bit-identical** — under deterministic latency
(tests/test_cohort_parity.py pins this three ways against the event
simulator) and under stochastic scenarios (tests/test_scenarios.py),
whose latency/availability draws are message-addressed on the shared
threefry chain rather than consumed from a sequential stream.

Network and fleet heterogeneity come from a ``repro.scenarios``
Scenario — an empirical ``LatencyTable`` (alias-method draws on the
shared threefry chain, addressed by message identity), an availability
model (diurnal windows / churn as pure [C]-shaped tick ops), and an
optional speed distribution — never from a host callable, which cannot
cross into the jitted loop.  Latency draws are (client, round) /
(broadcast k, client) addressed, so the host-loop engine draws the
exact same arrival ticks and host-cohort vs device stays
**bit-identical under stochastic scenarios too** (the legacy ``latency``
spec — float seconds or an (lo, hi) range — is adapted onto the same
machinery).  The default ``uniform`` scenario matches the host engines'
legacy default network and quantizes to the same single tick whenever
``dt = block / max(speed) >= 0.1`` — the usual regime.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis.salts import NOISE_SALT
from repro.cohort.state import (FRAC_BITS, DeviceCohortState,
                                default_max_ticks, next_pow2, pad_sizes,
                                speed_accrual)
from repro.core.strategies import get_strategy, ring_decay
from repro.kernels.cohort_dp import cohort_clip_noise
from repro.kernels.tick_fused import (bucket_apply, tick_deliver,
                                      tick_scatter)
from repro.scenarios import (get_scenario, legacy_latency_scenario,
                             scenario_plan)
from repro.sharding import cohort_mesh, cohort_shardings
from repro.telemetry import (STALE_BINS, PhaseTimer, build_report,
                             open_trace, update_msg_bytes)
from repro.telemetry.costs import (N_OPS, OP_BLOCK_TICKS, OP_FAR_GROUPS,
                                   OP_FAR_TICKS, OP_RING_SCATTERS)

# Unroll bound for the overflow bucket's per-completion-tick far-group
# loop: one iteration per distinct far arrival tick.  Most tables have a
# handful of bins past the ring boundary; a union of many fine-binned
# per-client tables is clamped here so the jitted tick never scales
# with the tail — a tick that genuinely produces more distinct far
# groups than this trips the err latch and run() raises with the
# ring_cap advice.
FAR_UNROLL_CAP = 16


def _build_segment(ctask, *, C: int, D: int, block: int, b_stat: int,
                   d_gate: int, L: int, R: int, B: int, Q: int, F: int,
                   plan, dp_clip: float, dp_sigma: float,
                   dp_round_clip: float, use_dp_kernel: bool,
                   interpret: bool, in_kernel_rng: bool,
                   fuse_ticks: bool, seed: int, strategy):
    """Compile the eval-boundary segment runner for one configuration.

    Returns ``segment(state, etas, sizes, accrual, target_k, tick_limit)``
    — a jitted, state-donating function that advances the protocol until
    ``server_k >= target_k`` or the tick budget runs out.  Per-instance
    arrays (etas, sizes, accrual) are arguments rather than closure
    constants so fresh engine instances with the same geometry reuse the
    compiled executable.
    """
    dp_on = dp_sigma > 0.0 or dp_round_clip > 0.0
    noise_scale = dp_clip * dp_sigma
    ones1 = jnp.ones((1,), jnp.float32)   # unit decay for [1, D] buckets
    # server-side aggregation strategy (repro.core.strategies), resolved
    # at trace time: the paper default applies the due [D] bucket as-is;
    # FedAsync keeps a sender-k-stratified [R, D] twin of each bucket
    # and decays strata at apply; FedBuff banks due buckets and flushes
    # every BUF-th message.  All strategy branches are Python-level, so
    # the default tick's jaxpr — and the goldens it pins — is unchanged.
    stratified = strategy.stratified
    buffered = strategy.buffered
    BUF = strategy.buffer_size if buffered else 0
    noise_base = jax.random.PRNGKey(seed ^ NOISE_SALT)   # == host engine's
    run_block = ctask.block_body(b_stat)
    cidx = jnp.arange(C)
    S = STALE_BINS
    upd_bytes = jnp.int32(update_msg_bytes(D))
    # scenario closures (repro.scenarios.ScenarioPlan): message-addressed
    # latency-tick draws and the availability mask, pure jax ops the host
    # engine evaluates identically — the bit-parity contract
    avail_mask = plan.avail_mask

    def segment(st: DeviceCohortState, etas, sizes, accrual,
                target_k, tick_limit) -> DeviceCohortState:

        def tick_fn(st: DeviceCohortState) -> DeviceCohortState:
            t = st.tick + 1

            # 1) server: pop this tick's arrival bucket (ring slot +
            #    any overflow entry due now), merge H counts,
            #    cascade-fire every round whose H just filled
            slot = t & (L - 1)
            cnt_row = st.upd_cnt[slot]                       # [R]
            ks_row = st.upd_ks[slot]                         # [R]
            if F > 0:
                ovf_hit = st.ovf_at == t                     # [Q]
                # entries merge by arrival tick at insert, so at most
                # one slot is due; the masked sums only run on hit
                # ticks (far arrivals are the latency tail)

                def pop_ovf(_):
                    out = (jnp.sum(st.ovf_vec
                                   * ovf_hit.astype(jnp.float32)[:, None],
                                   axis=0),
                           jnp.sum(st.ovf_cnt
                                   * ovf_hit.astype(jnp.int32)[:, None],
                                   axis=0),
                           jnp.sum(st.ovf_ks
                                   * ovf_hit.astype(jnp.int32)[:, None],
                                   axis=0))
                    if stratified:
                        out += (jnp.sum(
                            st.ovf_kvec
                            * ovf_hit.astype(jnp.float32)[:, None, None],
                            axis=0),)
                    return out

                def no_ovf(_):
                    out = (jnp.zeros((D,), jnp.float32),
                           jnp.zeros((R,), jnp.int32),
                           jnp.zeros((R,), jnp.int32))
                    if stratified:
                        out += (jnp.zeros((R, D), jnp.float32),)
                    return out

                popped = lax.cond(jnp.any(ovf_hit), pop_ovf, no_ovf,
                                  None)
                ovf_vec_t, ovf_cnt_t, ovf_ks_t = popped[:3]
                cnt_total = cnt_row + ovf_cnt_t
                ks_total = ks_row + ovf_ks_t
                # overflow + ring_slot in THIS order — the host engine
                # applies far + near the same way (bit parity)
                arr_due = ovf_vec_t + st.upd_vec[slot]
                kvec_due = (popped[3] + st.upd_kvec[slot]
                            if stratified else None)
                ovf_vec = jnp.where(ovf_hit[:, None], 0.0, st.ovf_vec)
                ovf_at = jnp.where(ovf_hit, 0, st.ovf_at)
                ovf_cnt = jnp.where(ovf_hit[:, None], 0, st.ovf_cnt)
                ovf_ks = jnp.where(ovf_hit[:, None], 0, st.ovf_ks)
                ovf_kvec = (jnp.where(ovf_hit[:, None, None], 0.0,
                                      st.ovf_kvec)
                            if stratified else st.ovf_kvec)
            else:
                cnt_total = cnt_row
                ks_total = ks_row
                arr_due = st.upd_vec[slot]
                kvec_due = st.upd_kvec[slot] if stratified else None
                ovf_vec, ovf_at, ovf_cnt, ovf_ks = (
                    st.ovf_vec, st.ovf_at, st.ovf_cnt, st.ovf_ks)
                ovf_kvec = st.ovf_kvec
            has_arrivals = jnp.sum(cnt_total) > 0
            # bucket apply — fused kernel (kernels/tick_fused): on CPU
            # its reference path traces the engines' historical
            # expressions verbatim (bit parity with _make_strat_apply /
            # v - arr_due); on TPU/GPU it is one Pallas pass over D
            if stratified:
                # FedAsync: decay each sender-k stratum of the due
                # bucket by its staleness — ring_decay is the SHARED
                # expression the host engine jits in _make_strat_apply;
                # here the weights feed the kernel as an operand
                dec = ring_decay(strategy, st.server_k, R)
                v = bucket_apply(st.v, kvec_due, dec, has_arrivals)
                buf_vec, buf_cnt = st.buf_vec, st.buf_cnt
            elif buffered:
                # FedBuff: bank the due bucket, flush (and reset) on
                # every BUF-th banked message — the host engine flushes
                # on the same python-side counter
                buf_vec = jnp.where(has_arrivals,
                                    st.buf_vec + arr_due, st.buf_vec)
                buf_cnt = st.buf_cnt + jnp.sum(cnt_total)
                flush = buf_cnt >= BUF
                v = bucket_apply(st.v, buf_vec[None, :], ones1, flush)
                buf_vec = jnp.where(flush,
                                    jnp.zeros((D,), jnp.float32),
                                    buf_vec)
                buf_cnt = jnp.where(flush, 0, buf_cnt)
            else:
                v = bucket_apply(st.v, arr_due[None, :], ones1,
                                 has_arrivals)
                buf_vec, buf_cnt = st.buf_vec, st.buf_cnt
            upd_vec = st.upd_vec.at[slot].set(
                jnp.zeros((D,), jnp.float32))
            upd_cnt = st.upd_cnt.at[slot].set(jnp.zeros((R,), jnp.int32))
            upd_ks = st.upd_ks.at[slot].set(jnp.zeros((R,), jnp.int32))
            upd_kvec = (st.upd_kvec.at[slot].set(
                jnp.zeros((R, D), jnp.float32))
                if stratified else st.upd_kvec)
            h_counts = st.h_counts + cnt_total
            # staleness-at-apply census: slot r of ks_total counts the
            # arrivals whose sender saw broadcast counter r (mod R); the
            # true staleness tau = server_k - k_send is in [0, d-1], so
            # its mod-R residue against the PRE-cascade server_k is
            # exact — the host engine bins the same quantity per pair
            tau = (st.server_k - jnp.arange(R, dtype=jnp.int32)) & (R - 1)
            stale_hist = st.stale_hist.at[
                jnp.minimum(tau, S - 1)].add(ks_total)

            def casc_cond(c):
                sk, hc = c[0], c[1]
                return hc[sk & (R - 1)] >= C

            def casc_body(c):
                sk, hc, bc_v, bc_k, bc_at, nb = c
                hc = hc.at[sk & (R - 1)].set(0)
                sk = sk + 1
                b = sk & (B - 1)
                bc_v = bc_v.at[b].set(v)
                bc_k = bc_k.at[b].set(sk)
                bc_at = bc_at.at[b].set(t + plan.broadcast_ticks(sk))
                return (sk, hc, bc_v, bc_k, bc_at, nb + 1)

            (server_k, h_counts, bc_v, bc_k, bc_at,
             broadcasts) = lax.while_loop(
                casc_cond, casc_body,
                (st.server_k, h_counts, st.bc_v, st.bc_k, st.bc_at,
                 st.broadcasts))

            # 2) masked ISRRECEIVE: freshest due broadcast per client
            #    (ascending-k sequential delivery == keep only max k);
            #    the [C, D] gather+replace only runs on delivery ticks
            elig = (bc_at <= t) & (bc_k[:, None] > st.k[None, :])  # [B, C]
            eta = etas[jnp.minimum(st.i, etas.shape[0] - 1)]       # [C]

            def do_deliver(_):
                cand = jnp.where(elig, bc_k[:, None], 0)
                best = jnp.argmax(cand, axis=0)                    # [C]
                best_k = jnp.max(cand, axis=0)
                take = best_k > st.k
                # fused gather+receive (kernels/tick_fused): the ring
                # gather and the masked ISRRECEIVE in one [C, D] pass;
                # CPU reference = bc_v[best] - eta*U verbatim
                w = tick_deliver(st.w, st.U, bc_v, best, take, eta)
                return w, jnp.where(take, best_k, st.k)

            w, k = lax.cond(jnp.any(elig), do_deliver,
                            lambda _: (st.w, st.k), None)

            # 3) advance the cohort: credit accrual + one masked block.
            #    Availability gates compute, credit AND completion — an
            #    off client accrues nothing and sends nothing this tick.
            active = st.i < k + d_gate
            if avail_mask is not None:
                active = active & avail_mask(t)
            credit = st.credit + jnp.where(active, accrual, 0)
            s_i = sizes[cidx, jnp.minimum(st.i, sizes.shape[1] - 1)]
            n = jnp.where(active,
                          jnp.minimum(s_i - st.h, credit >> FRAC_BITS), 0)
            n = jnp.maximum(n, 0)
            credit = credit - (n << FRAC_BITS)
            # idle ticks (everyone blocked / awaiting credit) skip the
            # block entirely — mirrors the host engine's nmax > 0 guard
            any_block = jnp.any(n > 0)
            w, U = lax.cond(
                any_block,
                lambda ops: run_block(*ops),
                lambda ops: (ops[0], ops[1]),
                (w, st.U, st.i, st.h, n, eta))
            h = st.h + n

            # 4) round completions: clip/noise, bucket scatter, advance —
            #    all [C, D]-sized work gated on any round finishing
            done = active & (h >= s_i)
            done_i32 = done.astype(jnp.int32)
            any_done = jnp.any(done)
            messages = st.messages + jnp.sum(done_i32)
            part = st.part + done_i32
            bytes_up = st.bytes_up + done_i32 * upd_bytes

            # op census (repro.telemetry.costs): branch hits and row
            # counts, int-only so the float math is untouched.  The
            # delivery metrics re-evaluate do_deliver's take-mask
            # OUTSIDE its lax.cond (cheap [B, C] int compares); the
            # host engine counts clients whose k advanced — identical.
            dlv_take = jnp.max(jnp.where(elig, bc_k[:, None], 0),
                               axis=0) > st.k
            deliver_rows = jnp.sum(dlv_take.astype(jnp.int32))
            op_inc = jnp.stack([
                jnp.int32(1),                               # ticks
                any_block.astype(jnp.int32),                # block_ticks
                has_arrivals.astype(jnp.int32),             # bucket_applies
                (server_k > st.server_k).astype(jnp.int32),  # cascade_ticks
                (deliver_rows > 0).astype(jnp.int32),       # deliver_ticks
                deliver_rows,                               # deliver_rows
                jnp.int32(0),                   # ring_scatters (do_complete)
                any_done.astype(jnp.int32),                 # complete_ticks
                jnp.int32(0),                   # far_ticks (do_complete)
                jnp.int32(0),                   # far_groups (do_far)
            ])
            op_census = st.ops + op_inc

            def do_complete(ops):
                (w, U, upd_vec, upd_cnt, upd_ks, upd_kvec, ovf_vec,
                 ovf_at, ovf_cnt, ovf_ks, ovf_kvec, ovf_hwm, far_msgs,
                 err, op_census) = ops
                if dp_on:
                    nk = jax.random.fold_in(noise_base, t)
                    noised, _ = cohort_clip_noise(
                        U, nk, eta * done.astype(jnp.float32), done,
                        clip=dp_round_clip, noise_scale=noise_scale,
                        use_kernel=use_dp_kernel, interpret=interpret,
                        in_kernel_rng=in_kernel_rng)
                    sent = noised
                else:
                    sent = U
                # update latency addressed by (client, round) — st.i is
                # pre-increment, matching the host engine's draw point
                arr_off = plan.update_ticks(st.i)                  # [C]
                arr_slot = (t + arr_off) & (L - 1)
                # offsets past the ring go to the overflow bucket; the
                # ring (and its unrolled scatter) stays bounded by the
                # plan's ring_ticks, not the latency tail
                near = done & (arr_off < L) if F > 0 else done
                # ring scatter + DP w-consistency (Algorithm 1 line 24)
                # + U reset in ONE fused kernel call.  The per-row
                # masks / eta weights are the engines' historical
                # expressions precomputed as operands; the kernel's
                # reference path keeps each slot the host engine's
                # _weighted_sum over the full client axis under the
                # guarded add (rows with no arrivals stay bitwise
                # untouched — not old + 0), so host<->device bit parity
                # is unchanged.  FedAsync stratifies by the sender's
                # freshest-seen k (mod R): its [L, R, D] bucket
                # flattens to L*R scatter rows (sl-major, matching the
                # host's _make_strat_insert row loop).
                kmod = k & (R - 1) if stratified else None
                in_ls = [near & (arr_slot == sl) for sl in range(L)]
                if stratified:
                    masks = [in_l & (kmod == r)
                             for in_l in in_ls for r in range(R)]
                    rows = upd_kvec.reshape((L * R, D))
                else:
                    masks = in_ls
                    rows = upd_vec
                # distinct near slots scattered
                ring_sc = jnp.sum(jnp.stack(
                    [jnp.any(in_l) for in_l in in_ls]).astype(jnp.int32))
                wgt = jnp.stack([eta * m.astype(jnp.float32)
                                 for m in masks])                  # [G, C]
                any_g = jnp.stack([jnp.any(m) for m in masks])     # [G]
                w, U, rows = tick_scatter(sent, w, U, rows, wgt,
                                          any_g, done, eta, dp_on=dp_on)
                if stratified:
                    upd_kvec = rows.reshape((L, R, D))
                else:
                    upd_vec = rows
                oh_l = ((arr_slot[:, None] == jnp.arange(L)[None, :])
                        & near[:, None]).astype(jnp.int32)         # [C, L]
                oh_r = ((st.i & (R - 1))[:, None]
                        == jnp.arange(R)[None, :]).astype(jnp.int32)
                upd_cnt = upd_cnt + jnp.einsum("cl,cr->lr", oh_l, oh_r)
                # sender-k census ring, same layout keyed by the k each
                # finishing client saw at send (k is post-delivery for
                # this tick — the host engine reads st.k[c] at the same
                # point in its _finish_rounds)
                oh_s = ((k & (R - 1))[:, None]
                        == jnp.arange(R)[None, :]).astype(jnp.int32)
                upd_ks = upd_ks + jnp.einsum("cl,cr->lr", oh_l, oh_s)
                op_census = op_census.at[OP_RING_SCATTERS].add(ring_sc)
                if F > 0:
                    far_mask = done & (arr_off >= L)
                    arr_tick = t + arr_off
                    far_msgs = far_msgs + jnp.sum(
                        far_mask.astype(jnp.int32))
                    # do_far runs iff any(far_mask): counting its branch
                    # hit here (inside do_complete) is equivalent
                    op_census = op_census.at[OP_FAR_TICKS].add(
                        jnp.any(far_mask).astype(jnp.int32))

                    def do_far(fops):
                        (ovf_vec, ovf_at, ovf_cnt, ovf_ks, ovf_kvec,
                         ovf_hwm, err, op_census) = fops
                        far_grps = jnp.int32(0)
                        remaining = far_mask
                        # one unroll step per DISTINCT far arrival tick,
                        # ascending (matches the host's np.unique order);
                        # F = |{quantized bin values >= L}| bounds the
                        # distinct far ticks one completion can produce
                        for _ in range(F):
                            tick_q = jnp.min(jnp.where(
                                remaining, arr_tick,
                                jnp.int32(2 ** 31 - 1)))
                            grp = remaining & (arr_tick == tick_q)
                            any_grp = jnp.any(grp)
                            far_grps = far_grps + any_grp.astype(jnp.int32)
                            vec = jnp.sum(
                                sent * (eta
                                        * grp.astype(jnp.float32))[:, None],
                                axis=0)
                            cnt = jnp.sum(
                                oh_r * grp.astype(jnp.int32)[:, None],
                                axis=0)
                            cnt_ks = jnp.sum(
                                oh_s * grp.astype(jnp.int32)[:, None],
                                axis=0)
                            match = ovf_at == tick_q
                            has_match = jnp.any(match)
                            free = ovf_at == 0
                            ok = has_match | jnp.any(free)
                            idx = jnp.where(has_match, jnp.argmax(match),
                                            jnp.argmax(free))
                            write = any_grp & ok
                            if stratified:
                                # sender-k-stratified twin insert — the
                                # host runs _make_strat_insert on the
                                # same far bucket; guard per stratum so
                                # empty rows stay bitwise untouched
                                for r in range(R):
                                    grp_r = grp & (kmod == r)
                                    vec_r = jnp.sum(
                                        sent * (eta * grp_r.astype(
                                            jnp.float32))[:, None],
                                        axis=0)
                                    ovf_kvec = ovf_kvec.at[idx, r].set(
                                        jnp.where(
                                            write & jnp.any(grp_r),
                                            ovf_kvec[idx, r] + vec_r,
                                            ovf_kvec[idx, r]))
                            else:
                                ovf_vec = ovf_vec.at[idx].set(
                                    jnp.where(write, ovf_vec[idx] + vec,
                                              ovf_vec[idx]))
                            ovf_cnt = ovf_cnt.at[idx].set(
                                jnp.where(write, ovf_cnt[idx] + cnt,
                                          ovf_cnt[idx]))
                            ovf_ks = ovf_ks.at[idx].set(
                                jnp.where(write, ovf_ks[idx] + cnt_ks,
                                          ovf_ks[idx]))
                            ovf_at = ovf_at.at[idx].set(
                                jnp.where(write, tick_q, ovf_at[idx]))
                            err = err | (any_grp & ~ok).astype(jnp.int32)
                            remaining = remaining & ~grp
                        err = err | jnp.any(remaining).astype(jnp.int32)
                        # occupancy high-water mark, sampled after this
                        # tick's inserts — one occupied slot per pending
                        # far arrival tick, the host engine's
                        # len(far_contrib) at the same point
                        ovf_hwm = jnp.maximum(
                            ovf_hwm,
                            jnp.sum((ovf_at != 0).astype(jnp.int32)))
                        op_census = op_census.at[OP_FAR_GROUPS].add(
                            far_grps)
                        return (ovf_vec, ovf_at, ovf_cnt, ovf_ks,
                                ovf_kvec, ovf_hwm, err, op_census)

                    (ovf_vec, ovf_at, ovf_cnt, ovf_ks, ovf_kvec,
                     ovf_hwm, err, op_census) = lax.cond(
                        jnp.any(far_mask), do_far, lambda fops: fops,
                        (ovf_vec, ovf_at, ovf_cnt, ovf_ks, ovf_kvec,
                         ovf_hwm, err, op_census))
                return (w, U, upd_vec, upd_cnt, upd_ks, upd_kvec,
                        ovf_vec, ovf_at, ovf_cnt, ovf_ks, ovf_kvec,
                        ovf_hwm, far_msgs, err, op_census)

            (w, U, upd_vec, upd_cnt, upd_ks, upd_kvec, ovf_vec, ovf_at,
             ovf_cnt, ovf_ks, ovf_kvec, ovf_hwm, far_msgs, err,
             op_census) = lax.cond(
                any_done, do_complete, lambda ops: ops,
                (w, U, upd_vec, upd_cnt, upd_ks, upd_kvec, ovf_vec,
                 ovf_at, ovf_cnt, ovf_ks, ovf_kvec, st.ovf_hwm,
                 st.far_msgs, st.err, op_census))
            i = jnp.where(done, st.i + 1, st.i)
            h = jnp.where(done, 0, h)
            credit = jnp.where(
                done, jnp.minimum(credit, block << FRAC_BITS), credit)

            return DeviceCohortState(
                w=w, U=U, v=v, i=i, h=h, k=k, credit=credit,
                server_k=server_k, tick=t, upd_vec=upd_vec,
                upd_cnt=upd_cnt, h_counts=h_counts, bc_v=bc_v,
                bc_k=bc_k, bc_at=bc_at, ovf_vec=ovf_vec, ovf_at=ovf_at,
                ovf_cnt=ovf_cnt, err=err, messages=messages,
                broadcasts=broadcasts, part=part, bytes_up=bytes_up,
                stale_hist=stale_hist, upd_ks=upd_ks, ovf_ks=ovf_ks,
                ovf_hwm=ovf_hwm, far_msgs=far_msgs, upd_kvec=upd_kvec,
                ovf_kvec=ovf_kvec, buf_vec=buf_vec, buf_cnt=buf_cnt,
                ops=op_census, iters=st.iters)

        def predict_block(s):
            """Int-only preview of tick s.tick + 1's block predicate.

            Mirrors the deliver-k advance and credit accrual on the
            PRE-tick broadcast state; a cascade fired by the next tick
            itself (same-tick delivery) can make this wrong, which only
            shifts which iteration a block tick lands in — the merged
            tick is the full tick_fn, so the protocol state, the ops
            census, and the relations block_iters <= loop_iters <=
            ticks are exact regardless.
            """
            T = s.tick + 1
            elig2 = (s.bc_at <= T) & (s.bc_k[:, None] > s.k[None, :])
            best_k2 = jnp.max(jnp.where(elig2, s.bc_k[:, None], 0),
                              axis=0)
            k2 = jnp.where(best_k2 > s.k, best_k2, s.k)
            active2 = s.i < k2 + d_gate
            if avail_mask is not None:
                active2 = active2 & avail_mask(T)
            credit2 = s.credit + jnp.where(active2, accrual, 0)
            s_i2 = sizes[cidx, jnp.minimum(s.i, sizes.shape[1] - 1)]
            n2 = jnp.where(active2,
                           jnp.minimum(s_i2 - s.h,
                                       credit2 >> FRAC_BITS), 0)
            return jnp.any(jnp.maximum(n2, 0) > 0)

        def loop_body(st0: DeviceCohortState) -> DeviceCohortState:
            # tick coalescing (fuse_ticks): run the tick, and when the
            # NEXT tick (a) would run under the loop condition anyway
            # and (b) is predicted to do no client compute, run it in
            # the same while_loop iteration.  The merged tick is the
            # SAME tick_fn under the same condition the unfused loop
            # would have evaluated, so the tick sequence — and every
            # protocol/census counter — is identical bitwise; only the
            # iteration attribution in ``iters`` changes.  Overhead-only
            # ticks thus ride along with compute iterations instead of
            # costing a loop step of their own.
            st1 = tick_fn(st0)
            if fuse_ticks:
                merge = ((st1.server_k < target_k)
                         & (st1.tick < tick_limit) & (st1.err == 0)
                         & ~predict_block(st1))
                st2 = lax.cond(merge, tick_fn, lambda s: s, st1)
            else:
                st2 = st1
            had_block = (st2.ops[OP_BLOCK_TICKS]
                         > st0.ops[OP_BLOCK_TICKS]).astype(jnp.int32)
            return st2._replace(
                iters=st0.iters + jnp.stack([jnp.int32(1), had_block]))

        return lax.while_loop(
            lambda s: ((s.server_k < target_k) & (s.tick < tick_limit)
                       & (s.err == 0)),
            loop_body, st)

    return jax.jit(segment, donate_argnums=(0,))


class DeviceCohortEngine:
    """Drop-in engine with the ``CohortEngine`` constructor vocabulary,
    minus host-callable latency (see module docstring)."""

    def __init__(self, ctask, *, sizes_per_client,
                 round_stepsizes: Sequence[float], d: int = 1,
                 speeds: Optional[Sequence[float]] = None,
                 latency=None, seed: int = 0, block: int = 64,
                 dp_sigma: float = 0.0, dp_clip: float = 0.0,
                 dp_round_clip: float = 0.0, use_dp_kernel: bool = True,
                 interpret: Optional[bool] = None, scenario=None,
                 trace=None, dp_delta: float = 1e-5, strategy=None,
                 dp_rng: str = "operand", fuse_ticks: bool = True):
        self.ctask = ctask
        C = ctask.C
        self.C = C
        self.D = ctask.D
        self.d_gate = int(d)
        self.block = int(block)
        if (2 * self.block) << FRAC_BITS >= 2 ** 31:
            raise ValueError(
                f"block={block} overflows the device engine's int32 "
                f"fixed-point credit (max {(2 ** 30 >> FRAC_BITS) - 1}); "
                "use the host cohort engine for larger blocks")
        self.seed = int(seed)
        if scenario is not None and latency is not None:
            raise ValueError("pass either scenario= or latency=, not both")
        scn = (get_scenario(scenario) if scenario is not None
               else legacy_latency_scenario(latency))
        if speeds is None:
            speeds = scn.speeds(C, seed)
        self.speeds = np.asarray(speeds if speeds is not None
                                 else np.ones(C), np.float64)
        assert len(self.speeds) == C
        self.dt = self.block / float(self.speeds.max())
        self._plan = scenario_plan(scn, C=C, seed=self.seed, dt=self.dt)

        self.sizes = pad_sizes(sizes_per_client, C)
        self.etas = np.asarray(round_stepsizes, np.float64)

        from repro.core.tasks import validate_dp_knobs
        validate_dp_knobs(dp_clip, dp_sigma, "DeviceCohortEngine")
        self.dp_sigma = float(dp_sigma)
        self.dp_clip = float(dp_clip)
        self.dp_round_clip = float(dp_round_clip)
        self.use_dp_kernel = bool(use_dp_kernel)
        # interpret=None: infer from the backend — interpret-mode Pallas
        # on CPU (byte-identical to the historical default there), the
        # compiled kernel on a real TPU/GPU
        self.interpret = ((jax.default_backend() == "cpu")
                          if interpret is None else bool(interpret))
        # DP noise source: "operand" streams jax.random normals into the
        # clip+noise kernel (bitwise host-vs-device, the parity/golden
        # contract); "in_kernel" draws via pltpu.prng_random_bits inside
        # the kernel (TPU only — no HBM noise block, distributionally
        # equivalent, pinned by a chi-square test instead of bitwise)
        if dp_rng not in ("operand", "in_kernel"):
            raise ValueError(f"dp_rng={dp_rng!r} not in "
                             f"('operand', 'in_kernel')")
        if dp_rng == "in_kernel":
            if jax.default_backend() != "tpu":
                raise ValueError(
                    "dp_rng='in_kernel' needs a TPU backend: the "
                    "pltpu.prng_random_bits kernel has no CPU/GPU "
                    "lowering (use dp_rng='operand')")
            if not self.use_dp_kernel:
                raise ValueError("dp_rng='in_kernel' requires "
                                 "use_dp_kernel=True")
        self.dp_rng = dp_rng
        self.fuse_ticks = bool(fuse_ticks)
        self.dp_delta = float(dp_delta)
        self._trace = open_trace(trace)

        # ring capacities and the static per-tick block size: n is bounded
        # by the round size AND by the credit cap (2 * block post-accrual).
        # L covers the latency table's tail only up to the plan's
        # ring boundary (Scenario.ring_cap): draws quantizing past it go
        # to the Q-slot overflow bucket instead of widening the ring and
        # its unrolled scatter, so compile time/memory no longer scale
        # with next_pow2(max latency ticks) under heavy-tailed tables.
        # F bounds the distinct far arrival ticks one completion tick
        # can produce (the count of quantized bin values past the ring),
        # itself capped at FAR_UNROLL_CAP so a fine-binned per-client
        # table union cannot reintroduce tail-scaling compile cost —
        # a completion tick needing more far groups than the unroll
        # covers trips the err latch (raise ring_cap) instead.
        self.L = self._plan.ring_ticks
        self.F = min(len(self._plan.far_tick_values), FAR_UNROLL_CAP)
        self.Q = (next_pow2(min(C * (self.d_gate + 1),
                                self._plan.max_lat_ticks + 1, 128))
                  if self.F else 1)
        self.R = next_pow2(self.d_gate + 2)
        self.B = next_pow2(self.d_gate + 2)
        self.strategy = get_strategy(strategy)
        self.b_stat = next_pow2(
            max(1, min(2 * self.block, int(self.sizes.max()))))

        self.mesh = cohort_mesh()
        self._shardings = cohort_shardings(self.mesh, C)
        self.state = self._init_state()
        self._etas_dev = jnp.asarray(self.etas, jnp.float32)
        self._sizes_dev = jax.device_put(
            jnp.asarray(self.sizes, jnp.int32), self._shardings["w"])
        self._accrual_dev = jax.device_put(
            jnp.asarray(speed_accrual(self.speeds, self.block), jnp.int32),
            self._shardings["credit"])
        self.history: List[Dict[str, float]] = []

    def _init_state(self) -> DeviceCohortState:
        C, D, L, R, B, Q = self.C, self.D, self.L, self.R, self.B, self.Q
        v0 = jnp.asarray(self.ctask.init_flat(), jnp.float32)
        # four distinct buffers — donation rejects aliased arguments
        zc = lambda: jnp.zeros((C,), jnp.int32)  # noqa: E731
        fields = dict(
            w=jnp.tile(v0[None, :], (C, 1)),
            U=jnp.zeros((C, D), jnp.float32),
            v=v0, i=zc(), h=zc(), k=zc(), credit=zc(),
            server_k=jnp.int32(0), tick=jnp.int32(0),
            upd_vec=jnp.zeros((L, D), jnp.float32),
            upd_cnt=jnp.zeros((L, R), jnp.int32),
            h_counts=jnp.zeros((R,), jnp.int32),
            bc_v=jnp.zeros((B, D), jnp.float32),
            bc_k=jnp.zeros((B,), jnp.int32),
            bc_at=jnp.zeros((B, C), jnp.int32),
            ovf_vec=jnp.zeros((Q, D), jnp.float32),
            ovf_at=jnp.zeros((Q,), jnp.int32),
            ovf_cnt=jnp.zeros((Q, R), jnp.int32),
            err=jnp.int32(0),
            messages=jnp.int32(0), broadcasts=jnp.int32(0),
            part=zc(), bytes_up=zc(),
            stale_hist=jnp.zeros((STALE_BINS,), jnp.int32),
            upd_ks=jnp.zeros((L, R), jnp.int32),
            ovf_ks=jnp.zeros((Q, R), jnp.int32),
            ovf_hwm=jnp.int32(0), far_msgs=jnp.int32(0),
            # aggregation-strategy buffers: full-size only when the
            # strategy uses them ([1, ...] dummies otherwise keep the
            # donated state pytree small under the paper default)
            upd_kvec=jnp.zeros((L, R, D) if self.strategy.stratified
                               else (1, 1, 1), jnp.float32),
            ovf_kvec=jnp.zeros((Q, R, D) if self.strategy.stratified
                               else (1, 1, 1), jnp.float32),
            buf_vec=jnp.zeros((D,) if self.strategy.buffered else (1,),
                              jnp.float32),
            buf_cnt=jnp.int32(0),
            ops=jnp.zeros((N_OPS,), jnp.int32),
            iters=jnp.zeros((2,), jnp.int32))
        return DeviceCohortState(**{
            f: jax.device_put(val, self._shardings[f])
            for f, val in fields.items()})

    # -- compiled segment (cached on the cohort task, like its block fns) --
    def _segment_fn(self):
        key = ("device_segment", self.C, self.D, self.block, self.b_stat,
               self.d_gate, self.L, self.R, self.B, self.Q,
               self._plan.fingerprint(), self.dp_clip, self.dp_sigma,
               self.dp_round_clip, self.use_dp_kernel, self.interpret,
               self.dp_rng, self.fuse_ticks, self.seed,
               self.strategy.fingerprint())
        cache = getattr(self.ctask, "_segment_fns", None)
        if cache is None:
            cache = self.ctask._segment_fns = {}
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = _build_segment(
                self.ctask, C=self.C, D=self.D, block=self.block,
                b_stat=self.b_stat, d_gate=self.d_gate, L=self.L,
                R=self.R, B=self.B, Q=self.Q, F=self.F,
                plan=self._plan, dp_clip=self.dp_clip,
                dp_sigma=self.dp_sigma, dp_round_clip=self.dp_round_clip,
                use_dp_kernel=self.use_dp_kernel,
                interpret=self.interpret,
                in_kernel_rng=(self.dp_rng == "in_kernel"),
                fuse_ticks=self.fuse_ticks, seed=self.seed,
                strategy=self.strategy)
        return fn

    @property
    def fused_iters(self):
        """(loop_iters, block_iters): while_loop iterations executed and
        how many contained a block tick — the tick-coalescing census the
        bench's ``tick_overhead_ratio`` is computed from (syncs)."""
        it = np.asarray(self.state.iters)
        return int(it[0]), int(it[1])

    @property
    def total_messages(self) -> int:
        return int(self.state.messages)

    @property
    def total_broadcasts(self) -> int:
        return int(self.state.broadcasts)

    # -- main loop ----------------------------------------------------------
    def run(self, *, max_rounds: int, eval_every: int = 1,
            eval_fn: Optional[Callable] = None,
            max_ticks: Optional[int] = None) -> Dict[str, Any]:
        """Run until the server completes ``max_rounds`` broadcasts.

        Same result schema as ``AsyncFLSimulator.run`` /
        ``CohortEngine.run``; the device is synced once per eval segment.
        """
        if eval_fn is not None:
            evals = lambda vec: eval_fn(self.ctask.unflatten(vec))  # noqa: E731
        else:
            evals = self.ctask.metrics
        if max_ticks is None:
            max_ticks = default_max_ticks(
                self.sizes, self.speeds, self.block, max_rounds,
                lat_tail_ticks=self._plan.max_lat_ticks,
                duty=self._plan.duty)
        seg = self._segment_fn()
        st = self.state
        next_eval = eval_every
        # kept on the engine so the timeline CLI (python -m
        # repro.telemetry capture) can export the wall spans after run()
        timer = self.timer = PhaseTimer()
        first_segment = True
        while True:
            target = min(next_eval, max_rounds)
            # scalar segment bounds are committed to device OUTSIDE the
            # transfer guard below — the guarded steady dispatch must
            # see device-resident operands only
            tgt = jnp.int32(target)
            lim = jnp.int32(max_ticks)
            with timer.phase("first_segment" if first_segment
                             else "steady"):
                if first_segment:
                    # compile + closure-constant upload happen here
                    st = seg(st, self._etas_dev, self._sizes_dev,
                             self._accrual_dev, tgt, lim)
                else:
                    # runtime sanitizer (parity contract): a steady
                    # segment performs ZERO implicit host<->device
                    # transfers between eval syncs — a hidden transfer
                    # raises here instead of silently serializing the
                    # jitted tick loop
                    with jax.transfer_guard("disallow"):
                        st = seg(st, self._etas_dev, self._sizes_dev,
                                 self._accrual_dev, tgt, lim)
                self.state = st
                sk = int(st.server_k)        # the one sync per segment
                # phase-accurate timing: the while_loop's outputs
                # materialize together, but make the boundary explicit
                # so async dispatch can never charge segment work to
                # the eval phase that follows
                jax.block_until_ready(st.v)
            first_segment = False
            if sk < target:
                if int(st.err) != 0:
                    raise RuntimeError(
                        f"device engine overflow bucket exhausted at "
                        f"tick {int(st.tick)} (Q={self.Q} slots, "
                        f"F={self.F} far groups/tick, ring L={self.L}):"
                        f" too many distinct far arrival ticks in "
                        f"flight — raise Scenario.ring_cap (now "
                        f"{self._plan.scenario.ring_cap}) or shorten "
                        f"the latency tail")
                raise RuntimeError(
                    f"cohort engine stalled: {int(st.tick)} ticks, "
                    f"server_k={sk} < {max_rounds} "
                    f"(in flight: "
                    f"{int(jnp.sum(st.upd_cnt)) + int(jnp.sum(st.ovf_cnt))}"
                    f" updates, "
                    f"{int(jnp.sum(jnp.any(st.bc_at > st.tick, axis=1)))}"
                    f" broadcasts)")
            if sk >= next_eval:
                with timer.phase("eval"):
                    m = evals(st.v)
                    m.update(round=sk, time=int(st.tick) * self.dt,
                             messages=int(st.messages))
                    self.history.append(m)
                    next_eval = sk + eval_every
                    self._emit_segment()
            if sk >= max_rounds:
                break
        with timer.phase("eval"):
            final = evals(st.v)
        # overflow telemetry surfaced for ring_cap tuning: the high-water
        # mark against the Q-slot capacity plus the far-routed share
        final.update(round=sk, time=int(st.tick) * self.dt,
                     messages=int(st.messages),
                     broadcasts=int(st.broadcasts),
                     overflow_hwm=int(st.ovf_hwm),
                     overflow_slots=self.Q if self.F else 0,
                     far_messages=int(st.far_msgs))
        report = self.telemetry_report(wall=timer.as_dict())
        if self._trace:
            self._trace.emit("report", **report.to_dict())
            self._trace.close()
        return {"final": final, "history": self.history,
                "model": self.ctask.unflatten(st.v), "telemetry": report}

    # -- telemetry ----------------------------------------------------------
    def _emit_segment(self) -> None:
        if not self._trace:
            return
        st = self.state
        self._trace.emit(
            "segment", engine="device", round=int(st.server_k),
            tick=int(st.tick), time=int(st.tick) * self.dt,
            messages=int(st.messages),
            broadcasts=int(st.broadcasts),
            bytes_up_total=int(np.asarray(st.bytes_up,
                                          dtype=np.int64).sum()),
            staleness_hist=np.asarray(st.stale_hist),
            overflow_hwm=int(st.ovf_hwm),
            ops=np.asarray(st.ops))

    def telemetry_report(self, wall=None):
        """MetricsReport from the on-device counters (syncs the state)."""
        st = self.state
        src_task = getattr(self.ctask, "task", None)
        return build_report(
            engine="device", clients=self.C, flat_dim=self.D,
            rounds=int(st.server_k), messages=int(st.messages),
            broadcasts=int(st.broadcasts),
            participation=np.asarray(st.part, dtype=np.int64),
            bytes_up=np.asarray(st.bytes_up, dtype=np.int64),
            staleness_hist=np.asarray(st.stale_hist, dtype=np.int64),
            overflow_hwm=int(st.ovf_hwm),
            overflow_slots=self.Q if self.F else 0,
            far_messages=int(st.far_msgs),
            ticks=int(st.tick),
            ops=np.asarray(st.ops, dtype=np.int64),
            dp_sigma=self.dp_sigma, dp_delta=self.dp_delta,
            n_examples=(int(src_task.X.shape[0])
                        if hasattr(src_task, "X") else None),
            sizes_per_client=self.sizes, wall=wall)
