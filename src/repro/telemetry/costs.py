"""In-loop op-census: per-segment cost attribution for the tick engines.

The census counters of PR 6 say *what* the protocol did (messages,
broadcasts, staleness); the op census says *which tick-loop operations
did it*, so a ``BENCH_cohort.json`` steady-state number can be
decomposed into cost-per-op and the ROADMAP roofline item ("~half the
device protocol time sits outside ``run_block``") gets per-op evidence
instead of a guess.

The counters live INSIDE the jitted ``lax.while_loop`` as one
``[N_OPS]`` int32 vector on ``DeviceCohortState`` (``ops``), covered by
``cohort_pspecs`` and threaded through the same ``lax.cond`` operand
tuples as the PR 6 census, so they never perturb the float math; the
host engine mirrors them with numpy increments at the exact same
protocol points.  The parity contract extends to them: host vs device
is BITWISE equal on every scenario preset and strategy.

Counter semantics (all cumulative over the run):

  ``ticks``            protocol ticks executed
  ``block_ticks``      ticks where >= 1 client ran block iterations
                       (the ``run_block``/``nmax > 0`` gate)
  ``bucket_applies``   ticks whose arrival bucket was non-empty (the
                       server's ``v -= bucket`` apply ran)
  ``cascade_ticks``    ticks where the broadcast cascade fired (the
                       server's completed-round counter advanced)
  ``deliver_ticks``    ticks where >= 1 client's freshest-seen k
                       advanced (the [C, D] ISRRECEIVE gather ran)
  ``deliver_rows``     clients whose freshest-seen k advanced, summed
                       over ticks (rows the delivery gather replaced)
  ``ring_scatters``    distinct near-tier ring slots scattered into by
                       finishing cohorts (the unrolled per-slot
                       masked-sum writes that actually ran)
  ``complete_ticks``   ticks where >= 1 round completed (``do_complete``
                       branch hits)
  ``far_ticks``        completion ticks that routed >= 1 update to the
                       far tier (``do_far`` branch hits)
  ``far_groups``       distinct far arrival-tick groups inserted into
                       the overflow bucket

Relations the trace checker enforces (rule INV-SPAN, see
``repro.analysis.invariants``): tick-gated counters are bounded by
``ticks``; ``complete_ticks <= messages``; ``ring_scatters <=
messages - far_messages``; ``far_ticks <= far_groups <=
far_messages``; ``bucket_applies <= ring_scatters + far_groups``;
``cascade_ticks <= broadcasts``; ``deliver_rows <= broadcasts * C``.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

#: op-census counter names, in vector order (index = position)
OP_NAMES = (
    "ticks",
    "block_ticks",
    "bucket_applies",
    "cascade_ticks",
    "deliver_ticks",
    "deliver_rows",
    "ring_scatters",
    "complete_ticks",
    "far_ticks",
    "far_groups",
)
N_OPS = len(OP_NAMES)

# index constants (used by both engines' increment sites)
OP_TICKS = OP_NAMES.index("ticks")
OP_BLOCK_TICKS = OP_NAMES.index("block_ticks")
OP_BUCKET_APPLIES = OP_NAMES.index("bucket_applies")
OP_CASCADE_TICKS = OP_NAMES.index("cascade_ticks")
OP_DELIVER_TICKS = OP_NAMES.index("deliver_ticks")
OP_DELIVER_ROWS = OP_NAMES.index("deliver_rows")
OP_RING_SCATTERS = OP_NAMES.index("ring_scatters")
OP_COMPLETE_TICKS = OP_NAMES.index("complete_ticks")
OP_FAR_TICKS = OP_NAMES.index("far_ticks")
OP_FAR_GROUPS = OP_NAMES.index("far_groups")

#: counters incremented at most once per tick — each is bounded by
#: ``ticks`` (INV-SPAN uses this split)
TICK_GATED = ("block_ticks", "bucket_applies", "cascade_ticks",
              "deliver_ticks", "complete_ticks", "far_ticks")


def zero_ops() -> np.ndarray:
    """Host-side zero op-census vector (int64 accumulator)."""
    return np.zeros(N_OPS, dtype=np.int64)


def ops_dict(ops: Union[Sequence[int], np.ndarray, None]
             ) -> Optional[Dict[str, int]]:
    """[N_OPS] vector -> name-keyed dict (None passes through)."""
    if ops is None:
        return None
    vals = [int(x) for x in np.asarray(ops).reshape(-1)]
    if len(vals) != N_OPS:
        raise ValueError(
            f"op-census vector has {len(vals)} entries, want {N_OPS} "
            f"({', '.join(OP_NAMES)})")
    return dict(zip(OP_NAMES, vals))


def ops_vector(ops: Optional[Mapping[str, int]]) -> np.ndarray:
    """Name-keyed dict -> [N_OPS] int64 vector (unknown keys rejected)."""
    out = zero_ops()
    if ops:
        for name, val in ops.items():
            if name not in OP_NAMES:
                raise ValueError(f"unknown op-census counter {name!r}")
            out[OP_NAMES.index(name)] = int(val)
    return out


def cost_decomposition(ops: Mapping[str, int], *,
                       steady_s: Optional[float] = None,
                       ticks: Optional[int] = None,
                       loop_iters: Optional[int] = None,
                       block_iters: Optional[int] = None
                       ) -> Dict[str, float]:
    """Per-op share of a steady-state run, for BENCH_cohort.json.

    With ``steady_s`` given, adds ``s_per_tick`` (amortized wall seconds
    per protocol tick) so entries can be compared across workloads; the
    ``tick_overhead_ratio`` is the roofline item's number.  Without the
    iteration census it is the fraction of ticks that did protocol-only
    work (no client compute block ran).  When the device engine's tick
    coalescing is on, overhead ticks ride along inside compute
    iterations, so what the roofline actually pays is while_loop
    ITERATIONS — pass ``loop_iters`` / ``block_iters``
    (``DeviceCohortEngine.fused_iters``) and the ratio becomes the
    fraction of loop iterations that ran without a compute block,
    alongside ``ticks_per_iter`` (how many protocol ticks one iteration
    amortizes, in [1, 2]).
    """
    t = int(ticks if ticks is not None else ops.get("ticks", 0))
    out: Dict[str, float] = {}
    if t > 0:
        for name in OP_NAMES:
            out[f"{name}_per_tick"] = ops.get(name, 0) / t
        out["tick_overhead_ratio"] = 1.0 - ops.get("block_ticks", 0) / t
        if steady_s is not None:
            out["s_per_tick"] = float(steady_s) / t
        if loop_iters is not None and int(loop_iters) > 0:
            li = int(loop_iters)
            out["loop_iters"] = float(li)
            out["ticks_per_iter"] = t / li
            out["tick_overhead_ratio"] = 1.0 - int(block_iters or 0) / li
    return out


def check_ops(ops: Mapping[str, int], *,
              messages: Optional[int] = None,
              broadcasts: Optional[int] = None,
              far_messages: Optional[int] = None,
              clients: Optional[int] = None,
              ticks: Optional[int] = None,
              loop_iters: Optional[int] = None,
              block_iters: Optional[int] = None) -> List[str]:
    """Internal-consistency relations of one op-census dict.

    Returns human-readable problem strings; the trace checker wraps
    them as INV-SPAN violations.  Only relations whose inputs are
    provided are checked.
    """
    problems: List[str] = []
    get = lambda k: int(ops.get(k, 0))  # noqa: E731
    for name in OP_NAMES:
        if get(name) < 0:
            problems.append(f"op counter {name} is negative: {get(name)}")
    t = int(ticks) if ticks is not None else get("ticks")
    for name in TICK_GATED:
        if get(name) > t:
            problems.append(
                f"tick-gated op counter {name}={get(name)} exceeds "
                f"ticks={t}")
    if ticks is not None and get("ticks") != int(ticks):
        problems.append(
            f"op counter ticks={get('ticks')} != report ticks={ticks}")
    if messages is not None:
        if get("complete_ticks") > int(messages):
            problems.append(
                f"complete_ticks={get('complete_ticks')} exceeds "
                f"messages={messages} (a completion tick sends >= 1)")
        near = int(messages) - int(far_messages or 0)
        if get("ring_scatters") > near:
            problems.append(
                f"ring_scatters={get('ring_scatters')} exceeds near-tier "
                f"messages={near} (a scatter needs >= 1 near arrival)")
        if get("bucket_applies") > (get("ring_scatters")
                                    + get("far_groups")):
            problems.append(
                f"bucket_applies={get('bucket_applies')} exceeds "
                f"ring_scatters + far_groups = "
                f"{get('ring_scatters') + get('far_groups')} (an applied "
                f"bucket comes from >= 1 insert)")
    if far_messages is not None:
        if get("far_groups") > int(far_messages):
            problems.append(
                f"far_groups={get('far_groups')} exceeds "
                f"far_messages={far_messages}")
        if get("far_ticks") > get("far_groups"):
            problems.append(
                f"far_ticks={get('far_ticks')} exceeds "
                f"far_groups={get('far_groups')}")
    if broadcasts is not None:
        if get("cascade_ticks") > int(broadcasts):
            problems.append(
                f"cascade_ticks={get('cascade_ticks')} exceeds "
                f"broadcasts={broadcasts} (a cascade tick fires >= 1)")
        if clients is not None and get("deliver_rows") > \
                int(broadcasts) * int(clients):
            problems.append(
                f"deliver_rows={get('deliver_rows')} exceeds "
                f"broadcasts * clients = {int(broadcasts) * int(clients)}"
                f" (a client advances k at most once per broadcast)")
    if get("deliver_ticks") > get("deliver_rows"):
        problems.append(
            f"deliver_ticks={get('deliver_ticks')} exceeds "
            f"deliver_rows={get('deliver_rows')}")
    if loop_iters is not None:
        li, bi = int(loop_iters), int(block_iters or 0)
        # tick coalescing merges at most two ticks per iteration, and
        # an iteration holds at most one block tick
        if not bi <= li <= t <= 2 * li:
            problems.append(
                f"iteration census violates block_iters <= loop_iters "
                f"<= ticks <= 2 * loop_iters: ({bi}, {li}, {t})")
        if get("block_ticks") < bi:
            problems.append(
                f"block_iters={bi} exceeds block_ticks="
                f"{get('block_ticks')} (an iteration's block came from "
                f">= 1 block tick)")
    return problems
