"""MetricsReport — the single telemetry schema all three engines emit.

The paper's headline claims are *fewer rounds* and *less aggregated
Gaussian noise*; both are communication/privacy statements, so the
report is built around three integer counter families that every engine
must agree on:

  * communication census — per-client update messages sent
    (``participation``) and bytes on the wire (``bytes_up``); downlink
    bytes are derived, since every fired broadcast fans out to the full
    fleet (Algorithm 3 broadcasts to all clients).
  * staleness-at-apply — for each applied update, how many server
    rounds elapsed between the sender's freshest-seen broadcast counter
    ``k`` at send time and the server's completed-round counter when the
    update is folded into ``v``.  The wait gate (Supp. B.2) bounds this
    by ``d - 1``, so the histogram doubles as a runtime check of the
    gate.  Binned into ``STALE_BINS`` fixed bins (last bin is
    overflow) so the device engine can hold it as a fixed-shape array
    inside the jitted tick loop.
  * overflow-bucket high-water mark — peak simultaneous occupancy of
    the device engine's far-tier arrival slots (host engines report the
    equivalent: peak pending far-tick buckets), the datum for tuning
    ``Scenario.ring_cap``.

Wire model: payloads travel as float32, one element per flat model
coordinate, plus a fixed ``HEADER_BYTES`` envelope (round, client, k,
length).  Integer byte counts are therefore exact and engine-invariant.

Counter parity contract: ``participation``, ``bytes_up``, ``messages``,
``broadcasts`` and ``staleness_hist`` are bitwise identical between the
host and device cohort engines, and exactly equal to the event
simulator's ground truth at ``d = 1`` under deterministic scenarios (at
``d > 1`` the event sim applies updates message-by-message while the
cohort engines merge a tick's arrivals before the cascade, so the
*histogram* may legitimately differ across the event/tick boundary; the
census counters still agree).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.telemetry.costs import ops_dict

# Fixed number of staleness bins: bins 0..STALE_BINS-2 count exact
# staleness values, the last bin absorbs everything >= STALE_BINS-1.
STALE_BINS = 8
# Message envelope: (round, client, k, payload length) as 4 x int32.
HEADER_BYTES = 16
F32_BYTES = 4


def update_msg_bytes(flat_dim: int) -> int:
    """Bytes on the wire for one client->server update message."""
    return HEADER_BYTES + F32_BYTES * int(flat_dim)


def broadcast_msg_bytes(flat_dim: int) -> int:
    """Bytes on the wire for one server->client broadcast copy."""
    return HEADER_BYTES + F32_BYTES * int(flat_dim)


def model_flat_dim(model: Any) -> int:
    """Total scalar count of a model pytree (numpy/jax leaves)."""
    import jax
    return int(sum(int(np.prod(np.shape(leaf)))
                   for leaf in jax.tree_util.tree_leaves(model)))


def staleness_bin(tau: int) -> int:
    return min(int(tau), STALE_BINS - 1)


@dataclass
class MetricsReport:
    """Uniform cross-engine telemetry record.

    Integer counters are numpy int64 arrays / Python ints; everything an
    engine cannot measure stays at its zero/None default, so reports
    from different engines share one schema.
    """
    engine: str                      # "event" | "host" | "device"
    clients: int
    flat_dim: int
    rounds: int                      # server completed-round counter
    messages: int                    # client->server updates sent
    broadcasts: int                  # server broadcasts fired
    update_msg_bytes: int
    broadcast_msg_bytes: int
    participation: np.ndarray        # [C] int64 — updates sent per client
    bytes_up: np.ndarray             # [C] int64 — uplink bytes per client
    bytes_down: np.ndarray           # [C] int64 — downlink bytes per client
    staleness_hist: np.ndarray       # [STALE_BINS] int64 — at-apply bins
    overflow_hwm: int = 0            # peak far-tier arrival-slot occupancy
    overflow_slots: Optional[int] = None   # device capacity (Q); None=host
    far_messages: int = 0            # updates that landed in the far tier
    ticks: Optional[int] = None      # cohort engines only
    virtual_time: Optional[float] = None   # event sim only (seconds)
    dp: Optional[List[Dict[str, Any]]] = None  # per-client accounting rows
    wall: Dict[str, float] = field(default_factory=dict)  # profiling
    # op census (repro.telemetry.costs): which tick-loop operations ran,
    # keyed by costs.OP_NAMES — cohort engines only, bitwise
    # host-vs-device like the counters above
    ops: Optional[Dict[str, int]] = None

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for f in dataclasses.fields(self):
            val = getattr(self, f.name)
            if isinstance(val, np.ndarray):
                out[f.name] = [int(x) for x in val]
            elif isinstance(val, (np.integer,)):
                out[f.name] = int(val)
            elif isinstance(val, (np.floating,)):
                out[f.name] = float(val)
            else:
                out[f.name] = val
        return out

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **kw)

    def summary(self) -> str:
        """One-paragraph human summary (for examples / logs)."""
        up = int(self.bytes_up.sum())
        down = int(self.bytes_down.sum())
        hist = "/".join(str(int(x)) for x in self.staleness_hist)
        lines = [
            f"[{self.engine}] rounds={self.rounds} "
            f"messages={self.messages} broadcasts={self.broadcasts}",
            f"  bytes up={up} down={down} "
            f"(msg={self.update_msg_bytes}B, bcast={self.broadcast_msg_bytes}B)",
            f"  staleness hist [0..{STALE_BINS - 2},{STALE_BINS - 1}+]: {hist}",
            f"  overflow hwm={self.overflow_hwm}"
            + (f"/{self.overflow_slots}" if self.overflow_slots else "")
            + f" far_messages={self.far_messages}",
        ]
        if self.dp:
            eps = [r["epsilon"] for r in self.dp if r["epsilon"] is not None]
            if eps:
                lines.append(f"  dp: max per-client epsilon={max(eps):.4g} "
                             f"over {len(self.dp)} clients")
        if self.ops:
            frag = " ".join(f"{k}={int(v)}" for k, v in self.ops.items())
            lines.append(f"  ops: {frag}")
        if self.wall:
            # phase entries are seconds (``_s``); their span counts
            # (``_n``, see SpanRecorder.as_dict) are plain integers
            frag = " ".join(
                f"{k}={v:.3g}s" if k.endswith("_s") else f"{k}={int(v)}"
                for k, v in self.wall.items())
            lines.append(f"  wall: {frag}")
        return "\n".join(lines)


def participation_sizes(sizes_per_client: Sequence[Sequence[int]],
                        participation: Sequence[int]
                        ) -> List[List[int]]:
    """Per-client list of sample sizes for the rounds actually sent.

    ``sizes_per_client[c]`` is the round-schedule s_{i,c} (the last entry
    repeats past the end, matching ``Client.s``); the returned row for
    client c has exactly ``participation[c]`` entries — the sample sizes
    the moments accountant must charge for that client.
    """
    rows: List[List[int]] = []
    for c, done in enumerate(participation):
        sched = list(sizes_per_client[c])
        rows.append([sched[min(i, len(sched) - 1)] for i in range(int(done))])
    return rows


def build_report(*, engine: str, clients: int, flat_dim: int, rounds: int,
                 messages: int, broadcasts: int,
                 participation: np.ndarray, bytes_up: np.ndarray,
                 staleness_hist: np.ndarray,
                 overflow_hwm: int = 0, overflow_slots: Optional[int] = None,
                 far_messages: int = 0,
                 ticks: Optional[int] = None,
                 virtual_time: Optional[float] = None,
                 dp_sigma: float = 0.0, dp_delta: float = 1e-5,
                 n_examples: Optional[int] = None,
                 sizes_per_client: Optional[Sequence[Sequence[int]]] = None,
                 wall: Optional[Dict[str, float]] = None,
                 ops=None) -> MetricsReport:
    """Assemble a MetricsReport from raw engine counters.

    Derives bytes_down (every fired broadcast reaches the whole fleet)
    and, when ``dp_sigma > 0`` and the dataset size is known, the
    per-client DP accounting rows from the rounds each client actually
    contributed.
    """
    ub = update_msg_bytes(flat_dim)
    bb = broadcast_msg_bytes(flat_dim)
    part = np.asarray(participation, dtype=np.int64)
    bup = np.asarray(bytes_up, dtype=np.int64)
    bdown = np.full(clients, int(broadcasts) * bb, dtype=np.int64)
    dp_rows = None
    if (dp_sigma > 0 and n_examples is not None
            and sizes_per_client is not None and len(sizes_per_client)):
        from repro.dp.accountant import per_client_accounting
        rows = participation_sizes(sizes_per_client, part)
        dp_rows = per_client_accounting(rows, n_examples, dp_sigma, dp_delta)
    return MetricsReport(
        engine=engine, clients=int(clients), flat_dim=int(flat_dim),
        rounds=int(rounds), messages=int(messages),
        broadcasts=int(broadcasts),
        update_msg_bytes=ub, broadcast_msg_bytes=bb,
        participation=part, bytes_up=bup, bytes_down=bdown,
        staleness_hist=np.asarray(staleness_hist, dtype=np.int64),
        overflow_hwm=int(overflow_hwm), overflow_slots=overflow_slots,
        far_messages=int(far_messages), ticks=ticks,
        virtual_time=virtual_time, dp=dp_rows, wall=dict(wall or {}),
        ops=(ops if isinstance(ops, dict) or ops is None
             else ops_dict(ops)))
