"""Telemetry: communication census, staleness/participation metrics,
per-client DP accounting, JSONL traces, and phase profiling — one
``MetricsReport`` schema shared by all three engines."""
from repro.telemetry.report import (
    HEADER_BYTES, STALE_BINS, MetricsReport, broadcast_msg_bytes,
    build_report, model_flat_dim, participation_sizes, staleness_bin,
    update_msg_bytes,
)
from repro.telemetry.trace import JsonlTraceWriter, open_trace
from repro.telemetry.profiling import PhaseTimer

__all__ = [
    "HEADER_BYTES", "STALE_BINS", "MetricsReport", "broadcast_msg_bytes",
    "build_report", "model_flat_dim", "participation_sizes",
    "staleness_bin", "update_msg_bytes",
    "JsonlTraceWriter", "open_trace", "PhaseTimer",
]
