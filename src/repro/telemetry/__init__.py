"""Telemetry: communication census, staleness/participation metrics,
per-client DP accounting, JSONL traces, the in-loop op census, and
span-based profiling with Perfetto timeline export — one
``MetricsReport`` schema shared by all three engines."""
from repro.telemetry.costs import (
    N_OPS, OP_NAMES, check_ops, cost_decomposition, ops_dict, ops_vector,
    zero_ops,
)
from repro.telemetry.report import (
    HEADER_BYTES, STALE_BINS, MetricsReport, broadcast_msg_bytes,
    build_report, model_flat_dim, participation_sizes, staleness_bin,
    update_msg_bytes,
)
from repro.telemetry.spans import (
    PhaseTimer, SpanRecorder, trace_to_perfetto, validate_trace_events,
    write_perfetto,
)
from repro.telemetry.trace import JsonlTraceWriter, open_trace

__all__ = [
    "HEADER_BYTES", "STALE_BINS", "MetricsReport", "broadcast_msg_bytes",
    "build_report", "model_flat_dim", "participation_sizes",
    "staleness_bin", "update_msg_bytes",
    "JsonlTraceWriter", "open_trace",
    "PhaseTimer", "SpanRecorder", "trace_to_perfetto",
    "validate_trace_events", "write_perfetto",
    "N_OPS", "OP_NAMES", "check_ops", "cost_decomposition", "ops_dict",
    "ops_vector", "zero_ops",
]
