"""Wall-clock phase profiling for engines and benchmarks.

JAX engines spend their first call tracing + compiling; steady-state
throughput claims are meaningless unless that phase is split out.
``PhaseTimer`` accumulates named wall-clock phases (re-entering a phase
adds to it) and serializes to a plain dict for MetricsReport.wall and
BENCH_cohort.json.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict


class PhaseTimer:
    def __init__(self):
        self.phases: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.phases[name] = self.phases.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def add(self, name: str, seconds: float) -> None:
        self.phases[name] = self.phases.get(name, 0.0) + float(seconds)
        self.counts[name] = self.counts.get(name, 0) + 1

    def as_dict(self, suffix: str = "_s") -> Dict[str, float]:
        return {f"{k}{suffix}": v for k, v in self.phases.items()}
