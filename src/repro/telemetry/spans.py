"""Span recording + Chrome/Perfetto trace-event export, on dual clocks.

``SpanRecorder`` subsumes the old ``PhaseTimer``: it still accumulates
named wall-clock phases for ``MetricsReport.wall`` / BENCH_cohort.json
(now exporting the re-entry *counts* alongside the seconds), but it also
keeps every individual span — (name, track, start, duration) — so a run
can be rendered as a timeline instead of a histogram.

Export targets the Chrome trace-event JSON the Perfetto UI loads
(https://ui.perfetto.dev, legacy JSON importer): complete ``"X"`` slices
for engine phases and eval segments, instant ``"i"`` + flow ``"s"``/
``"f"`` + async ``"b"``/``"e"`` events for message lifecycles.  Two
clocks coexist as two trace *processes*:

  * **wall** — real seconds from the recorder's epoch (compile/warmup/
    steady/eval engine phases, optionally bracketed with
    ``jax.profiler.TraceAnnotation`` so the same names show up inside an
    XLA profile);
  * **virtual protocol seconds** — reconstructed from the PR 6 JSONL
    trace (``repro.telemetry.trace``): the event sim's per-message
    records become send→apply / broadcast→deliver flow arrows, the
    cohort engines' per-eval ``segment`` records become slices carrying
    the census + op-census counters.

Both clocks are microseconds in the file (the trace-event unit), so a
device-engine run and the event simulator render on one comparable
timeline.  ``python -m repro.telemetry`` is the one-invocation CLI that
captures or converts a trace into a Perfetto-loadable file.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import (Any, Dict, IO, Iterable, List, Optional, Sequence,
                    Union)

__all__ = [
    "SpanRecorder", "PhaseTimer", "trace_to_perfetto",
    "validate_trace_events", "write_perfetto",
]


class SpanRecorder:
    """Accumulating phase timer that also keeps the span timeline.

    ``phases``/``counts``/``as_dict`` keep the PhaseTimer contract
    (every engine's ``MetricsReport.wall`` is built from them);
    ``spans`` holds one entry per ``phase()``/``add()`` with start times
    relative to the recorder's epoch (the first recorded instant), and
    ``to_trace_events`` renders them as Perfetto slices — one thread
    track per phase name, so re-entrant phases stay non-overlapping per
    track (invariant INV-SPAN).
    """

    def __init__(self, *, annotate: bool = False):
        self.phases: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        # (name, track, t0_s, dur_s, args) — t0 relative to epoch
        self.spans: List[Dict[str, Any]] = []
        self.epoch: Optional[float] = None
        self._annotate = bool(annotate)

    # -- recording --------------------------------------------------------
    def _now(self) -> float:
        t = time.perf_counter()
        if self.epoch is None:
            self.epoch = t
        return t - self.epoch

    @contextmanager
    def phase(self, name: str, *, track: Optional[str] = None,
              **args: Any):
        t0 = self._now()
        ann = None
        if self._annotate:
            # bracket the span in the XLA profiler's timeline too, when
            # a jax.profiler trace is being captured around this run
            try:
                import jax
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:
                ann = None
        try:
            yield
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            dur = self._now() - t0
            self._record(name, track, t0, dur, args)

    # old PhaseTimer users call phase(); span() is the forward-looking
    # alias the timeline docs use
    span = phase

    def add(self, name: str, seconds: float, *,
            track: Optional[str] = None, **args: Any) -> None:
        """Record a stretch that just ended (duration known, end = now)."""
        dur = float(seconds)
        t0 = self._now() - dur
        self._record(name, track, max(t0, 0.0), dur, args)

    def _record(self, name: str, track: Optional[str], t0: float,
                dur: float, args: Dict[str, Any]) -> None:
        self.phases[name] = self.phases.get(name, 0.0) + dur
        self.counts[name] = self.counts.get(name, 0) + 1
        self.spans.append(dict(name=name, track=track or name, t0=t0,
                               dur=dur, args=dict(args)))

    # -- aggregates (MetricsReport.wall / BENCH_cohort.json) --------------
    def as_dict(self, suffix: str = "_s") -> Dict[str, float]:
        """Accumulated seconds per phase (``<name>_s``) AND how many
        spans fed each accumulation (``<name>_n``)."""
        out: Dict[str, float] = {
            f"{k}{suffix}": v for k, v in self.phases.items()}
        out.update({f"{k}_n": n for k, n in self.counts.items()})
        return out

    # -- timeline export --------------------------------------------------
    def to_trace_events(self, builder: Optional["_EventBuilder"] = None,
                        *, process: str = "wall") -> List[Dict[str, Any]]:
        """Render the recorded spans as Perfetto ``"X"`` slices."""
        b = builder or _EventBuilder()
        for s in self.spans:
            b.slice(process, s["track"], s["name"],
                    ts_us=s["t0"] * 1e6, dur_us=s["dur"] * 1e6,
                    args=s["args"])
        return b.events


class PhaseTimer(SpanRecorder):
    """Backwards-compatible name: a SpanRecorder (see base docstring)."""


class _EventBuilder:
    """Trace-event assembly: integer pid/tid allocation + ``M`` metadata
    naming them, the way the Perfetto JSON importer expects."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self._pids: Dict[str, int] = {}
        self._tids: Dict[tuple, int] = {}

    def pid(self, process: str) -> int:
        p = self._pids.get(process)
        if p is None:
            p = self._pids[process] = len(self._pids) + 1
            self.events.append(dict(
                ph="M", name="process_name", pid=p, tid=0, ts=0,
                args={"name": process}))
        return p

    def tid(self, process: str, thread: str) -> tuple:
        p = self.pid(process)
        key = (p, thread)
        t = self._tids.get(key)
        if t is None:
            t = self._tids[key] = len(self._tids) + 1
            self.events.append(dict(
                ph="M", name="thread_name", pid=p, tid=t, ts=0,
                args={"name": thread}))
        return p, t

    def slice(self, process: str, thread: str, name: str, *,
              ts_us: float, dur_us: float,
              args: Optional[Dict[str, Any]] = None) -> None:
        p, t = self.tid(process, thread)
        self.events.append(dict(
            ph="X", name=name, pid=p, tid=t, ts=float(ts_us),
            dur=max(float(dur_us), 0.0), args=args or {}))

    def instant(self, process: str, thread: str, name: str, *,
                ts_us: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        p, t = self.tid(process, thread)
        self.events.append(dict(
            ph="i", s="t", name=name, pid=p, tid=t, ts=float(ts_us),
            args=args or {}))

    def flow(self, process: str, thread: str, name: str, flow_id: str,
             *, ts_us: float, start: bool) -> None:
        p, t = self.tid(process, thread)
        self.events.append(dict(
            ph="s" if start else "f", bp="e", cat="flow", name=name,
            id=flow_id, pid=p, tid=t, ts=float(ts_us)))

    def async_span(self, process: str, thread: str, name: str,
                   span_id: str, *, ts_us: float, begin: bool,
                   args: Optional[Dict[str, Any]] = None) -> None:
        p, t = self.tid(process, thread)
        self.events.append(dict(
            ph="b" if begin else "e", cat="lifecycle", name=name,
            id=span_id, pid=p, tid=t, ts=float(ts_us),
            args=args or {}))


def trace_to_perfetto(records: Iterable[Dict[str, Any]],
                      builder: Optional[_EventBuilder] = None
                      ) -> List[Dict[str, Any]]:
    """JSONL trace records (``repro.telemetry.trace``) -> trace events.

    Virtual protocol seconds become microseconds.  Event-sim message
    records render as per-client instants with send→apply and
    fire→deliver flow arrows plus an async ``in flight`` span per
    update; cohort ``segment`` records render as consecutive slices on
    the engine's track carrying the census + op-census counters.
    """
    b = builder or _EventBuilder()
    recs = list(records)
    proc = "protocol (virtual)"
    # broadcast fire times, so each delivery's flow can start at the fire
    fired_at = {r["k"]: r["time"] for r in recs
                if r.get("kind") == "broadcast_fired"}
    last_seg_time: Dict[str, float] = {}
    for r in recs:
        kind = r.get("kind")
        if kind == "update_sent":
            us = r["time"] * 1e6
            c, rd = r["client"], r["round"]
            uid = f"u{c}.{rd}"
            ctrack = f"client {c}"
            b.instant(proc, ctrack, "update_sent", ts_us=us,
                      args={k: r[k] for k in ("round", "k_send", "bytes",
                                              "latency_s") if k in r})
            b.async_span(proc, ctrack, "update in flight", uid,
                         ts_us=us, begin=True,
                         args={"round": rd, "client": c})
            b.flow(proc, ctrack, "update", uid, ts_us=us, start=True)
        elif kind == "update_applied":
            us = r["time"] * 1e6
            c, rd = r["client"], r["round"]
            uid = f"u{c}.{rd}"
            b.instant(proc, "server", "update_applied", ts_us=us,
                      args={k: r[k] for k in ("client", "round",
                                              "server_k", "staleness")
                            if k in r})
            b.flow(proc, "server", "update", uid, ts_us=us, start=False)
            b.async_span(proc, f"client {c}", "update in flight", uid,
                         ts_us=us, begin=False)
        elif kind == "broadcast_fired":
            us = r["time"] * 1e6
            b.instant(proc, "server", "broadcast_fired", ts_us=us,
                      args={k: r[k] for k in ("k", "bytes_per_client",
                                              "clients") if k in r})
        elif kind == "broadcast_applied":
            us = r["time"] * 1e6
            c, k = r["client"], r["k"]
            bid = f"b{k}.c{c}"
            b.instant(proc, f"client {c}", "broadcast_applied",
                      ts_us=us, args={kk: r[kk] for kk in ("k", "accepted")
                                      if kk in r})
            if k in fired_at:
                b.flow(proc, "server", "broadcast", bid,
                       ts_us=fired_at[k] * 1e6, start=True)
                b.flow(proc, f"client {c}", "broadcast", bid,
                       ts_us=us, start=False)
        elif kind == "segment":
            eng = r.get("engine", "cohort")
            track = f"{eng} segments"
            t1 = r.get("time")
            if t1 is None:      # pre-PR-9 traces carry only the tick
                t1 = float(r.get("tick", 0))
            t0 = last_seg_time.get(track, 0.0)
            last_seg_time[track] = t1
            args = {k: v for k, v in r.items() if k != "kind"}
            b.slice(proc, track, f"segment→round {r.get('round')}",
                    ts_us=t0 * 1e6, dur_us=(t1 - t0) * 1e6, args=args)
        elif kind == "report":
            # terminal summary as a zero-duration instant on the engine
            # track, args carrying the whole MetricsReport
            eng = r.get("engine", "engine")
            t1 = r.get("virtual_time") or last_seg_time.get(
                f"{eng} segments", 0.0)
            b.instant(proc, f"{eng} segments", "report",
                      ts_us=float(t1 or 0.0) * 1e6,
                      args={k: v for k, v in r.items() if k != "kind"})
    return b.events


def merge_trace_events(*event_lists: Sequence[Dict[str, Any]]
                       ) -> Dict[str, Any]:
    """Wrap one or more event lists as a loadable trace-event document."""
    events: List[Dict[str, Any]] = []
    for lst in event_lists:
        events.extend(lst)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_perfetto(path_or_fh: Union[str, IO[str]],
                   events_or_doc: Union[Sequence[Dict[str, Any]],
                                        Dict[str, Any]]) -> None:
    """Write a trace-event document Perfetto's JSON importer loads."""
    doc = (events_or_doc if isinstance(events_or_doc, dict)
           else merge_trace_events(events_or_doc))
    problems = validate_trace_events(doc)
    if problems:
        raise ValueError("refusing to write invalid trace: "
                         + "; ".join(problems[:5]))
    if isinstance(path_or_fh, (str, bytes)):
        with open(path_or_fh, "w") as fh:
            json.dump(doc, fh)
    else:
        json.dump(doc, path_or_fh)


# phase types and the keys each requires beyond (ph, name, pid, tid, ts)
_PH_REQUIRED = {
    "X": ("dur",), "M": ("args",), "i": (), "s": ("id",), "t": ("id",),
    "f": ("id",), "b": ("id",), "e": ("id",),
}
# float-µs comparisons: one nanosecond of slack
_OVERLAP_EPS_US = 1e-3


def validate_trace_events(doc: Any, *, check_overlap: bool = True
                          ) -> List[str]:
    """Schema + invariant check of a trace-event document.

    Returns human-readable problems (empty = valid): the document shape,
    per-``ph`` required keys, numeric non-negative timestamps, and —
    the INV-SPAN track discipline — complete ``"X"`` slices
    non-overlapping per (pid, tid) track.
    """
    problems: List[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["document must be an object with a traceEvents list"]
    slices: Dict[tuple, List[tuple]] = {}
    for n, ev in enumerate(doc["traceEvents"]):
        where = f"traceEvents[{n}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PH_REQUIRED:
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        for key in ("name", "pid", "tid", "ts") + _PH_REQUIRED[ph]:
            if key not in ev:
                problems.append(f"{where}: ph={ph} missing {key!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: ts must be a non-negative number,"
                            f" got {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X slice dur must be a "
                                f"non-negative number, got {dur!r}")
                continue
            slices.setdefault((ev.get("pid"), ev.get("tid")), []).append(
                (float(ts), float(dur), ev.get("name"), n))
    if check_overlap:
        for (pid, tid), rows in slices.items():
            rows.sort()
            for (t0, d0, n0, i0), (t1, d1, n1, i1) in zip(rows, rows[1:]):
                if t1 < t0 + d0 - _OVERLAP_EPS_US:
                    problems.append(
                        f"track (pid={pid}, tid={tid}): slice {n1!r} "
                        f"(traceEvents[{i1}], ts={t1}) overlaps "
                        f"{n0!r} (traceEvents[{i0}], "
                        f"ts={t0} dur={d0}) — spans must be "
                        f"non-overlapping per track")
    return problems
