"""Structured JSONL trace export.

The event simulator emits one record per protocol event (update sent /
applied, broadcast fired / applied); the cohort engines emit one
segment-summary record per eval segment plus a final ``report`` record.
Records are plain JSON objects with a ``kind`` discriminator so a trace
can be grepped/streamed without a schema registry.

``trace=`` accepts a path (opened and closed by the engine) or any
object with a ``write`` method (left open), so tests can pass an
``io.StringIO``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, IO, Optional, Union

import numpy as np


def _coerce(obj: Any) -> Any:
    if isinstance(obj, np.ndarray):
        return [_coerce(x) for x in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, dict):
        return {k: _coerce(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_coerce(x) for x in obj]
    return obj


class JsonlTraceWriter:
    """Append-only JSONL sink; one ``emit`` per record."""

    def __init__(self, sink: Union[str, IO[str]]):
        if isinstance(sink, (str, bytes)):
            self._fh: IO[str] = open(sink, "w")
            self._own = True
        else:
            self._fh = sink
            self._own = False
        self.records = 0

    def emit(self, kind: str, **fields: Any) -> None:
        rec: Dict[str, Any] = {"kind": kind}
        rec.update(_coerce(fields))
        self._fh.write(json.dumps(rec) + "\n")
        self.records += 1

    def close(self) -> None:
        if self._own:
            self._fh.close()
        else:
            self._fh.flush()


def open_trace(trace) -> Optional[JsonlTraceWriter]:
    """None | path | file-like | JsonlTraceWriter -> writer or None."""
    if trace is None:
        return None
    if isinstance(trace, JsonlTraceWriter):
        return trace
    return JsonlTraceWriter(trace)
