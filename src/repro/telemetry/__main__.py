"""``python -m repro.telemetry`` — one-invocation Perfetto timelines.

Two modes:

  * ``capture``: run a small engine workload end-to-end and write a
    Perfetto-loadable trace JSON combining BOTH clocks — the engine's
    wall-clock phase spans (compile/steady/eval, from ``SpanRecorder``)
    and the virtual-protocol timeline reconstructed from the run's JSONL
    trace (message lifecycles / eval segments with op-census counters).

      PYTHONPATH=src python -m repro.telemetry capture --out trace.json

  * ``convert``: turn an existing JSONL trace (``trace=`` engine output)
    into the same trace-event JSON.

      PYTHONPATH=src python -m repro.telemetry convert run.jsonl \
          --out trace.json

Open the result at https://ui.perfetto.dev (or chrome://tracing).
"""
from __future__ import annotations

import argparse
import io
import json
import sys
from typing import List, Optional

from repro.telemetry.spans import (_EventBuilder, merge_trace_events,
                                   trace_to_perfetto, write_perfetto)


def _read_jsonl(fh) -> List[dict]:
    return [json.loads(line) for line in fh if line.strip()]


def _cmd_convert(args) -> int:
    with open(args.trace) as fh:
        records = _read_jsonl(fh)
    builder = _EventBuilder()
    trace_to_perfetto(records, builder)
    write_perfetto(args.out, merge_trace_events(builder.events))
    print(f"wrote {args.out}: {len(builder.events)} trace events from "
          f"{len(records)} records")
    return 0


def _cmd_capture(args) -> int:
    from repro.cohort import make_simulator
    from repro.core import LogRegTask
    from repro.data import make_binary_dataset

    X, y = make_binary_dataset(300, 12, seed=args.seed + 7, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / 300, sample_seed=21,
                      dp_clip=1.0 if args.dp else 0.0,
                      dp_sigma=1.5 if args.dp else 0.0)
    sink = io.StringIO()
    sim = make_simulator(
        args.engine, task, n_clients=args.clients,
        sizes_per_client=[4, 6, 8],
        round_stepsizes=[0.1, 0.08, 0.06], d=args.d, seed=args.seed,
        scenario=args.scenario, strategy=args.strategy, trace=sink)
    res = sim.run(max_rounds=args.rounds, eval_every=1)

    records = _read_jsonl(io.StringIO(sink.getvalue()))
    if args.jsonl_out:
        with open(args.jsonl_out, "w") as fh:
            fh.write(sink.getvalue())
    # ONE builder so the wall and virtual processes get distinct pids
    builder = _EventBuilder()
    trace_to_perfetto(records, builder)
    recorder = getattr(getattr(sim, "engine", sim), "timer", None)
    if recorder is not None:
        recorder.to_trace_events(builder, process="wall")
    write_perfetto(args.out, merge_trace_events(builder.events))
    rep = res["telemetry"]
    print(rep.summary())
    print(f"wrote {args.out}: {len(builder.events)} trace events "
          f"({len(records)} JSONL records + "
          f"{len(recorder.spans) if recorder else 0} wall spans)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Perfetto timeline capture/convert for the engines")
    sub = ap.add_subparsers(dest="cmd", required=True)

    cv = sub.add_parser("convert",
                        help="JSONL engine trace -> Perfetto JSON")
    cv.add_argument("trace", help="input JSONL trace path")
    cv.add_argument("--out", required=True, help="output trace JSON path")
    cv.set_defaults(fn=_cmd_convert)

    cp = sub.add_parser(
        "capture",
        help="run a small workload and write its dual-clock timeline")
    cp.add_argument("--out", required=True, help="output trace JSON path")
    cp.add_argument("--engine", default="device",
                    choices=["event", "cohort", "device"])
    cp.add_argument("--scenario", default="mobile_diurnal")
    cp.add_argument("--strategy", default=None,
                    help="aggregation strategy spec (e.g. fedasync)")
    cp.add_argument("--clients", type=int, default=6)
    cp.add_argument("--rounds", type=int, default=3)
    cp.add_argument("--d", type=int, default=2)
    cp.add_argument("--seed", type=int, default=2)
    cp.add_argument("--dp", action="store_true",
                    help="enable the DP clip+noise path")
    cp.add_argument("--jsonl-out", default=None,
                    help="also keep the raw JSONL trace here")
    cp.set_defaults(fn=_cmd_capture)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
