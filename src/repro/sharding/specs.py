"""Per-architecture PartitionSpecs (GSPMD rules, divisibility-checked).

Strategy (baseline, recorded in EXPERIMENTS.md §Roofline):
  * ``model`` axis: tensor-parallel — shards attention head projections,
    MLP hidden, expert hidden, vocab (where divisible).
  * ``data`` axis: FSDP — shards the *other* matrix dimension of each
    large parameter (d_model side), plus the batch dimension of
    activations.
  * ``pod`` axis (multi-pod): FL clients — parameters are replicated
    across pods (each pod is one client cohort holding a full model
    replica, sharded within the pod); the FL server reduce is the only
    cross-pod collective, matching the paper's communication model.

Every rule degrades gracefully: an axis is applied to a tensor dimension
only when the dimension is divisible by the axis size, so every assigned
architecture lowers on both production meshes without bespoke cases.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# §Perf knob: disable FSDP (data-axis) sharding of parameters — for models
# whose model-parallel shard already fits HBM this removes the per-layer
# weight all-gather (see EXPERIMENTS.md §Perf).
NO_FSDP = os.environ.get("REPRO_NO_FSDP", "0") == "1"


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _fit(mesh: Mesh, dim: int, axis: str):
    """Return axis name if dim divisible by its size, else None."""
    if axis == "data" and NO_FSDP:
        return None
    return axis if (axis in mesh.axis_names and dim % _axis_size(mesh, axis)
                    == 0 and _axis_size(mesh, axis) > 1) else None


def _spec_for(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    """Rule table keyed on parameter leaf name."""
    name = path.split("/")[-1]

    def fit(i, axis):
        return _fit(mesh, shape[i], axis)

    nd = len(shape)
    if name in ("embed", "unembed"):                       # (V, d)
        v_ax = fit(0, "model")
        d_ax = fit(1, "data")
        if v_ax is None:                                   # odd vocab sizes
            return P(None, fit(1, "model"))
        return P(v_ax, d_ax)
    if name in ("wq", "wk", "wv"):                         # (L, d, out)
        return P(None, fit(1, "data"), fit(2, "model"))
    if name == "wo":                                       # (L, out, d)
        return P(None, fit(1, "model"), fit(2, "data"))
    if name in ("wg", "wu"):
        if nd == 4:                                        # moe (L,E,d,ff)
            return P(None, None, fit(2, "data"), fit(3, "model"))
        return P(None, fit(1, "data"), fit(2, "model"))    # (L, d, ff)
    if name == "wd":
        if nd == 4:                                        # moe (L,E,ff,d)
            return P(None, None, fit(2, "model"), fit(3, "data"))
        return P(None, fit(1, "model"), fit(2, "data"))    # (L, ff, d)
    if name in ("shared_wg", "shared_wu"):                 # (L, d, sf)
        return P(None, fit(1, "data"), fit(2, "model"))
    if name == "shared_wd":                                # (L, sf, d)
        return P(None, fit(1, "model"), fit(2, "data"))
    if name == "router":                                   # (L, d, E)
        return P(None, fit(1, "data"), None)
    if name == "in_proj":                                  # (L, d, proj)
        return P(None, fit(1, "data"), fit(2, "model"))
    if name == "out_proj":                                 # (L, d_in, d)
        return P(None, fit(1, "model"), fit(2, "data"))
    if name == "conv_w":                                   # (L, conv_dim, W)
        return P(None, fit(1, "model"), None)
    if name in ("conv_b", "gate_norm"):                    # (L, conv_dim)
        return P(None, fit(1, "model"))
    if name in ("bq", "bk", "bv"):                         # (L, out)
        return P(None, fit(1, "model"))
    if name in ("bu",):                                    # (L, ff)
        return P(None, fit(1, "model"))
    if name in ("bd",):                                    # (L, d)
        return P(None, fit(1, "data"))
    # norms, dt_bias, A_log, D, scalars: replicate
    return P(*([None] * nd))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(mesh: Mesh, params_shape: Any) -> Any:
    """Map a params shape-pytree to PartitionSpecs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(mesh, _path_str(path), leaf.shape),
        params_shape)


def param_shardings(mesh: Mesh, params_shape: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_pspecs(mesh, params_shape))


# ---------------------------------------------------------------------------
# Cohort engine: client-axis sharding for [C, D] population state
# ---------------------------------------------------------------------------

def cohort_mesh(devices=None) -> Mesh:
    """1-D mesh over all local devices; axis ``clients`` shards the
    population axis of the cohort engines' stacked state."""
    devices = list(devices) if devices is not None else jax.devices()
    return Mesh(np.asarray(devices), ("clients",))


def cohort_pspecs(mesh: Mesh, n_clients: int) -> Dict[str, P]:
    """Field -> PartitionSpec for ``DeviceCohortState``-shaped pytrees.

    Client-axis fields ([C, ...] or [..., C]) shard over ``clients`` when
    C is divisible by the axis size; the server model, the message rings'
    payloads ([L, D] / [B, D]) and all scalars replicate — they are what
    the batched server reduce touches, i.e. the FL analogue of the
    cross-pod reduce in the LLM mapping.
    """
    c_ax = _fit(mesh, n_clients, "clients")
    return {
        "w": P(c_ax, None), "U": P(c_ax, None), "v": P(None),
        "i": P(c_ax), "h": P(c_ax), "k": P(c_ax), "credit": P(c_ax),
        "server_k": P(), "tick": P(),
        "upd_vec": P(None, None), "upd_cnt": P(None, None),
        "h_counts": P(None),
        "bc_v": P(None, None), "bc_k": P(None), "bc_at": P(None, c_ax),
        "ovf_vec": P(None, None), "ovf_at": P(None),
        "ovf_cnt": P(None, None), "err": P(),
        "messages": P(), "broadcasts": P(),
        # telemetry counters: per-client census shards with the client
        # axis; the small histogram / ring-count arrays and scalar
        # high-water marks replicate like the message rings they mirror
        "part": P(c_ax), "bytes_up": P(c_ax),
        "stale_hist": P(None), "upd_ks": P(None, None),
        "ovf_ks": P(None, None), "ovf_hwm": P(), "far_msgs": P(),
        # aggregation-strategy buffers (repro.core.strategies): server-
        # side ring payloads and the FedBuff accumulator replicate like
        # the message rings they extend ([1, ...] dummies under the
        # default paper strategy)
        "upd_kvec": P(None, None, None), "ovf_kvec": P(None, None, None),
        "buf_vec": P(None), "buf_cnt": P(),
        # op-census vector (repro.telemetry.costs): scalar-ish counter
        # block, replicates like the other telemetry scalars
        "ops": P(None),
        # fused-loop iteration census ([loop_iters, block_iters]):
        # scalar-ish, replicates like ops
        "iters": P(None),
    }


def cohort_shardings(mesh: Mesh, n_clients: int) -> Dict[str, Any]:
    return {f: NamedSharding(mesh, s)
            for f, s in cohort_pspecs(mesh, n_clients).items()}


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return tuple(axes)


def batch_spec(mesh: Mesh, batch_size: int, extra_dims: int = 1) -> P:
    """Shard the batch dim over (pod, data) when divisible."""
    axes = [a for a in batch_axes(mesh)
            if batch_size % _axis_size(mesh, a) == 0]
    # try combined first
    combined = batch_axes(mesh)
    total = int(np.prod([_axis_size(mesh, a) for a in combined])) \
        if combined else 1
    if combined and batch_size % total == 0:
        lead = combined if len(combined) > 1 else combined[0]
    elif axes:
        lead = axes[0]
    else:
        lead = None
    return P(lead, *([None] * extra_dims))


def client_batch_spec(mesh: Mesh, per_client_batch: int,
                      extra_dims: int = 1) -> P:
    """(C, B, ...) batches: client axis over pod, batch over data."""
    c_ax = "pod" if "pod" in mesh.axis_names else None
    b_ax = _fit(mesh, per_client_batch, "data")
    return P(c_ax, b_ax, *([None] * extra_dims))


def cache_pspecs(mesh: Mesh, cache_shape: Any) -> Any:
    """Decode-cache sharding: batch over (pod,data) if divisible, else
    shard heads / state over model; fall back to replication."""
    def spec(path, leaf):
        shape = leaf.shape
        name = _path_str(path).split("/")[-1]
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, S_cache, KV, hd)
            b = _fit_combined(mesh, shape[1])
            kv = _fit(mesh, shape[3], "model")
            s = None
            if kv is None:
                s = _fit(mesh, shape[2], "model")
            return P(None, b, s, kv, None)
        if name in ("k_scale", "v_scale"):
            # (L, B, S_cache, KV) — int8-KV scales, mirror the kv layout
            b = _fit_combined(mesh, shape[1])
            kv = _fit(mesh, shape[3], "model")
            s = None
            if kv is None:
                s = _fit(mesh, shape[2], "model")
            return P(None, b, s, kv)
        if name == "h":          # ssm state (L, B, H, N, P)
            b = _fit_combined(mesh, shape[1])
            h_ax = _fit(mesh, shape[2], "model")
            return P(None, b, h_ax, None, None)
        if name == "conv":       # (L, B, W-1, conv_dim)
            b = _fit_combined(mesh, shape[1])
            return P(None, b, None, _fit(mesh, shape[3], "model"))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def _fit_combined(mesh: Mesh, dim: int):
    combined = batch_axes(mesh)
    total = int(np.prod([_axis_size(mesh, a) for a in combined])) \
        if combined else 1
    if combined and dim % total == 0 and total > 1:
        return combined if len(combined) > 1 else combined[0]
    for a in combined:
        if dim % _axis_size(mesh, a) == 0 and _axis_size(mesh, a) > 1:
            return a
    return None
