"""Activation-sharding constraint context.

GSPMD left to itself may shard activations on d_model and replicate the
batch (observed on the 16x16 mesh: 17 GB score buffers).  Model code calls
:func:`constrain` on (B, S, d)-shaped residuals; when a spec is installed
(by the launcher, under ``with mesh:``), a ``with_sharding_constraint``
pins the batch dimension to the data axes.  Outside the launcher (CPU
tests) it is a no-op, keeping the model code mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

_ACTIVATION_SPEC: Optional[P] = None
_PARAM_COT_SPECS: Optional[Any] = None   # blocks-tree of per-layer specs


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _with_cotangent_sharding(x, spec):
    return x


def _wcs_fwd(x, spec):
    return x, None


def _wcs_bwd(spec, _res, g):
    return (jax.lax.with_sharding_constraint(g, spec),)


_with_cotangent_sharding.defvjp(_wcs_fwd, _wcs_bwd)


@contextlib.contextmanager
def use_param_cotangent_specs(specs):
    """Install per-layer parameter-slice specs (leading L dim dropped).

    Inside the backward of the layer scan, XLA otherwise reduces each
    layer's weight gradient with a full all-reduce (replicated result)
    before slicing — pinning the cotangent sharding turns that into a
    reduce-scatter (grok-1 train_4k: 305 TB -> see EXPERIMENTS.md §Perf).
    """
    global _PARAM_COT_SPECS
    prev = _PARAM_COT_SPECS
    _PARAM_COT_SPECS = specs
    try:
        yield
    finally:
        _PARAM_COT_SPECS = prev


def shard_layer_param_cotangents(lp):
    """Apply cotangent-sharding to one layer's param slices (no-op unless
    specs installed by the launcher)."""
    if _PARAM_COT_SPECS is None:
        return lp
    return jax.tree_util.tree_map(
        lambda a, sp: _with_cotangent_sharding(a, sp), lp,
        _PARAM_COT_SPECS)


@contextlib.contextmanager
def use_activation_spec(spec: Optional[P]):
    global _ACTIVATION_SPEC
    prev = _ACTIVATION_SPEC
    _ACTIVATION_SPEC = spec
    try:
        yield
    finally:
        _ACTIVATION_SPEC = prev


def constrain(x):
    """Pin an activation whose FIRST axis is the (per-client) batch."""
    if _ACTIVATION_SPEC is None:
        return x
    spec = _ACTIVATION_SPEC
    extra = x.ndim - len(spec)
    if extra > 0:
        spec = P(*(tuple(spec) + (None,) * extra))
    elif extra < 0:
        spec = P(*tuple(spec)[:x.ndim])
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_tokens(x, dim: int = 0):
    """Pin a flattened-token dimension to ALL activation axes combined.

    Used for MoE dispatch/combine buffers whose leading dim is B*S (or
    expert-slot rows E*C): shards rows over ('data','model') jointly.
    """
    if _ACTIVATION_SPEC is None:
        return x
    axes = tuple(a for a in tuple(_ACTIVATION_SPEC) if a is not None)
    flat = []
    for a in axes:
        if isinstance(a, (tuple, list)):
            flat.extend(a)
        else:
            flat.append(a)
    if not flat:
        return x
    entry = tuple(flat) if len(flat) > 1 else flat[0]
    spec = [None] * x.ndim
    spec[dim] = entry
    return jax.lax.with_sharding_constraint(x, P(*spec))


def batch_model_axes():
    """(batch_axis_entry, model_axis_entry) from the installed spec."""
    if _ACTIVATION_SPEC is None:
        return None, None
    t = tuple(_ACTIVATION_SPEC)
    b = t[0] if len(t) > 0 else None
    m = t[1] if len(t) > 1 else None
    return b, m


def constrain_expert(x, *, last_is_ff: bool):
    """Pin MoE expert-region tensors (B, M, E, Cg, d|ff).

    The sequence-block axis M is UNSHARDED here — the model axis moves to
    the expert hidden dim instead, so expert weights keep their
    tensor-parallel sharding instead of being fully gathered (observed
    6.4 GB/layer f32 weight gathers on grok otherwise).
    """
    if _ACTIVATION_SPEC is None:
        return x
    b, m = batch_model_axes()
    spec = [None] * x.ndim
    spec[0] = b
    if last_is_ff:
        spec[-1] = m
    return jax.lax.with_sharding_constraint(x, P(*spec))


def activation_spec() -> Optional[P]:
    return _ACTIVATION_SPEC
