from repro.sharding.specs import (batch_spec, cache_pspecs,
                                  client_batch_spec, param_pspecs,
                                  param_shardings)

__all__ = ["batch_spec", "cache_pspecs", "client_batch_spec",
           "param_pspecs", "param_shardings"]
