from repro.sharding.specs import (batch_spec, cache_pspecs,
                                  client_batch_spec, cohort_mesh,
                                  cohort_pspecs, cohort_shardings,
                                  param_pspecs, param_shardings)

__all__ = ["batch_spec", "cache_pspecs", "client_batch_spec",
           "cohort_mesh", "cohort_pspecs", "cohort_shardings",
           "param_pspecs", "param_shardings"]
