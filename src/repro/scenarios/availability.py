"""Client availability and fleet-speed models for heterogeneity scenarios.

Availability models answer "is client c on at virtual time t?" and come
in two flavors the engines care about:

  * ``tick_plan(C, dt, seed)`` — a pure jax closure ``mask(t) -> bool[C]``
    over *integer tick* arithmetic, embedded directly in the cohort
    engines' tick loops (the host-loop engine calls the same jitted
    expression), so host-cohort vs device availability is bit-identical.
  * ``windows(C, seed)`` — a continuous-time accessor for the
    discrete-event simulator (on-time integration + its inverse), only
    for models whose windows are deterministic.  Hash-per-epoch models
    (``Churn``) have no continuous form and are rejected by the event
    simulator.

Semantics shared by all engines: availability gates *compute and
upload* — an off client accrues no iteration credit, takes no SGD step,
and sends no round update (the invariant the property tests pin).
Broadcast delivery is NOT gated: a broadcast whose arrival tick passes
while a client is off is picked up when the client returns, which the
freshest-wins ISRRECEIVE already models (stale ones drop out).

Speed models draw the per-client iterations/second vector once at
engine construction (``SpeedModel.draw``): long-tail Zipf fleets,
bimodal fast/slow populations, lognormal spreads — the distributions
Bonawitz et al. (1902.01046) report for real device populations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

AVAIL_SALT = 0xA7A1B      # availability threefry chain: seed ^ AVAIL_SALT
PHASE_SALT = 0xD1A7       # numpy stream for diurnal phase draws


@dataclass(frozen=True)
class AlwaysOn:
    """Full availability — the legacy (and default) regime."""
    duty: float = 1.0
    event_supported: bool = True

    def tick_plan(self, C: int, dt: float, seed: int) -> None:
        return None

    def windows(self, C: int, seed: int) -> None:
        return None


class _DiurnalWindows:
    """Continuous-time periodic on/off windows for the event simulator:
    client c is on during [k·P − φ_c, k·P − φ_c + on) for integer k."""

    def __init__(self, phase_s: np.ndarray, period_s: float, on_s: float):
        self.phase_s = phase_s
        self.period_s = float(period_s)
        self.on_s = float(on_s)

    def _cum_on(self, c: int, t: float) -> float:
        """Cumulative on-seconds of client c over (-inf, t]."""
        tt = t + self.phase_s[c]
        k, r = divmod(tt, self.period_s)
        return k * self.on_s + min(r, self.on_s)

    def on_time(self, c: int, t0: float, t1: float) -> float:
        """On-seconds inside [t0, t1]."""
        return max(0.0, self._cum_on(c, t1) - self._cum_on(c, t0))

    def advance(self, c: int, t0: float, work_s: float) -> float:
        """Earliest t with ``on_time(c, t0, t) == work_s`` (inverse)."""
        if work_s <= 0.0:
            return t0
        target = self._cum_on(c, t0) + work_s
        k, r = divmod(target, self.on_s)
        if r == 0.0:                  # lands exactly on a window end
            k, r = k - 1.0, self.on_s
        return k * self.period_s + r - self.phase_s[c]


@dataclass(frozen=True)
class Diurnal:
    """Periodic on/off windows with a per-client phase: each client is on
    for ``on_frac`` of every ``period_s`` virtual seconds, phases drawn
    uniformly (deterministically from the engine seed) so the fleet's
    availability rolls around the clock — the mobile diurnal pattern."""
    period_s: float = 512.0
    on_frac: float = 0.75
    event_supported: bool = True

    def __post_init__(self):
        if self.period_s <= 0.0 or not 0.0 < self.on_frac <= 1.0:
            raise ValueError("need period_s > 0 and 0 < on_frac <= 1")

    @property
    def duty(self) -> float:
        return self.on_frac

    def _phases(self, C: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed ^ PHASE_SALT)
        return rng.uniform(0.0, self.period_s, C)

    def tick_plan(self, C: int, dt: float,
                  seed: int) -> Optional[Callable]:
        if self.on_frac >= 1.0:
            return None
        period_t = max(2, int(round(self.period_s / dt)))
        on_t = min(period_t - 1, max(1, int(round(self.on_frac * period_t))))
        phase_t = jnp.asarray(
            np.floor(self._phases(C, seed) / dt).astype(np.int64)
            % period_t, jnp.int32)

        def mask(t):
            return (t + phase_t) % period_t < on_t

        return mask

    def windows(self, C: int, seed: int) -> Optional[_DiurnalWindows]:
        if self.on_frac >= 1.0:
            return None
        return _DiurnalWindows(self._phases(C, seed), self.period_s,
                               self.on_frac * self.period_s)


@dataclass(frozen=True)
class Churn:
    """Stochastic dropout/churn: every ``epoch_s`` virtual seconds each
    client independently re-draws availability with probability
    ``p_available``.  The draw is *addressed* — uniform bits from
    ``fold_in(avail_base, epoch)`` per client — so it is a pure function
    of (epoch, client): no Markov state in the engine, and both cohort
    engines see identical masks.  No continuous-time form exists, so the
    event simulator rejects it."""
    p_available: float = 0.9
    epoch_s: float = 64.0
    event_supported: bool = False

    def __post_init__(self):
        if not 0.0 < self.p_available <= 1.0 or self.epoch_s <= 0.0:
            raise ValueError("need 0 < p_available <= 1 and epoch_s > 0")

    @property
    def duty(self) -> float:
        return self.p_available

    def tick_plan(self, C: int, dt: float,
                  seed: int) -> Optional[Callable]:
        if self.p_available >= 1.0:
            return None
        epoch_t = max(1, int(round(self.epoch_s / dt)))
        base = jax.random.PRNGKey(seed ^ AVAIL_SALT)
        p = jnp.float32(self.p_available)

        def mask(t):
            u = jax.random.uniform(jax.random.fold_in(base, t // epoch_t),
                                   (C,))
            return u < p

        return mask

    def windows(self, C: int, seed: int):
        raise ValueError(
            "Churn availability is tick-hash addressed and has no "
            "continuous-time form; the event simulator cannot run it — "
            "use the cohort engines (engine='cohort'|'device')")


# ---------------------------------------------------------------------------
# Fleet speed distributions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SpeedModel:
    """Per-client iterations/second draw, normalized so max(speed) = 1
    (the cohort tick dt = block / max speed stays scale-free).

    kinds:
      uniform:   U(lo, hi)
      bimodal:   fast with prob 1 - slow_frac, else slow
      zipf:      1 / rank^alpha over a random permutation (long tail)
      lognormal: exp(sigma * N(0, 1))
    """
    kind: str = "uniform"
    lo: float = 0.5
    hi: float = 1.0
    slow: float = 0.25
    slow_frac: float = 0.3
    alpha: float = 0.8
    sigma: float = 0.5
    min_speed: float = 1e-3

    def draw(self, C: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed ^ 0x5BEED)
        if self.kind == "uniform":
            s = rng.uniform(self.lo, self.hi, C)
        elif self.kind == "bimodal":
            s = np.where(rng.random(C) < self.slow_frac, self.slow, 1.0)
        elif self.kind == "zipf":
            ranks = rng.permutation(C) + 1
            s = ranks.astype(np.float64) ** (-self.alpha)
        elif self.kind == "lognormal":
            s = np.exp(self.sigma * rng.standard_normal(C))
        else:
            raise ValueError(f"unknown speed model kind {self.kind!r}")
        s = np.maximum(s, self.min_speed)
        return s / s.max()
