"""Client availability and fleet-speed models for heterogeneity scenarios.

Availability models answer "is client c on at virtual time t?" and come
in two flavors the engines care about:

  * ``tick_plan(C, dt, seed)`` — a pure jax closure ``mask(t) -> bool[C]``
    over *integer tick* arithmetic, embedded directly in the cohort
    engines' tick loops (the host-loop engine calls the same jitted
    expression), so host-cohort vs device availability is bit-identical.
  * ``windows(C, seed)`` — a continuous-time accessor for the
    discrete-event simulator (on-time integration + its inverse), only
    for models whose windows are deterministic.  Hash-per-epoch models
    (``Churn``) have no continuous form and are rejected by the event
    simulator.

Semantics shared by all engines: availability gates *compute and
upload* — an off client accrues no iteration credit, takes no SGD step,
and sends no round update (the invariant the property tests pin).
Broadcast delivery is NOT gated: a broadcast whose arrival tick passes
while a client is off is picked up when the client returns, which the
freshest-wins ISRRECEIVE already models (stale ones drop out).

Speed models draw the per-client iterations/second vector once at
engine construction (``SpeedModel.draw``): long-tail Zipf fleets,
bimodal fast/slow populations, lognormal spreads — the distributions
Bonawitz et al. (1902.01046) report for real device populations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Salt constants live in the central registry (repro.analysis.salts);
# re-exported here for back-compat.  The PRNG auditor enforces that key
# creations use these registry imports, never ad-hoc literals.
from repro.analysis.salts import (AVAIL_SALT, PHASE_SALT, REGION_SALT,
                                  RENEW_SALT, SPEED_SALT)


@dataclass(frozen=True)
class AlwaysOn:
    """Full availability — the legacy (and default) regime."""
    duty: float = 1.0
    event_supported: bool = True

    def tick_plan(self, C: int, dt: float, seed: int) -> None:
        return None

    def windows(self, C: int, seed: int) -> None:
        return None


class _DiurnalWindows:
    """Continuous-time periodic on/off windows for the event simulator:
    client c is on during [k·P − φ_c, k·P − φ_c + on) for integer k."""

    def __init__(self, phase_s: np.ndarray, period_s: float, on_s: float):
        self.phase_s = phase_s
        self.period_s = float(period_s)
        self.on_s = float(on_s)

    def _cum_on(self, c: int, t: float) -> float:
        """Cumulative on-seconds of client c over (-inf, t]."""
        tt = t + self.phase_s[c]
        k, r = divmod(tt, self.period_s)
        return k * self.on_s + min(r, self.on_s)

    def on_time(self, c: int, t0: float, t1: float) -> float:
        """On-seconds inside [t0, t1]."""
        return max(0.0, self._cum_on(c, t1) - self._cum_on(c, t0))

    def advance(self, c: int, t0: float, work_s: float) -> float:
        """Earliest t with ``on_time(c, t0, t) == work_s`` (inverse)."""
        if work_s <= 0.0:
            return t0
        target = self._cum_on(c, t0) + work_s
        k, r = divmod(target, self.on_s)
        if r == 0.0:                  # lands exactly on a window end
            k, r = k - 1.0, self.on_s
        return k * self.period_s + r - self.phase_s[c]


@dataclass(frozen=True)
class Diurnal:
    """Periodic on/off windows with a per-client phase: each client is on
    for ``on_frac`` of every ``period_s`` virtual seconds, phases drawn
    uniformly (deterministically from the engine seed) so the fleet's
    availability rolls around the clock — the mobile diurnal pattern."""
    period_s: float = 512.0
    on_frac: float = 0.75
    event_supported: bool = True

    def __post_init__(self):
        if self.period_s <= 0.0 or not 0.0 < self.on_frac <= 1.0:
            raise ValueError("need period_s > 0 and 0 < on_frac <= 1")

    @property
    def duty(self) -> float:
        return self.on_frac

    def _phases(self, C: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed ^ PHASE_SALT)
        return rng.uniform(0.0, self.period_s, C)

    def tick_plan(self, C: int, dt: float,
                  seed: int) -> Optional[Callable]:
        if self.on_frac >= 1.0:
            return None
        period_t = max(2, int(round(self.period_s / dt)))
        on_t = min(period_t - 1, max(1, int(round(self.on_frac * period_t))))
        phase_t = jnp.asarray(
            np.floor(self._phases(C, seed) / dt).astype(np.int64)
            % period_t, jnp.int32)

        def mask(t):
            return (t + phase_t) % period_t < on_t

        return mask

    def windows(self, C: int, seed: int) -> Optional[_DiurnalWindows]:
        if self.on_frac >= 1.0:
            return None
        return _DiurnalWindows(self._phases(C, seed), self.period_s,
                               self.on_frac * self.period_s)


@dataclass(frozen=True)
class Churn:
    """Stochastic dropout/churn: every ``epoch_s`` virtual seconds each
    client independently re-draws availability with probability
    ``p_available``.  The draw is *addressed* — uniform bits from
    ``fold_in(avail_base, epoch)`` per client — so it is a pure function
    of (epoch, client): no Markov state in the engine, and both cohort
    engines see identical masks.  No continuous-time form exists, so the
    event simulator rejects it."""
    p_available: float = 0.9
    epoch_s: float = 64.0
    event_supported: bool = False

    def __post_init__(self):
        if not 0.0 < self.p_available <= 1.0 or self.epoch_s <= 0.0:
            raise ValueError("need 0 < p_available <= 1 and epoch_s > 0")

    @property
    def duty(self) -> float:
        return self.p_available

    def tick_plan(self, C: int, dt: float,
                  seed: int) -> Optional[Callable]:
        if self.p_available >= 1.0:
            return None
        epoch_t = max(1, int(round(self.epoch_s / dt)))
        base = jax.random.PRNGKey(seed ^ AVAIL_SALT)
        p = jnp.float32(self.p_available)

        def mask(t):
            u = jax.random.uniform(jax.random.fold_in(base, t // epoch_t),
                                   (C,))
            return u < p

        return mask

    def windows(self, C: int, seed: int):
        raise ValueError(
            "Churn availability is tick-hash addressed and has no "
            "continuous-time form; the event simulator cannot run it — "
            "use the cohort engines (engine='cohort'|'device')")


@dataclass(frozen=True)
class RegionalChurn:
    """Correlated churn: clients belong to regions, and availability
    mixes a shared per-(epoch, region) outage draw with the per-client
    draw — the regional-outage / network-partition regime independent
    ``Churn`` cannot express.

    Client c is on in an epoch iff its REGION is up (shared uniform from
    ``fold_in(PRNGKey(seed ^ REGION_SALT), epoch)`` against
    ``p_region_up``) AND its own draw passes (the ``Churn`` chain
    against ``p_available / p_region_up``), so the marginal duty is
    exactly ``p_available`` while two clients of one region share the
    outage factor: P(both on) = p_available^2 / p_region_up >
    p_available^2 (positive within-region correlation); clients of
    different regions stay independent.  Both draws are tick-hash
    addressed — pure functions of (epoch, region / client) — so the two
    cohort engines see identical masks; like ``Churn`` there is no
    continuous-time form and the event simulator rejects it.

    Regions come from ``region_of`` (an explicit [C] tuple of ids) or
    default to ``n_regions`` contiguous equal blocks of the client axis.
    """
    n_regions: int = 4
    p_available: float = 0.9
    p_region_up: float = 0.95
    epoch_s: float = 64.0
    region_of: Optional[tuple] = None
    event_supported: bool = False

    def __post_init__(self):
        if self.n_regions < 1:
            raise ValueError("need n_regions >= 1")
        if not 0.0 < self.p_available <= self.p_region_up <= 1.0:
            raise ValueError(
                "need 0 < p_available <= p_region_up <= 1 (the marginal "
                "duty cannot exceed the region-up probability)")
        if self.epoch_s <= 0.0:
            raise ValueError("need epoch_s > 0")
        if self.region_of is not None:
            r = tuple(int(x) for x in self.region_of)
            if any(not 0 <= x < self.n_regions for x in r):
                raise ValueError(
                    f"region_of ids must lie in [0, {self.n_regions}); "
                    f"got {sorted(set(self.region_of))}")
            object.__setattr__(self, "region_of", r)

    @property
    def duty(self) -> float:
        return self.p_available

    def regions(self, C: int) -> np.ndarray:
        if self.region_of is not None:
            if len(self.region_of) != C:
                raise ValueError(
                    f"region_of has {len(self.region_of)} entries for "
                    f"{C} clients")
            return np.asarray(self.region_of, np.int32)
        return (np.arange(C) * self.n_regions // C).astype(np.int32)

    def tick_plan(self, C: int, dt: float,
                  seed: int) -> Optional[Callable]:
        if self.p_available >= 1.0:
            return None
        epoch_t = max(1, int(round(self.epoch_s / dt)))
        base_c = jax.random.PRNGKey(seed ^ AVAIL_SALT)
        base_r = jax.random.PRNGKey(seed ^ REGION_SALT)
        reg = jnp.asarray(self.regions(C))
        p_client = jnp.float32(self.p_available / self.p_region_up)
        p_reg = jnp.float32(self.p_region_up)
        R = self.n_regions

        def mask(t):
            e = t // epoch_t
            ur = jax.random.uniform(jax.random.fold_in(base_r, e), (R,))
            uc = jax.random.uniform(jax.random.fold_in(base_c, e), (C,))
            return (ur[reg] < p_reg) & (uc < p_client)

        return mask

    def windows(self, C: int, seed: int):
        raise ValueError(
            "RegionalChurn is tick-hash addressed and has no "
            "continuous-time form; the event simulator cannot run it — "
            "use the cohort engines (engine='cohort'|'device'), or "
            "RenewalChurn for a churn model the event simulator "
            "integrates")


def _renewal_epoch_draw(base, e, C: int, N: int, duty, on_rate: float,
                        off_rate: float):
    """Per-(client, epoch) renewal schedule: stationary-Bernoulli(duty)
    initial states ``init_on [C]`` and f32 cumulative switch times
    ``cs [C, N]`` (seconds from the epoch start) from N exponential
    holdings on the ``fold_in(fold_in(base, epoch), client)`` chain.

    THE shared expression of the renewal chain: ``RenewalChurn.tick_plan``
    evaluates it traced inside the engines' jitted ticks and
    ``_RenewalWindows`` evaluates it per epoch on the host — identical
    f32 operands from the identical threefry addresses are what make the
    event simulator's trajectories PATH-WISE aligned with the cohort
    tick masks, not merely statistically equivalent.
    """
    cidx = jnp.arange(C)
    # holding j's exit rate depends on the state it is held in
    j_odd = (jnp.arange(N) % 2 == 1)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.fold_in(base, e), cidx)
    u = jax.vmap(lambda k: jax.random.uniform(k, (N + 1,)))(keys)
    init_on = u[:, 0] < duty                      # stationary
    state_on = init_on[:, None] ^ j_odd[None, :]  # [C, N]
    rate = jnp.where(state_on, jnp.float32(off_rate),
                     jnp.float32(on_rate))
    dur = -jnp.log1p(-u[:, 1:]) / rate
    return init_on, jnp.cumsum(dur, axis=1)


class _RenewalWindows:
    """Continuous-time alternating-renewal on/off windows for the event
    simulator, path-wise aligned with the cohort tick mask: time splits
    into epochs of ``E_s = epoch_cycles * mean_cycle_s`` seconds, and
    each epoch's per-client initial state and switch times come from the
    SAME ``_renewal_epoch_draw`` fold_in chain the tick mask consumes.
    Whenever the engine tick ``dt`` divides ``E_s`` exactly, tick t of
    the cohort engines and second ``t * dt`` of the event simulator land
    in the same epoch at the same offset, so ``on_at`` reproduces the
    tick mask elementwise (the exact-schedule test pins it).

    Beyond the N-th switch of an epoch the state clamps to the post-N
    parity until the epoch ends — the same clamp the tick mask's
    ``ndone`` sum applies (the ``n_draws >= 4 * epoch_cycles`` validation
    makes this a rare tail event)."""

    def __init__(self, av: "RenewalChurn", C: int, seed: int):
        self.C = int(C)
        self.N = int(av.n_draws)
        self.E_s = float(av.epoch_cycles * av.mean_cycle_s)
        self._base = jax.random.PRNGKey(seed ^ RENEW_SALT)
        self._duty = jnp.float32(av.duty)
        self._on_rate = float(av.on_rate)
        self._off_rate = float(av.off_rate)
        self._epochs = {}                       # e -> (init_on, cs)
        self._pref = [[0.0] for _ in range(C)]  # on-secs over epochs [0, i)

    def _epoch(self, e: int):
        ent = self._epochs.get(e)
        if ent is None:
            init_on, cs = _renewal_epoch_draw(
                self._base, e, self.C, self.N, self._duty,
                self._on_rate, self._off_rate)
            ent = (np.asarray(init_on), np.asarray(cs))
            self._epochs[e] = ent
        return ent

    def on_at(self, c: int, t: float) -> bool:
        """State of client c at second t — the tick mask's expression
        verbatim (f32 ``cs <= tau`` switch counting)."""
        e = int(t // self.E_s)
        init_on, cs = self._epoch(e)
        tau = np.float32(t - e * self.E_s)
        ndone = int(np.sum(cs[c] <= tau))
        return bool(init_on[c]) ^ (ndone % 2 == 1)

    def _walk(self, c: int, e: int, tau: float,
              need: Optional[float] = None) -> float:
        """Segment walk inside epoch e.  With ``need=None``: on-seconds
        of client c over epoch offsets [0, tau].  With ``need``: the
        smallest offset at which that many on-seconds have accrued
        (requires the epoch to hold them — callers check totals)."""
        init_on, cs = self._epoch(e)
        sw = cs[c].astype(np.float64)
        on, acc, prev = bool(init_on[c]), 0.0, 0.0
        for j in range(self.N):
            hi = min(float(sw[j]), tau)
            if hi > prev:
                if on:
                    if need is not None and acc + (hi - prev) >= need:
                        return prev + (need - acc)
                    acc += hi - prev
                prev = hi
            if sw[j] >= tau:
                break
            on = not on
        else:
            # post-N clamp segment up to the epoch-offset horizon
            if tau > prev and on:
                if need is not None and acc + (tau - prev) >= need:
                    return prev + (need - acc)
                acc += tau - prev
        if need is not None:
            raise ValueError(
                f"epoch {e} holds only {acc} on-seconds for client {c}, "
                f"need {need}")
        return acc

    def _prefix(self, c: int, e: int) -> float:
        """Cumulative on-seconds of client c over the e full epochs
        [0, e * E_s] (memoized per client)."""
        pl = self._pref[c]
        while len(pl) <= e:
            pl.append(pl[-1] + self._walk(c, len(pl) - 1, self.E_s))
        return pl[e]

    def _cum(self, c: int, t: float) -> float:
        """Cumulative on-seconds of client c over [0, t]."""
        if t <= 0.0:
            return 0.0
        e = int(t // self.E_s)
        return self._prefix(c, e) + self._walk(c, e, t - e * self.E_s)

    def on_time(self, c: int, t0: float, t1: float) -> float:
        return max(0.0, self._cum(c, t1) - self._cum(c, t0))

    def advance(self, c: int, t0: float, work_s: float) -> float:
        """Earliest t with ``on_time(c, t0, t) == work_s`` (inverse)."""
        if work_s <= 0.0:
            return t0
        target = self._cum(c, t0) + work_s
        e = max(int(t0 // self.E_s), 0)
        while self._prefix(c, e + 1) < target:
            e += 1
        need = target - self._prefix(c, e)
        return e * self.E_s + self._walk(c, e, self.E_s, need=need)


@dataclass(frozen=True)
class RenewalChurn:
    """Stochastic churn as an alternating renewal process: each client
    holds ON for Exp(off_rate) seconds, then OFF for Exp(on_rate)
    seconds, independently across clients.  Stationary duty is
    ``on_rate / (on_rate + off_rate)``.

    Unlike ``Churn`` this HAS a continuous-time form, so the event
    simulator integrates it exactly.  Virtual time splits into epochs of
    ``epoch_cycles`` mean on/off cycles, and within an epoch the process
    is an exact renewal schedule whose initial state and holding times
    are pure functions of (client, epoch) — ``fold_in(PRNGKey(seed ^
    RENEW_SALT), epoch)`` then per-client fold_in
    (``_renewal_epoch_draw``) — regenerated at epoch boundaries from the
    stationary law.  BOTH forms consume that one chain: the cohort
    engines' tick mask evaluates it traced, the event simulator's
    ``_RenewalWindows`` integrates the same switch times on the host.
    Host-cohort vs device therefore stays BIT-IDENTICAL, and
    event-vs-cohort is a *path-wise* contract — whenever the tick ``dt``
    divides the epoch length exactly, the tick mask equals the windows
    state at every tick (the exact-schedule test pins it), with the duty
    chi-square as the distributional backstop.
    """
    on_rate: float = 1.0 / 16.0     # per virtual second: 1 / mean_off_s
    off_rate: float = 1.0 / 48.0    # per virtual second: 1 / mean_on_s
    epoch_cycles: float = 4.0       # cohort-engine regeneration horizon
    n_draws: int = 24               # holding times drawn per epoch
    event_supported: bool = True

    def __post_init__(self):
        if self.on_rate <= 0.0 or self.off_rate <= 0.0:
            raise ValueError("need on_rate > 0 and off_rate > 0")
        if self.epoch_cycles <= 0.0 or self.n_draws < 2:
            raise ValueError("need epoch_cycles > 0 and n_draws >= 2")
        # n_draws must comfortably cover the holdings in one epoch, or
        # the tick mask clamps to the post-n_draws state
        if self.n_draws < 4 * self.epoch_cycles:
            raise ValueError(
                f"n_draws={self.n_draws} cannot cover epoch_cycles="
                f"{self.epoch_cycles} (need >= 4 * epoch_cycles)")

    @property
    def duty(self) -> float:
        return self.on_rate / (self.on_rate + self.off_rate)

    @property
    def mean_cycle_s(self) -> float:
        return 1.0 / self.on_rate + 1.0 / self.off_rate

    def tick_plan(self, C: int, dt: float,
                  seed: int) -> Optional[Callable]:
        epoch_t = max(1, int(round(self.epoch_cycles * self.mean_cycle_s
                                   / dt)))
        base = jax.random.PRNGKey(seed ^ RENEW_SALT)
        N = int(self.n_draws)
        duty = jnp.float32(self.duty)
        on_rate, off_rate = self.on_rate, self.off_rate

        def mask(t):
            e = t // epoch_t
            tau = (t - e * epoch_t).astype(jnp.float32) * jnp.float32(dt)
            init_on, cs = _renewal_epoch_draw(base, e, C, N, duty,
                                              on_rate, off_rate)
            ndone = jnp.sum(cs <= tau, axis=1)
            return init_on ^ (ndone % 2 == 1)

        return mask

    def windows(self, C: int, seed: int) -> _RenewalWindows:
        return _RenewalWindows(self, C, seed)


# ---------------------------------------------------------------------------
# Fleet speed distributions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SpeedModel:
    """Per-client iterations/second draw, normalized so max(speed) = 1
    (the cohort tick dt = block / max speed stays scale-free).

    kinds:
      uniform:   U(lo, hi)
      bimodal:   fast with prob 1 - slow_frac, else slow
      zipf:      1 / rank^alpha over a random permutation (long tail)
      lognormal: exp(sigma * N(0, 1))
    """
    kind: str = "uniform"
    lo: float = 0.5
    hi: float = 1.0
    slow: float = 0.25
    slow_frac: float = 0.3
    alpha: float = 0.8
    sigma: float = 0.5
    min_speed: float = 1e-3

    def draw(self, C: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed ^ SPEED_SALT)
        if self.kind == "uniform":
            s = rng.uniform(self.lo, self.hi, C)
        elif self.kind == "bimodal":
            s = np.where(rng.random(C) < self.slow_frac, self.slow, 1.0)
        elif self.kind == "zipf":
            ranks = rng.permutation(C) + 1
            s = ranks.astype(np.float64) ** (-self.alpha)
        elif self.kind == "lognormal":
            s = np.exp(self.sigma * rng.standard_normal(C))
        else:
            raise ValueError(f"unknown speed model kind {self.kind!r}")
        s = np.maximum(s, self.min_speed)
        return s / s.max()
