"""Empirical latency tables: quantized distributions sampled on device.

A ``LatencyTable`` is a discrete distribution over message latency in
virtual seconds — K bin representatives plus probabilities.  Tables are
built from parametric fits (uniform, lognormal, Pareto tail, mixtures)
or ingested from JSON/CSV traces of per-client round times
(``from_samples`` / ``repro.scenarios.registry.scenario_from_trace``),
and sampled *inside* jitted code via the alias method: one threefry key
per draw yields two uniforms, a column pick and an accept test, so a
sample is O(1), jit-traceable, and bit-reproducible wherever the same
key chain is used.  The cohort engines pre-quantize bin values to tick
counts (``tick_values``), so the in-loop sample is an integer gather.

No scipy: the lognormal/Pareto fits only need ``math.erf`` and
closed-form quantiles.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LatencyTable:
    """Discrete latency distribution: ascending bin values (virtual
    seconds) + probabilities.  Frozen and tuple-backed, so tables hash —
    the device engine keys its compiled-segment cache on them."""
    values: Tuple[float, ...]
    probs: Tuple[float, ...]

    def __post_init__(self):
        v = tuple(float(x) for x in self.values)
        p = tuple(float(x) for x in self.probs)
        if len(v) == 0 or len(v) != len(p):
            raise ValueError("values and probs must be equal-length and "
                             "non-empty")
        if any(x <= 0.0 for x in v):
            raise ValueError("latency bin values must be positive seconds")
        if any(b < a for a, b in zip(v, v[1:])):
            raise ValueError("latency bin values must be ascending")
        if any(x < 0.0 for x in p):
            raise ValueError("bin probabilities must be non-negative")
        tot = sum(p)
        if not tot > 0.0:
            raise ValueError("bin probabilities must sum to > 0")
        if abs(tot - 1.0) > 1e-9:     # idempotent: keeps an already-
            p = tuple(x / tot for x in p)   # normalized table bit-exact
        object.__setattr__(self, "values", v)
        object.__setattr__(self, "probs", p)

    # -- constructors ------------------------------------------------------
    @classmethod
    def constant(cls, seconds: float) -> "LatencyTable":
        return cls((float(seconds),), (1.0,))

    @classmethod
    def from_uniform(cls, lo: float, hi: float,
                     n_bins: int = 8) -> "LatencyTable":
        """Uniform(lo, hi) quantized to equal-width bins (centers)."""
        if not 0.0 < lo <= hi:
            raise ValueError(f"need 0 < lo <= hi, got ({lo}, {hi})")
        if hi == lo:
            return cls.constant(lo)
        edges = np.linspace(lo, hi, n_bins + 1)
        mids = 0.5 * (edges[:-1] + edges[1:])
        return cls(tuple(mids), (1.0 / n_bins,) * n_bins)

    @classmethod
    def from_samples(cls, samples: Sequence[float],
                     n_bins: int = 16) -> "LatencyTable":
        """Empirical histogram of observed per-message latencies."""
        s = np.asarray(list(samples), np.float64)
        if s.size == 0:
            raise ValueError("empty latency trace")
        if np.any(s <= 0.0) or not np.all(np.isfinite(s)):
            raise ValueError("trace latencies must be positive and finite")
        if float(s.min()) == float(s.max()):
            return cls.constant(float(s[0]))
        counts, edges = np.histogram(s, bins=n_bins)
        mids = 0.5 * (edges[:-1] + edges[1:])
        keep = counts > 0
        return cls(tuple(mids[keep]), tuple(counts[keep] / s.size))

    @classmethod
    def from_lognormal(cls, median: float, sigma: float, n_bins: int = 16,
                       spread: float = 3.0) -> "LatencyTable":
        """Lognormal fit: log-spaced bins over median * exp(±spread·σ),
        probabilities from the CDF (Φ via ``math.erf``), values at the
        geometric bin centers."""
        if median <= 0.0 or sigma <= 0.0:
            raise ValueError("need median > 0 and sigma > 0")
        z = np.linspace(-spread, spread, n_bins + 1)
        edges = median * np.exp(sigma * z)
        cdf = np.array([0.5 * (1.0 + math.erf(zz / math.sqrt(2.0)))
                        for zz in z])
        p = np.diff(cdf)
        p[0] += cdf[0]                 # fold both tails into the end bins
        p[-1] += 1.0 - cdf[-1]
        mids = np.sqrt(edges[:-1] * edges[1:])
        return cls(tuple(mids), tuple(p))

    @classmethod
    def from_pareto(cls, scale: float, alpha: float, n_bins: int = 16,
                    q_hi: float = 0.99) -> "LatencyTable":
        """Pareto(scale, alpha) heavy tail, truncated at quantile q_hi
        (the residual tail mass folds into the last bin) — the
        straggler-latency shape of IoT/mobile fleet measurements."""
        if scale <= 0.0 or alpha <= 0.0 or not 0.0 < q_hi < 1.0:
            raise ValueError("need scale > 0, alpha > 0, 0 < q_hi < 1")
        qs = np.linspace(0.0, q_hi, n_bins + 1)
        edges = scale * (1.0 - qs) ** (-1.0 / alpha)   # closed-form ppf
        p = np.diff(qs)
        p[-1] += 1.0 - q_hi
        mids = np.sqrt(edges[:-1] * edges[1:])
        return cls(tuple(mids), tuple(p))

    @classmethod
    def mix(cls, tables: Sequence["LatencyTable"],
            weights: Sequence[float]) -> "LatencyTable":
        """Mixture of tables (e.g. bimodal wifi/cellular latency)."""
        if len(tables) != len(weights) or not tables:
            raise ValueError("need one weight per table")
        pairs = sorted(
            (v, w * p) for t, w in zip(tables, weights)
            for v, p in zip(t.values, t.probs))
        return cls(tuple(v for v, _ in pairs), tuple(p for _, p in pairs))

    # -- (de)serialization — trace ingestion round-trip --------------------
    def to_json(self) -> str:
        return json.dumps({"values": list(self.values),
                           "probs": list(self.probs)})

    @classmethod
    def from_json(cls, text: str) -> "LatencyTable":
        obj = json.loads(text)
        return cls(tuple(obj["values"]), tuple(obj["probs"]))

    @classmethod
    def from_trace(cls, path: str, n_bins: int = 16) -> "LatencyTable":
        """Ingest a latency trace file.

        JSON: either a bare list of per-message seconds, or an object
        with a ``latency_s`` list, or an already-quantized
        ``{"values": [...], "probs": [...]}`` table.
        CSV: headerless, one latency per row (first column); or with a
        header row, the ``latency_s`` column (a header without one is
        an error — guessing a column would silently ingest wrong data).
        """
        ext = os.path.splitext(path)[1].lower()
        if ext not in (".json", ".csv"):
            raise ValueError(f"unsupported trace format {ext!r} "
                             "(want .json or .csv)")
        with open(path) as f:
            text = f.read()
        if ext == ".json":
            obj = json.loads(text)
            if isinstance(obj, dict) and "values" in obj:
                return cls(tuple(obj["values"]), tuple(obj["probs"]))
            samples = obj["latency_s"] if isinstance(obj, dict) else obj
            return cls.from_samples(samples, n_bins=n_bins)
        rows = [r.strip() for r in text.splitlines() if r.strip()]
        if not rows:
            raise ValueError(f"empty latency trace {path!r}")
        cells = [r.split(",") for r in rows]
        col = 0
        try:
            float(cells[0][0])
        except ValueError:                           # header row
            names = [c.strip() for c in cells[0]]
            if "latency_s" not in names:
                raise ValueError(
                    f"CSV trace header {names} has no 'latency_s' "
                    "column; refusing to guess which column holds the "
                    "latencies")
            col = names.index("latency_s")
            cells = cells[1:]
        if not cells:
            raise ValueError(f"empty latency trace {path!r} "
                             "(header but no data rows)")
        return cls.from_samples([float(r[col]) for r in cells],
                                n_bins=n_bins)

    @classmethod
    def per_client_from_trace(cls, path: str, n_bins: int = 16
                              ) -> Tuple["LatencyTable", ...]:
        """Ingest a trace keyed by device: one table per distinct client.

        JSON: an object with a ``clients`` mapping of client id ->
        per-message latency seconds.  CSV: header row with both a
        ``client`` and a ``latency_s`` column.  Tables come back ordered
        by sorted client id (numeric when the ids parse as numbers), so
        an engine's client ``c`` maps onto table ``c % T`` under the
        default cyclic assignment.
        """
        ext = os.path.splitext(path)[1].lower()
        if ext not in (".json", ".csv"):
            raise ValueError(f"unsupported trace format {ext!r} "
                             "(want .json or .csv)")
        with open(path) as f:
            text = f.read()
        groups: dict = {}
        if ext == ".json":
            obj = json.loads(text)
            if not isinstance(obj, dict) or "clients" not in obj:
                raise ValueError(
                    "per-client JSON trace needs a 'clients' mapping of "
                    "client id -> [latency_s, ...]")
            groups = {str(k): list(v) for k, v in obj["clients"].items()}
        else:
            rows = [r.strip() for r in text.splitlines() if r.strip()]
            if not rows:
                raise ValueError(f"empty latency trace {path!r}")
            names = [c.strip() for c in rows[0].split(",")]
            if "client" not in names or "latency_s" not in names:
                raise ValueError(
                    f"per-client CSV trace header {names} needs both a "
                    "'client' and a 'latency_s' column")
            ci, li = names.index("client"), names.index("latency_s")
            for r in rows[1:]:
                c = r.split(",")
                groups.setdefault(c[ci].strip(), []).append(float(c[li]))
        if not groups:
            raise ValueError(f"empty latency trace {path!r}")

        def order(k):
            try:
                return (0, float(k), k)
            except ValueError:
                return (1, 0.0, k)

        return tuple(cls.from_samples(groups[k], n_bins=n_bins)
                     for k in sorted(groups, key=order))

    # -- stats -------------------------------------------------------------
    def mean(self) -> float:
        return sum(v * p for v, p in zip(self.values, self.probs))

    def quantile(self, q: float) -> float:
        acc = 0.0
        for v, p in zip(self.values, self.probs):
            acc += p
            if acc >= q:
                return v
        return self.values[-1]

    @property
    def max_s(self) -> float:
        return self.values[-1]

    # -- engine-facing views ----------------------------------------------
    def tick_values(self, dt: float) -> np.ndarray:
        """Bin values quantized to arrival-tick offsets, minimum 1 —
        the same ``max(1, ceil(s / dt))`` rule both cohort engines use
        for deterministic latency, so a one-bin table reproduces the
        legacy constant-latency schedule exactly."""
        v = np.asarray(self.values, np.float64)
        return np.maximum(1, np.ceil(v / dt)).astype(np.int32)

    def alias_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Vose alias decomposition -> (prob f32 [K], alias i32 [K])."""
        return vose_alias(self.probs)

    def padded(self, K: int) -> Tuple[np.ndarray, np.ndarray]:
        """(values f64 [K], probs f64 [K]) padded to K bins with
        zero-probability copies of the last bin — how ``ScenarioPlan``
        stacks tables of different sizes into one [T, K] block.  Padding
        bins never win an alias draw (their column probability is 0 and
        their alias points at a real bin), so a padded table samples
        exactly like the original."""
        n = len(self.values)
        if K < n:
            raise ValueError(f"cannot pad a {n}-bin table down to {K}")
        v = np.asarray(self.values + (self.values[-1],) * (K - n))
        p = np.asarray(self.probs + (0.0,) * (K - n))
        return v, p


def vose_alias(probs) -> Tuple[np.ndarray, np.ndarray]:
    """Vose alias decomposition of a probability vector (zero-probability
    padding bins allowed) -> (prob f32 [K], alias i32 [K])."""
    K = len(probs)
    p = np.asarray(probs, np.float64) * K
    prob = np.zeros(K, np.float64)
    alias = np.zeros(K, np.int64)
    small = [i for i in range(K) if p[i] < 1.0]
    large = [i for i in range(K) if p[i] >= 1.0]
    while small and large:
        s, l = small.pop(), large.pop()
        prob[s] = p[s]
        alias[s] = l
        p[l] = (p[l] + p[s]) - 1.0
        (small if p[l] < 1.0 else large).append(l)
    for i in large + small:       # numerical leftovers: certain bins
        prob[i] = 1.0
        alias[i] = i
    return prob.astype(np.float32), alias.astype(np.int32)


def key_uniforms(keys):
    """[N, 2] uint32 threefry keys -> [N, 2] uniforms in [0, 1)."""
    return jax.vmap(lambda k: jax.random.uniform(k, (2,)))(keys)


def alias_sample(u, prob, alias):
    """Alias-method draw: ``u`` [..., 2] uniforms -> bin indices.

    u[..., 0] picks a column, u[..., 1] runs the accept test; identical
    arithmetic on every engine keeps draws bit-reproducible.
    """
    K = prob.shape[0]
    j0 = jnp.minimum((u[..., 0] * K).astype(jnp.int32), K - 1)
    return jnp.where(u[..., 1] < prob[j0], j0, alias[j0])


def alias_sample_rows(u, prob, alias):
    """Per-row alias draw for stacked tables: ``u`` [..., 2] uniforms
    against row-matched ``prob`` / ``alias`` [..., K] arrays (one table
    row per leading index, e.g. the per-client ``table_id`` gather).

    Identical arithmetic to ``alias_sample`` — for a single table the
    two produce bit-identical bins, which is what keeps per-client
    scenarios on the engines' existing parity contract.
    """
    K = prob.shape[-1]
    j0 = jnp.minimum((u[..., 0] * K).astype(jnp.int32), K - 1)
    p0 = jnp.take_along_axis(prob, j0[..., None], axis=-1)[..., 0]
    a0 = jnp.take_along_axis(alias, j0[..., None], axis=-1)[..., 0]
    return jnp.where(u[..., 1] < p0, j0, a0)


def implied_probs(prob: np.ndarray, alias: np.ndarray) -> np.ndarray:
    """Probability of each bin under exact alias sampling — the
    decode-side invariant pinned by the property tests:
    ``implied_probs(*t.alias_arrays()) == t.probs``."""
    K = len(prob)
    out = np.asarray(prob, np.float64).copy()
    for i in range(K):
        out[alias[i]] += 1.0 - prob[i]
    return out / K
