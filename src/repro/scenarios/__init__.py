# Device-sampleable fleet-heterogeneity scenarios: empirical latency
# tables (alias-method draws on the engines' threefry chain),
# availability/churn models, long-tail speed distributions, and a
# registry of named presets + trace ingestion.  One Scenario spec drives
# all three engines (event, host-cohort, device-resident) — see
# repro.scenarios.registry for the key-chain contract that keeps
# host-cohort vs device trajectories bit-identical under stochastic
# latency and availability.
from repro.scenarios.availability import (AlwaysOn, Churn, Diurnal,
                                          SpeedModel)
from repro.scenarios.registry import (Scenario, ScenarioPlan, get_scenario,
                                      legacy_latency_scenario,
                                      register_scenario, scenario_from_trace,
                                      scenario_names, scenario_plan)
from repro.scenarios.tables import (LatencyTable, alias_sample,
                                    implied_probs, key_uniforms)

__all__ = [
    "LatencyTable", "alias_sample", "key_uniforms", "implied_probs",
    "AlwaysOn", "Diurnal", "Churn", "SpeedModel",
    "Scenario", "ScenarioPlan", "scenario_plan", "get_scenario",
    "register_scenario", "scenario_names", "scenario_from_trace",
    "legacy_latency_scenario",
]
