# Device-sampleable fleet-heterogeneity scenarios: empirical latency
# tables (alias-method draws on the engines' threefry chain, one table
# fleet-wide or per-client via a TableAssignment), availability/churn
# models (diurnal windows, independent/regional epoch churn, renewal-
# process on/off churn), long-tail speed distributions, and a registry
# of named presets + trace ingestion.  One Scenario spec drives all
# three engines (event, host-cohort, device-resident) — see
# repro.scenarios.registry for the key-chain contract that keeps
# host-cohort vs device trajectories bit-identical under stochastic
# latency and availability.
from repro.scenarios.availability import (AlwaysOn, Churn, Diurnal,
                                          RegionalChurn, RenewalChurn,
                                          SpeedModel)
from repro.scenarios.registry import (Scenario, ScenarioPlan,
                                      TableAssignment, get_scenario,
                                      legacy_latency_scenario,
                                      register_scenario, scenario_from_trace,
                                      scenario_names, scenario_plan)
from repro.scenarios.tables import (LatencyTable, alias_sample,
                                    alias_sample_rows, implied_probs,
                                    key_uniforms, vose_alias)

__all__ = [
    "LatencyTable", "alias_sample", "alias_sample_rows", "key_uniforms",
    "implied_probs", "vose_alias",
    "AlwaysOn", "Diurnal", "Churn", "RegionalChurn", "RenewalChurn",
    "SpeedModel",
    "Scenario", "ScenarioPlan", "TableAssignment", "scenario_plan",
    "get_scenario", "register_scenario", "scenario_names",
    "scenario_from_trace", "legacy_latency_scenario",
]
