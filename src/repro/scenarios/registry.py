"""Scenario specs, named presets, trace ingestion, and engine plans.

A ``Scenario`` bundles the three axes of fleet heterogeneity the
asynchronous protocol exists to survive — message latency
(``LatencyTable``), availability (on/off windows, churn), and compute
speed (``SpeedModel``) — into one declarative, hashable spec that all
three engines accept (``AsyncFLSimulator``, ``CohortEngine``,
``DeviceCohortEngine``) in place of the old ``latency_fn`` / ``(lo,
hi)`` split.

``ScenarioPlan`` is the compiled view one engine instance consumes:
alias tables and tick quantization for a specific (C, dt, seed), plus
the threefry key chain all engines share.  Latency draws are *message
addressed* — update latency by (client, round), broadcast latency by
(round k, client) — so they are pure functions of message identity, not
of engine scheduling: the host-loop and device-resident cohort engines
draw bit-identical arrival ticks, and the event simulator draws the
same bins in continuous time.

Key chain (distinct from the DP-noise ``seed ^ 0x5EED`` chain):

    lat_base  = PRNGKey(seed ^ LAT_SALT)
    update    (c, i): fold_in(fold_in(fold_in(lat_base, 0), c), i)
    broadcast (k, c): fold_in(fold_in(fold_in(lat_base, 1), k), c)
    churn     (t, c): uniform(fold_in(PRNGKey(seed ^ AVAIL_SALT),
                                      t // epoch))[c]

Presets: ``uniform`` (the legacy default network), ``mobile_diurnal``
(lognormal latency, diurnal windows, bimodal speeds),
``iot_straggler`` (Pareto-tail latency, churn, Zipf speeds).  Traces
ingest via ``scenario_from_trace`` (JSON/CSV per-message seconds).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Salt constants live in the central registry (repro.analysis.salts);
# re-exported here for back-compat.  The PRNG auditor enforces that key
# creations use these registry imports, never ad-hoc literals.
from repro.analysis.salts import LAT_SALT, TABLE_SALT
from repro.scenarios.availability import (AlwaysOn, Churn, Diurnal,
                                          RegionalChurn, RenewalChurn,
                                          SpeedModel)
from repro.scenarios.tables import (LatencyTable, alias_sample_rows,
                                    key_uniforms, vose_alias)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def draw_table_ids(C: int, T: int, weights, seed):
    """[C] int32 table ids for ``TableAssignment("draw")``, derived
    entirely on the threefry chain: one uniform per client from
    ``fold_in(PRNGKey(seed ^ TABLE_SALT), c)`` inverted through the
    normalized-weight CDF.

    Jit-compatible with static ``(C, T, weights)`` and a traced seed —
    the multi-host prerequisite: every host re-derives the SAME ids
    in-jit from the seed instead of shipping a host-numpy array drawn
    on one process.  ``weights=None`` means uniform over the T tables.
    """
    base = jax.random.PRNGKey(seed ^ TABLE_SALT)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        base, jnp.arange(C))
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
    w = (jnp.asarray(weights, jnp.float32) if weights is not None
         else jnp.ones(T, jnp.float32))
    cum = jnp.cumsum(w / jnp.sum(w))
    # inverse CDF over the first T-1 thresholds: u >= cum[j] pushes the
    # id past bin j, and u < 1 <= cum[-1]-ish keeps ids in [0, T)
    return jnp.sum(u[:, None] >= cum[None, :-1], axis=1).astype(jnp.int32)


@dataclass(frozen=True)
class TableAssignment:
    """[C]-indexed mapping of clients onto a scenario's latency tables.

    kinds:
      cycle:    client c uses table c % T (the per-device trace default)
      explicit: ``table_id`` is the full [C] tuple of table indices
      draw:     each client draws its table from ``weights`` (uniform
                when omitted) on the ``TABLE_SALT`` threefry chain —
                a pure, jit-rederivable function of the engine seed
                (``draw_table_ids``)
    """
    kind: str = "cycle"
    table_id: Optional[Tuple[int, ...]] = None
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.kind not in ("cycle", "explicit", "draw"):
            raise ValueError(f"unknown table assignment kind "
                             f"{self.kind!r} (want cycle|explicit|draw)")
        if self.kind == "explicit":
            if self.table_id is None:
                raise ValueError("explicit table assignment needs "
                                 "table_id")
            object.__setattr__(self, "table_id",
                               tuple(int(x) for x in self.table_id))
        if self.weights is not None:
            w = tuple(float(x) for x in self.weights)
            if any(x < 0.0 for x in w) or not sum(w) > 0.0:
                raise ValueError("table assignment weights must be "
                                 "non-negative and sum to > 0")
            object.__setattr__(self, "weights", w)

    def resolve(self, C: int, T: int, seed: int) -> np.ndarray:
        """-> [C] int32 table ids, validated against C and T."""
        if self.kind == "explicit":
            if len(self.table_id) != C:
                raise ValueError(
                    f"table_id length {len(self.table_id)} does not "
                    f"match n_clients {C}")
            tid = np.asarray(self.table_id, np.int64)
            if tid.size and (tid.min() < 0 or tid.max() >= T):
                raise ValueError(
                    f"table_id entries must lie in [0, {T}); got range "
                    f"[{tid.min()}, {tid.max()}]")
            return tid.astype(np.int32)
        if self.kind == "draw":
            if self.weights is not None and len(self.weights) != T:
                raise ValueError(
                    f"need one weight per table: {len(self.weights)} "
                    f"weights for {T} tables")
            return np.asarray(draw_table_ids(C, T, self.weights, seed),
                              np.int32)
        return (np.arange(C) % T).astype(np.int32)


@dataclass(frozen=True)
class Scenario:
    """Declarative heterogeneity spec shared by all engines.  Frozen and
    hashable: the device engine keys its compiled-segment cache on it.

    ``latency`` is one ``LatencyTable`` for the whole fleet or a tuple
    of tables with a ``TableAssignment`` mapping clients onto them
    (per-client heterogeneous network distributions, e.g. per-device
    trace ingestion).  ``ring_cap`` bounds the device engine's update
    arrival ring (and hence its unrolled bucket scatter): latency draws
    quantizing past it spill into the engine's explicit overflow bucket
    instead of widening the ring — both cohort engines split arrivals at
    the same plan-computed boundary, which is what keeps them
    bit-identical under heavy-tailed tables."""
    name: str
    latency: Any                    # LatencyTable | tuple of LatencyTable
    availability: Any = field(default_factory=AlwaysOn)
    speed_model: Optional[SpeedModel] = None
    assignment: Optional[TableAssignment] = None
    ring_cap: int = 32

    def __post_init__(self):
        lat = self.latency
        if isinstance(lat, (list, tuple)):
            lat = tuple(lat)
            if not lat:
                raise ValueError("need at least one latency table")
            if not all(isinstance(t, LatencyTable) for t in lat):
                raise TypeError("latency tuple entries must be "
                                "LatencyTables")
            object.__setattr__(self, "latency", lat)
        elif not isinstance(lat, LatencyTable):
            raise TypeError(f"latency must be a LatencyTable or a tuple "
                            f"of them, got {type(lat).__name__}")
        if self.assignment is None and len(self.tables) > 1:
            object.__setattr__(self, "assignment", TableAssignment())
        if self.ring_cap < 2:
            raise ValueError("need ring_cap >= 2")

    @property
    def tables(self) -> Tuple[LatencyTable, ...]:
        lat = self.latency
        return lat if isinstance(lat, tuple) else (lat,)

    def speeds(self, C: int, seed: int) -> Optional[np.ndarray]:
        if self.speed_model is None:
            return None
        return self.speed_model.draw(C, seed)


class ScenarioPlan:
    """One engine instance's compiled view of a scenario.

    With ``dt`` set (cohort engines): jit-traceable tick closures —
    ``update_ticks`` / ``broadcast_ticks`` ([C] int32 arrival offsets,
    >= 1) and ``avail_mask`` (bool [C], or None when always-on).  The
    host-loop engine calls the same closures jitted
    (``host_update_ticks`` etc.), which is what makes host-cohort vs
    device bit-identical under stochastic scenarios.

    With ``dt=None`` (event simulator): continuous-seconds accessors
    ``update_latency_s`` / ``broadcast_latency_s`` drawing the same bins
    from the same chain, and ``windows`` for deterministic availability.
    """

    def __init__(self, scenario: Scenario, *, C: int, seed: int,
                 dt: Optional[float] = None):
        self.scenario = scenario
        self.C = int(C)
        self.seed = int(seed)
        self.dt = dt
        tables = scenario.tables
        self.T = len(tables)
        self.K = max(len(t.values) for t in tables)
        if scenario.assignment is not None:
            self.table_id = scenario.assignment.resolve(self.C, self.T,
                                                        seed)
        else:
            self.table_id = np.zeros(self.C, np.int32)
        # stacked [T, K] blocks: tables padded to a common K with
        # zero-probability bins (LatencyTable.padded — padded tables draw
        # exactly like the originals), then gathered once over
        # table_id[c] into per-client [C, K] rows so the in-loop draw is
        # a take_along_axis, not a per-call table dispatch
        padded = [t.padded(self.K) for t in tables]
        vals_tk = np.stack([v for v, _ in padded])          # [T, K] f64
        aliases = [vose_alias(p) for _, p in padded]
        tid = self.table_id
        self._values_c = vals_tk[tid]                       # [C, K] f64
        self._prob_c = jnp.asarray(
            np.stack([a[0] for a in aliases])[tid])         # [C, K] f32
        self._alias_c = jnp.asarray(
            np.stack([a[1] for a in aliases])[tid])         # [C, K] i32
        self._values_c_dev = jnp.asarray(self._values_c, jnp.float32)
        self._cidx = jnp.arange(self.C)
        # per-client-constant seconds: every assigned row is a single
        # effective bin — skip the RNG entirely (legacy constant
        # network).  Values round-trip through f32 like the sampled path
        # (the draw gathers from the f32 [C, K] block).
        self._const_s = bool(
            (self._values_c == self._values_c[:, :1]).all())
        self._const_vals_s = self._values_c[:, 0].astype(
            np.float32).astype(np.float64)

        lat_base = jax.random.PRNGKey(seed ^ LAT_SALT)
        self._upd_base = jax.random.fold_in(lat_base, 0)
        self._bc_base = jax.random.fold_in(lat_base, 1)
        self._upd_client_keys = jax.vmap(
            jax.random.fold_in, in_axes=(None, 0))(self._upd_base,
                                                   self._cidx)
        self._upd_s_cache: Dict[int, np.ndarray] = {}

        self.duty = float(scenario.availability.duty)
        if dt is not None:
            tick_c = np.maximum(
                1, np.ceil(self._values_c / dt)).astype(np.int32)
            self.max_lat_ticks = int(tick_c.max())
            # near/far arrival split shared by BOTH cohort engines: the
            # device update ring holds ring_ticks slots; draws past it
            # go to the explicit overflow bucket.  far_tick_values is
            # the (compile-time) set of quantized bin values >= the
            # boundary — it bounds how many distinct far arrival ticks
            # one completion tick can produce.
            self.ring_ticks = next_pow2(
                min(self.max_lat_ticks + 1, scenario.ring_cap))
            self.far_tick_values = tuple(
                int(v) for v in np.unique(tick_c[tick_c >= self.ring_ticks]))
            # constant fast path: every client's table quantizes to one
            # tick at this dt (the default uniform scenario at the usual
            # dt >= 0.1) — skip the in-loop RNG, matching legacy engines
            self._ticks_const = bool((tick_c == tick_c[:, :1]).all())
            self._tick0_c = tick_c[:, 0].astype(np.int64)
            self._tick0_c_dev = jnp.asarray(tick_c[:, 0])
            self._tick_vals_c = jnp.asarray(tick_c)
            self.avail_mask = scenario.availability.tick_plan(
                self.C, dt, seed)
            self._host_upd = jax.jit(self.update_ticks)
            self._host_bc = jax.jit(self.broadcast_ticks)
            self._host_avail = (jax.jit(self.avail_mask)
                                if self.avail_mask is not None else None)

    def fingerprint(self):
        """Hashable identity for compiled-code caches; the plan is a
        pure function of (scenario, C, dt, seed) and the caller's cache
        key already carries C and seed."""
        return (self.scenario, self.dt)

    # -- tick-quantized draws (cohort engines, jit-traceable) --------------
    def _draw_bins(self, keys):
        """Per-client alias draw: [C, 2]-keyed bins from each client's
        assigned table row."""
        return alias_sample_rows(key_uniforms(keys), self._prob_c,
                                 self._alias_c)

    def update_ticks(self, i):
        """Arrival-tick offsets for every client's round-``i[c]`` update
        message ([C] traced int32 -> [C] int32, each >= 1)."""
        if self._ticks_const:
            return self._tick0_c_dev
        keys = jax.vmap(jax.random.fold_in)(self._upd_client_keys, i)
        j = self._draw_bins(keys)
        return jnp.take_along_axis(self._tick_vals_c, j[:, None],
                                   axis=1)[:, 0]

    def broadcast_ticks(self, k):
        """Per-client arrival-tick offsets of broadcast ``k`` (scalar
        traced int32 -> [C] int32)."""
        if self._ticks_const:
            return self._tick0_c_dev
        bk = jax.random.fold_in(self._bc_base, k)
        keys = jax.vmap(jax.random.fold_in,
                        in_axes=(None, 0))(bk, self._cidx)
        j = self._draw_bins(keys)
        return jnp.take_along_axis(self._tick_vals_c, j[:, None],
                                   axis=1)[:, 0]

    # -- host-side wrappers (host-loop cohort engine) ----------------------
    def host_update_ticks(self, i: np.ndarray) -> np.ndarray:
        if self._ticks_const:
            return self._tick0_c.copy()
        # device_put of a pre-converted array: an int64->int32
        # jnp.asarray is an IMPLICIT transfer and raises inside the
        # host engine's transfer-guarded steady segments
        return np.asarray(
            self._host_upd(jax.device_put(np.asarray(i, np.int32))),
            np.int64)

    def host_broadcast_ticks(self, k: int) -> np.ndarray:
        if self._ticks_const:
            return self._tick0_c.copy()
        # device_put, not jnp.int32: the host engine calls this inside
        # its transfer-guarded steady segments, where only EXPLICIT
        # host->device transfers are allowed
        return np.asarray(self._host_bc(jax.device_put(np.int32(k))),
                          np.int64)

    def host_avail(self, t: int) -> Optional[np.ndarray]:
        if self._host_avail is None:
            return None
        return np.asarray(self._host_avail(jax.device_put(np.int32(t))))

    # -- continuous-seconds draws (event simulator) ------------------------
    def update_latencies_s(self, i: int) -> np.ndarray:
        """All C clients' latency seconds for their round-``i`` update
        message in ONE vectorized draw (cached per round): same
        per-(c, i) keys and uniforms as the cohort engines'
        ``update_ticks``, so every engine puts each message in the same
        bin.  The event simulator asks per message; the batch+cache
        turns its per-message jit dispatch + host sync into one device
        call per round."""
        if self._const_s:
            return self._const_vals_s.copy()
        i = int(i)
        hit = self._upd_s_cache.get(i)
        if hit is not None:
            return hit
        if not hasattr(self, "_upd_vec_jit"):
            def draw(i):
                keys = jax.vmap(jax.random.fold_in,
                                in_axes=(0, None))(self._upd_client_keys,
                                                   i)
                j = self._draw_bins(keys)
                return jnp.take_along_axis(self._values_c_dev,
                                           j[:, None], axis=1)[:, 0]
            self._upd_vec_jit = jax.jit(draw)
        out = np.asarray(self._upd_vec_jit(jnp.int32(i)), np.float64)
        self._upd_s_cache[i] = out
        while len(self._upd_s_cache) > 16:      # rounds advance in order
            self._upd_s_cache.pop(next(iter(self._upd_s_cache)))
        return out

    def update_latency_s(self, c: int, i: int) -> float:
        """Latency (virtual seconds) of client c's round-i update — same
        bin the cohort engines quantize for this message."""
        return float(self.update_latencies_s(i)[c])

    def broadcast_latencies_s(self, k: int) -> np.ndarray:
        """All C clients' latency seconds for broadcast ``k`` in ONE
        vectorized draw — same per-(k, c) keys and uniforms as the
        cohort engines' ``broadcast_ticks``, so every engine puts the
        message in the same bin."""
        if self._const_s:
            return self._const_vals_s.copy()
        if not hasattr(self, "_bc_vec_jit"):
            def draw(k):
                bk = jax.random.fold_in(self._bc_base, k)
                keys = jax.vmap(jax.random.fold_in,
                                in_axes=(None, 0))(bk, self._cidx)
                j = self._draw_bins(keys)
                return jnp.take_along_axis(self._values_c_dev,
                                           j[:, None], axis=1)[:, 0]
            self._bc_vec_jit = jax.jit(draw)
        return np.asarray(self._bc_vec_jit(jnp.int32(k)), np.float64)


# -- plan cache: plans are immutable, sampler jits are reused across
#    engine instances (benchmarks build fresh simulators per repetition)
_PLAN_CACHE: Dict[Any, ScenarioPlan] = {}
_PLAN_CACHE_MAX = 32


def scenario_plan(scenario: Scenario, *, C: int, seed: int,
                  dt: Optional[float] = None) -> ScenarioPlan:
    key = (scenario, C, seed, dt)
    plan = _PLAN_CACHE.pop(key, None)
    if plan is None:
        plan = ScenarioPlan(scenario, C=C, seed=seed, dt=dt)
    _PLAN_CACHE[key] = plan                      # pop+reinsert: LRU order
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
    return plan


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], Scenario]] = {}


def register_scenario(name: str):
    """Decorator: register a zero-arg Scenario builder under ``name``."""
    def deco(fn: Callable[[], Scenario]):
        _REGISTRY[name] = fn
        return fn
    return deco


def scenario_names():
    return sorted(_REGISTRY)


def get_scenario(spec) -> Scenario:
    """Resolve a scenario argument: a ``Scenario`` passes through, a
    string looks up a registered preset."""
    if isinstance(spec, Scenario):
        return spec
    if isinstance(spec, str):
        if spec not in _REGISTRY:
            raise KeyError(f"unknown scenario {spec!r} "
                           f"(have {scenario_names()})")
        return _REGISTRY[spec]()
    raise TypeError(f"scenario must be a Scenario or preset name, "
                    f"got {type(spec).__name__}")


@register_scenario("uniform")
def _uniform() -> Scenario:
    """The legacy default network: latency U(0.05, 0.1) virtual seconds,
    full availability, caller-supplied speeds."""
    return Scenario("uniform", LatencyTable.from_uniform(0.05, 0.1, 8))


@register_scenario("mobile_diurnal")
def _mobile_diurnal() -> Scenario:
    """Phone-fleet shape: lognormal latency (wifi body, cellular tail),
    diurnal charging/idle windows with per-client phase, bimodal
    fast/slow device split."""
    return Scenario(
        "mobile_diurnal",
        LatencyTable.from_lognormal(median=0.3, sigma=0.8, n_bins=12),
        Diurnal(period_s=512.0, on_frac=0.75),
        SpeedModel(kind="bimodal", slow=0.3, slow_frac=0.3))


@register_scenario("iot_straggler")
def _iot_straggler() -> Scenario:
    """Sensor-fleet shape: Pareto-tail latency (lossy links, retries),
    epoch churn (duty-cycled radios), Zipf long-tail compute speeds."""
    return Scenario(
        "iot_straggler",
        LatencyTable.from_pareto(scale=0.1, alpha=1.2, n_bins=12,
                                 q_hi=0.99),
        Churn(p_available=0.9, epoch_s=64.0),
        SpeedModel(kind="zipf", alpha=0.5))


@register_scenario("geo_regional")
def _geo_regional() -> Scenario:
    """Geo-distributed fleet: two network populations (fiber body,
    cellular tail) assigned per client, with correlated regional
    outages — the partition regime independent churn cannot express.
    Cohort-engines only (RegionalChurn has no continuous-time form)."""
    return Scenario(
        "geo_regional",
        (LatencyTable.from_lognormal(median=0.08, sigma=0.4, n_bins=8),
         LatencyTable.from_lognormal(median=0.5, sigma=0.9, n_bins=8)),
        RegionalChurn(n_regions=4, p_available=0.9, p_region_up=0.95,
                      epoch_s=64.0),
        SpeedModel(kind="lognormal", sigma=0.4),
        assignment=TableAssignment("draw", weights=(0.6, 0.4)))


@register_scenario("sensor_renewal")
def _sensor_renewal() -> Scenario:
    """Duty-cycled sensor fleet: Pareto-tail latency plus renewal-process
    on/off churn (exponential holding times) — the churn model ALL three
    engines run: the event simulator integrates the continuous renewal
    windows, the cohort engines the addressed per-tick approximation."""
    return Scenario(
        "sensor_renewal",
        LatencyTable.from_pareto(scale=0.1, alpha=1.2, n_bins=12,
                                 q_hi=0.99),
        RenewalChurn(on_rate=1.0 / 16.0, off_rate=1.0 / 48.0),
        SpeedModel(kind="zipf", alpha=0.5))


def scenario_from_trace(path: str, *, name: Optional[str] = None,
                        availability=None,
                        speed_model: Optional[SpeedModel] = None,
                        n_bins: int = 16,
                        per_client: bool = False) -> Scenario:
    """Build a scenario whose latency table is fit to a measured trace
    (JSON/CSV of per-message seconds, see ``LatencyTable.from_trace``).

    With ``per_client=True`` the trace must be keyed by device (JSON
    ``clients`` mapping, or CSV with ``client`` + ``latency_s``
    columns): each distinct trace client becomes its own table
    (``LatencyTable.per_client_from_trace``) and engine client ``c``
    uses table ``c % T`` — per-device latency distributions survive
    ingestion instead of being pooled into one fleet histogram."""
    if per_client:
        tables = LatencyTable.per_client_from_trace(path, n_bins=n_bins)
        return Scenario(
            name or f"trace:{path}", tables,
            availability if availability is not None else AlwaysOn(),
            speed_model, assignment=TableAssignment("cycle"))
    return Scenario(name or f"trace:{path}",
                    LatencyTable.from_trace(path, n_bins=n_bins),
                    availability if availability is not None else AlwaysOn(),
                    speed_model)


def legacy_latency_scenario(latency) -> Scenario:
    """Adapt the device engine's pre-scenario ``latency`` spec: a float
    is a constant virtual-second latency, an (lo, hi) pair is uniform;
    ``None`` is the legacy default network."""
    if callable(latency):
        raise TypeError(
            "the jitted engines take a latency *scenario* — a Scenario, "
            "a preset name, a float (virtual seconds) or an (lo, hi) "
            "uniform range — not a host callable; a Python latency_fn "
            "cannot run inside the jitted tick loop (use engine='cohort' "
            "with latency_fn=... for host-callable latency)")
    if latency is None:
        return get_scenario("uniform")
    if isinstance(latency, (int, float)):
        return Scenario(f"const:{latency}",
                        LatencyTable.constant(float(latency)))
    lo, hi = (float(latency[0]), float(latency[1]))
    if not 0.0 < lo <= hi:
        raise ValueError(
            f"latency=(lo, hi) needs 0 < lo <= hi, got ({lo}, {hi})")
    if lo == hi:
        return Scenario(f"const:{lo}", LatencyTable.constant(lo))
    return Scenario(f"uniform:{lo},{hi}",
                    LatencyTable.from_uniform(lo, hi, 8))
