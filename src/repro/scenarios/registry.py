"""Scenario specs, named presets, trace ingestion, and engine plans.

A ``Scenario`` bundles the three axes of fleet heterogeneity the
asynchronous protocol exists to survive — message latency
(``LatencyTable``), availability (on/off windows, churn), and compute
speed (``SpeedModel``) — into one declarative, hashable spec that all
three engines accept (``AsyncFLSimulator``, ``CohortEngine``,
``DeviceCohortEngine``) in place of the old ``latency_fn`` / ``(lo,
hi)`` split.

``ScenarioPlan`` is the compiled view one engine instance consumes:
alias tables and tick quantization for a specific (C, dt, seed), plus
the threefry key chain all engines share.  Latency draws are *message
addressed* — update latency by (client, round), broadcast latency by
(round k, client) — so they are pure functions of message identity, not
of engine scheduling: the host-loop and device-resident cohort engines
draw bit-identical arrival ticks, and the event simulator draws the
same bins in continuous time.

Key chain (distinct from the DP-noise ``seed ^ 0x5EED`` chain):

    lat_base  = PRNGKey(seed ^ LAT_SALT)
    update    (c, i): fold_in(fold_in(fold_in(lat_base, 0), c), i)
    broadcast (k, c): fold_in(fold_in(fold_in(lat_base, 1), k), c)
    churn     (t, c): uniform(fold_in(PRNGKey(seed ^ AVAIL_SALT),
                                      t // epoch))[c]

Presets: ``uniform`` (the legacy default network), ``mobile_diurnal``
(lognormal latency, diurnal windows, bimodal speeds),
``iot_straggler`` (Pareto-tail latency, churn, Zipf speeds).  Traces
ingest via ``scenario_from_trace`` (JSON/CSV per-message seconds).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.scenarios.availability import (AlwaysOn, Churn, Diurnal,
                                          SpeedModel)
from repro.scenarios.tables import LatencyTable, alias_sample, key_uniforms

LAT_SALT = 0x1A7E9C       # latency threefry chain: seed ^ LAT_SALT


@dataclass(frozen=True)
class Scenario:
    """Declarative heterogeneity spec shared by all engines.  Frozen and
    hashable: the device engine keys its compiled-segment cache on it."""
    name: str
    latency: LatencyTable
    availability: Any = field(default_factory=AlwaysOn)
    speed_model: Optional[SpeedModel] = None

    def speeds(self, C: int, seed: int) -> Optional[np.ndarray]:
        if self.speed_model is None:
            return None
        return self.speed_model.draw(C, seed)


class ScenarioPlan:
    """One engine instance's compiled view of a scenario.

    With ``dt`` set (cohort engines): jit-traceable tick closures —
    ``update_ticks`` / ``broadcast_ticks`` ([C] int32 arrival offsets,
    >= 1) and ``avail_mask`` (bool [C], or None when always-on).  The
    host-loop engine calls the same closures jitted
    (``host_update_ticks`` etc.), which is what makes host-cohort vs
    device bit-identical under stochastic scenarios.

    With ``dt=None`` (event simulator): continuous-seconds accessors
    ``update_latency_s`` / ``broadcast_latency_s`` drawing the same bins
    from the same chain, and ``windows`` for deterministic availability.
    """

    def __init__(self, scenario: Scenario, *, C: int, seed: int,
                 dt: Optional[float] = None):
        self.scenario = scenario
        self.C = int(C)
        self.seed = int(seed)
        self.dt = dt
        tbl = scenario.latency
        self.K = len(tbl.values)
        prob, alias = tbl.alias_arrays()
        self._prob = jnp.asarray(prob)
        self._alias = jnp.asarray(alias)
        self._values_s = jnp.asarray(np.asarray(tbl.values, np.float32))
        self._cidx = jnp.arange(self.C)

        lat_base = jax.random.PRNGKey(seed ^ LAT_SALT)
        self._upd_base = jax.random.fold_in(lat_base, 0)
        self._bc_base = jax.random.fold_in(lat_base, 1)
        self._upd_client_keys = jax.vmap(
            jax.random.fold_in, in_axes=(None, 0))(self._upd_base,
                                                   self._cidx)

        self.duty = float(scenario.availability.duty)
        if dt is not None:
            tick_vals = tbl.tick_values(dt)
            self.max_lat_ticks = int(tick_vals.max())
            # constant fast path: a one-bin table, OR a multi-bin table
            # whose bins all quantize to the same tick at this dt (the
            # default uniform scenario at the usual dt >= 0.1) — skip
            # the in-loop RNG entirely, matching the legacy engines
            self._ticks_const = bool((tick_vals == tick_vals[0]).all())
            self._tick0 = int(tick_vals[0])
            self._tick_vals = jnp.asarray(tick_vals)
            self.avail_mask = scenario.availability.tick_plan(
                self.C, dt, seed)
            self._host_upd = jax.jit(self.update_ticks)
            self._host_bc = jax.jit(self.broadcast_ticks)
            self._host_avail = (jax.jit(self.avail_mask)
                                if self.avail_mask is not None else None)

    def fingerprint(self):
        """Hashable identity for compiled-code caches; the plan is a
        pure function of (scenario, C, dt, seed) and the caller's cache
        key already carries C and seed."""
        return (self.scenario, self.dt)

    # -- tick-quantized draws (cohort engines, jit-traceable) --------------
    def _draw_ticks(self, keys):
        return self._tick_vals[alias_sample(key_uniforms(keys),
                                            self._prob, self._alias)]

    def update_ticks(self, i):
        """Arrival-tick offsets for every client's round-``i[c]`` update
        message ([C] traced int32 -> [C] int32, each >= 1)."""
        if self._ticks_const:
            return jnp.full((self.C,), self._tick0, jnp.int32)
        keys = jax.vmap(jax.random.fold_in)(self._upd_client_keys, i)
        return self._draw_ticks(keys)

    def broadcast_ticks(self, k):
        """Per-client arrival-tick offsets of broadcast ``k`` (scalar
        traced int32 -> [C] int32)."""
        if self._ticks_const:
            return jnp.full((self.C,), self._tick0, jnp.int32)
        bk = jax.random.fold_in(self._bc_base, k)
        keys = jax.vmap(jax.random.fold_in,
                        in_axes=(None, 0))(bk, self._cidx)
        return self._draw_ticks(keys)

    # -- host-side wrappers (host-loop cohort engine) ----------------------
    def host_update_ticks(self, i: np.ndarray) -> np.ndarray:
        if self._ticks_const:
            return np.full(self.C, self._tick0, np.int64)
        return np.asarray(self._host_upd(jnp.asarray(i, jnp.int32)),
                          np.int64)

    def host_broadcast_ticks(self, k: int) -> np.ndarray:
        if self._ticks_const:
            return np.full(self.C, self._tick0, np.int64)
        return np.asarray(self._host_bc(jnp.int32(k)), np.int64)

    def host_avail(self, t: int) -> Optional[np.ndarray]:
        if self._host_avail is None:
            return None
        return np.asarray(self._host_avail(jnp.int32(t)))

    # -- continuous-seconds draws (event simulator) ------------------------
    def _lat_s(self, key) -> Any:
        u = jax.random.uniform(key, (2,))
        return self._values_s[alias_sample(u, self._prob, self._alias)]

    def update_latency_s(self, c: int, i: int) -> float:
        """Latency (virtual seconds) of client c's round-i update — same
        bin the cohort engines quantize for this message."""
        if self.K == 1:
            return float(self._values_s[0])
        if not hasattr(self, "_upd_s_jit"):
            self._upd_s_jit = jax.jit(lambda c, i: self._lat_s(
                jax.random.fold_in(
                    jax.random.fold_in(self._upd_base, c), i)))
        return float(self._upd_s_jit(jnp.int32(c), jnp.int32(i)))

    def broadcast_latencies_s(self, k: int) -> np.ndarray:
        """All C clients' latency seconds for broadcast ``k`` in ONE
        vectorized draw — same per-(k, c) keys and uniforms as the
        cohort engines' ``broadcast_ticks``, so every engine puts the
        message in the same bin."""
        if self.K == 1:
            return np.full(self.C, float(self._values_s[0]))
        if not hasattr(self, "_bc_vec_jit"):
            def draw(k):
                bk = jax.random.fold_in(self._bc_base, k)
                keys = jax.vmap(jax.random.fold_in,
                                in_axes=(None, 0))(bk, self._cidx)
                return self._values_s[alias_sample(
                    key_uniforms(keys), self._prob, self._alias)]
            self._bc_vec_jit = jax.jit(draw)
        return np.asarray(self._bc_vec_jit(jnp.int32(k)), np.float64)


# -- plan cache: plans are immutable, sampler jits are reused across
#    engine instances (benchmarks build fresh simulators per repetition)
_PLAN_CACHE: Dict[Any, ScenarioPlan] = {}
_PLAN_CACHE_MAX = 32


def scenario_plan(scenario: Scenario, *, C: int, seed: int,
                  dt: Optional[float] = None) -> ScenarioPlan:
    key = (scenario, C, seed, dt)
    plan = _PLAN_CACHE.pop(key, None)
    if plan is None:
        plan = ScenarioPlan(scenario, C=C, seed=seed, dt=dt)
    _PLAN_CACHE[key] = plan                      # pop+reinsert: LRU order
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
    return plan


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], Scenario]] = {}


def register_scenario(name: str):
    """Decorator: register a zero-arg Scenario builder under ``name``."""
    def deco(fn: Callable[[], Scenario]):
        _REGISTRY[name] = fn
        return fn
    return deco


def scenario_names():
    return sorted(_REGISTRY)


def get_scenario(spec) -> Scenario:
    """Resolve a scenario argument: a ``Scenario`` passes through, a
    string looks up a registered preset."""
    if isinstance(spec, Scenario):
        return spec
    if isinstance(spec, str):
        if spec not in _REGISTRY:
            raise KeyError(f"unknown scenario {spec!r} "
                           f"(have {scenario_names()})")
        return _REGISTRY[spec]()
    raise TypeError(f"scenario must be a Scenario or preset name, "
                    f"got {type(spec).__name__}")


@register_scenario("uniform")
def _uniform() -> Scenario:
    """The legacy default network: latency U(0.05, 0.1) virtual seconds,
    full availability, caller-supplied speeds."""
    return Scenario("uniform", LatencyTable.from_uniform(0.05, 0.1, 8))


@register_scenario("mobile_diurnal")
def _mobile_diurnal() -> Scenario:
    """Phone-fleet shape: lognormal latency (wifi body, cellular tail),
    diurnal charging/idle windows with per-client phase, bimodal
    fast/slow device split."""
    return Scenario(
        "mobile_diurnal",
        LatencyTable.from_lognormal(median=0.3, sigma=0.8, n_bins=12),
        Diurnal(period_s=512.0, on_frac=0.75),
        SpeedModel(kind="bimodal", slow=0.3, slow_frac=0.3))


@register_scenario("iot_straggler")
def _iot_straggler() -> Scenario:
    """Sensor-fleet shape: Pareto-tail latency (lossy links, retries),
    epoch churn (duty-cycled radios), Zipf long-tail compute speeds."""
    return Scenario(
        "iot_straggler",
        LatencyTable.from_pareto(scale=0.1, alpha=1.2, n_bins=12,
                                 q_hi=0.99),
        Churn(p_available=0.9, epoch_s=64.0),
        SpeedModel(kind="zipf", alpha=0.5))


def scenario_from_trace(path: str, *, name: Optional[str] = None,
                        availability=None,
                        speed_model: Optional[SpeedModel] = None,
                        n_bins: int = 16) -> Scenario:
    """Build a scenario whose latency table is fit to a measured trace
    (JSON/CSV of per-message seconds, see ``LatencyTable.from_trace``)."""
    return Scenario(name or f"trace:{path}",
                    LatencyTable.from_trace(path, n_bins=n_bins),
                    availability if availability is not None else AlwaysOn(),
                    speed_model)


def legacy_latency_scenario(latency) -> Scenario:
    """Adapt the device engine's pre-scenario ``latency`` spec: a float
    is a constant virtual-second latency, an (lo, hi) pair is uniform;
    ``None`` is the legacy default network."""
    if callable(latency):
        raise TypeError(
            "the jitted engines take a latency *scenario* — a Scenario, "
            "a preset name, a float (virtual seconds) or an (lo, hi) "
            "uniform range — not a host callable; a Python latency_fn "
            "cannot run inside the jitted tick loop (use engine='cohort' "
            "with latency_fn=... for host-callable latency)")
    if latency is None:
        return get_scenario("uniform")
    if isinstance(latency, (int, float)):
        return Scenario(f"const:{latency}",
                        LatencyTable.constant(float(latency)))
    lo, hi = (float(latency[0]), float(latency[1]))
    if lo == hi:
        return Scenario(f"const:{lo}", LatencyTable.constant(lo))
    return Scenario(f"uniform:{lo},{hi}",
                    LatencyTable.from_uniform(lo, hi, 8))
