from repro.checkpoint.io import (load_fl_state, load_pytree, save_fl_state,
                                 save_pytree)

__all__ = ["load_fl_state", "load_pytree", "save_fl_state", "save_pytree"]
