"""Pytree checkpointing (npz container + structure manifest).

FL-aware: ``save_fl_state`` persists the global model, server round
counter, per-client progress, and RNG so an interrupted run resumes
mid-protocol (the paper's server/clients are long-running processes).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # npz cannot round-trip ml_dtypes (bf16 etc.): widen to f32
            # (lossless for bf16); the template dtype restores it on load
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_pytree(path: str, tree, *, metadata: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    manifest = {
        "keys": sorted(flat),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_pytree(path: str, template) -> Any:
    """Restore into the template's structure (keys must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_k, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype)
                      if hasattr(leaf, "dtype") else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_fl_state(directory: str, *, global_model, server_k: int,
                  client_states: Optional[Dict[int, Dict]] = None,
                  step_metadata: Optional[Dict] = None) -> None:
    os.makedirs(directory, exist_ok=True)
    save_pytree(os.path.join(directory, "global_model.npz"), global_model,
                metadata={"server_k": server_k, **(step_metadata or {})})
    if client_states:
        summary = {str(c): {k: v for k, v in st.items()
                            if isinstance(v, (int, float, str))}
                   for c, st in client_states.items()}
        with open(os.path.join(directory, "clients.json"), "w") as f:
            json.dump(summary, f, indent=1)


def load_fl_state(directory: str, template) -> Tuple[Any, int]:
    path = os.path.join(directory, "global_model.npz")
    model = load_pytree(path, template)
    with open(path + ".json") as f:
        manifest = json.load(f)
    return model, int(manifest["metadata"].get("server_k", 0))
