from repro.kernels.cohort_dp.kernel import (cohort_clip_noise_kernel,
                                            cohort_clip_noise_prng_kernel)
from repro.kernels.cohort_dp.ops import cohort_clip_noise
from repro.kernels.cohort_dp.ref import cohort_clip_noise_ref

__all__ = ["cohort_clip_noise_kernel", "cohort_clip_noise_prng_kernel",
           "cohort_clip_noise", "cohort_clip_noise_ref"]
