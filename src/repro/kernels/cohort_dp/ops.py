"""Jitted wrapper for the cohort clip+noise kernel, with padding.

``cohort_clip_noise`` pads the client axis to the f32 sublane multiple and
the model axis to the lane-block multiple, generates the Gaussian noise
(operand path) or derives an in-kernel PRNG seed (TPU path) from a jax
key, and unpads.  Padded rows carry mask 0 / weight 0, so they pass
through as zeros and contribute nothing to the aggregate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cohort_dp.kernel import (cohort_clip_noise_kernel,
                                            cohort_clip_noise_prng_kernel)
from repro.kernels.cohort_dp.ref import cohort_clip_noise_ref


@functools.partial(jax.jit, static_argnames=("clip", "noise_scale",
                                             "d_block", "use_kernel",
                                             "interpret", "in_kernel_rng"))
def cohort_clip_noise(u, key, weights, mask, *, clip: float = 0.0,
                      noise_scale: float = 0.0, d_block: int = 128,
                      use_kernel: bool = True, interpret=None,
                      in_kernel_rng: bool = False):
    """u: (C, D) round updates -> (noised rows (C, D), weighted agg (D,)).

    clip <= 0 disables the per-row norm clip (example-granularity DP clips
    inside the iteration loop instead); noise_scale is the std-dev
    multiplier on the standard-normal draw (protocol: dp_clip * dp_sigma).
    With ``in_kernel_rng`` the noise is drawn inside the kernel (TPU only,
    distributionally equivalent but not bit-matching the operand path).
    ``interpret=None`` infers interpret mode from ``jax.default_backend()``
    — interpret on CPU (byte-identical to the historical default there),
    the compiled kernel on a real TPU/GPU.
    """
    C, D = u.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if interpret and not in_kernel_rng:
        # CPU/interpret path has no 128-lane constraint: shrink the tile
        # to the model dim's power-of-two so a small D (e.g. the paper's
        # logreg, D=33) is not padded 4x.  The engines call this inside
        # their jitted tick, so the saving is per completion tick.
        p = 8
        while p < D:
            p <<= 1
        d_block = min(d_block, p)
    u = u.astype(jnp.float32)
    mask_f = mask.astype(jnp.float32)
    wgt = weights.astype(jnp.float32)
    draw_operand_noise = noise_scale > 0.0 and not (use_kernel
                                                    and in_kernel_rng)
    noise = (jax.random.normal(key, (C, D), jnp.float32)
             if draw_operand_noise else jnp.zeros((C, D), jnp.float32))
    if not use_kernel:
        return cohort_clip_noise_ref(u, noise, wgt, mask_f, clip=clip,
                                     noise_scale=noise_scale)

    pad_c = (-C) % 8
    pad_d = (-D) % d_block
    if pad_c or pad_d:
        u = jnp.pad(u, ((0, pad_c), (0, pad_d)))
        noise = jnp.pad(noise, ((0, pad_c), (0, pad_d)))
        mask_f = jnp.pad(mask_f, (0, pad_c))
        wgt = jnp.pad(wgt, (0, pad_c))
    if in_kernel_rng:
        seed = jax.random.randint(key, (1,), 0, jnp.iinfo(jnp.int32).max,
                                  jnp.int32)
        out, agg = cohort_clip_noise_prng_kernel(
            u, seed, wgt, mask_f, clip=clip, noise_scale=noise_scale,
            d_block=d_block)
    else:
        out, agg = cohort_clip_noise_kernel(
            u, noise, wgt, mask_f, clip=clip, noise_scale=noise_scale,
            d_block=d_block, interpret=interpret)
    return out[:C, :D], agg[:D]
