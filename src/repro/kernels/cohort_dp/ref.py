"""Pure-jnp oracle for the cohort clip+noise+accumulate kernel."""
from __future__ import annotations

import jax.numpy as jnp


def cohort_clip_noise_ref(u, noise, weights, mask, *, clip: float,
                          noise_scale: float):
    """Batched round-completion DP over a client cohort.

    u:       (C, D) per-client round updates (flattened model dim)
    noise:   (C, D) standard-normal draws
    weights: (C,)   per-client aggregation weight (eta_i * send mask)
    mask:    (C,)   1.0 for clients finishing a round, 0.0 pass-through

    Returns (out, agg):
      out[c] = u[c] * min(1, clip/||u[c]||) + noise_scale * noise[c]
               for masked rows (clip <= 0 disables the row clip);
               pass-through rows return u[c] unchanged.
      agg[d] = sum_c weights[c] * out[c, d]
    """
    u = u.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    if clip > 0.0:
        norms = jnp.sqrt(jnp.sum(u * u, axis=1))
        scale = 1.0 / jnp.maximum(1.0, norms / clip)
    else:
        scale = jnp.ones_like(mask)
    scale = 1.0 + mask * (scale - 1.0)          # masked-out rows: scale 1
    out = u * scale[:, None]
    if noise_scale > 0.0:
        out = out + (noise_scale * mask)[:, None] * noise.astype(jnp.float32)
    agg = jnp.sum(out * weights.astype(jnp.float32)[:, None], axis=0)
    return out, agg
