"""Fused per-client clip → noise → accumulate Pallas kernel (TPU target).

The cohort engine's round-completion hot spot (Algorithm 1 lines 17/23-24
lifted to a *batched* client population): given the round updates of a
cohort U (C, D) — C clients, D the flattened model dimension — produce

    out[c] = U[c] * min(1, clip / ||U[c]||_2) + noise_scale * N(0, 1)
    agg[d] = sum_c weight[c] * out[c, d]

for the rows selected by ``mask`` (non-finishing clients pass through
unchanged).  ``weight`` folds the server round step size eta(i_c) into the
reduction, so ``agg`` is exactly the vector the batched server subtracts
from the global model for one arrival bucket — the XLA baseline would
materialize the scaled+noised (C, D) copy and reduce it separately.

Layout follows ``kernels/dp_clip``: a sequential-grid pass accumulates
per-row squared norms into a (C,) accumulator that lives in the output ref
across grid steps, then a tiled pass scales rows, adds noise, and reduces.
Two noise paths:
  * operand noise (CPU/interpret-safe): standard normals are streamed in
    as a (C, D) input and the kernel fuses clip+add+reduce;
  * in-kernel PRNG (TPU only): ``pltpu.prng_random_bits`` + Box–Muller
    per D-tile, so the noise block never touches HBM.  The TPU PRNG
    primitives have no CPU lowering, hence no interpret mode for it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sqsum_kernel(u_ref, out_ref):
    di = pl.program_id(0)

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u = u_ref[...].astype(jnp.float32)              # (C, d_block)
    out_ref[...] += jnp.sum(u * u, axis=1)


def _scale_noise(u, sq, noise, mask, wgt, *, clip, noise_scale):
    """Shared tile math for both noise paths."""
    if clip > 0.0:
        norms = jnp.sqrt(sq)                        # (C,)
        scale = 1.0 / jnp.maximum(1.0, norms / clip)
    else:
        scale = jnp.ones_like(mask)
    scale = 1.0 + mask * (scale - 1.0)              # pass-through rows
    out = u * scale[:, None]
    if noise_scale > 0.0:
        out = out + (noise_scale * mask)[:, None] * noise
    return out, jnp.sum(out * wgt[:, None], axis=0)


def _clip_noise_kernel(u_ref, sq_ref, noise_ref, mask_ref, wgt_ref,
                       out_u_ref, out_agg_ref, *, clip: float,
                       noise_scale: float):
    out, agg = _scale_noise(
        u_ref[...].astype(jnp.float32), sq_ref[...], noise_ref[...],
        mask_ref[...], wgt_ref[...], clip=clip, noise_scale=noise_scale)
    out_u_ref[...] = out.astype(out_u_ref.dtype)
    out_agg_ref[...] = agg


def _clip_noise_prng_kernel(seed_ref, u_ref, sq_ref, mask_ref, wgt_ref,
                            out_u_ref, out_agg_ref, *, clip: float,
                            noise_scale: float):
    # Per-tile stream: each grid step reseeds so tiles draw independently.
    pltpu.prng_seed(seed_ref[0] + pl.program_id(0))
    shape = u_ref.shape
    b1 = pltpu.prng_random_bits(shape)
    b2 = pltpu.prng_random_bits(shape)
    # Box–Muller from two uniforms built off the top 24 bits.
    u1 = (b1 >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) + 2.0 ** -25
    u2 = (b2 >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    normal = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    out, agg = _scale_noise(
        u_ref[...].astype(jnp.float32), sq_ref[...], normal,
        mask_ref[...], wgt_ref[...], clip=clip, noise_scale=noise_scale)
    out_u_ref[...] = out.astype(out_u_ref.dtype)
    out_agg_ref[...] = agg


def _row_sqsum(u, *, d_block: int, interpret: bool):
    C, D = u.shape
    nd = D // d_block
    return pl.pallas_call(
        _sqsum_kernel,
        grid=(nd,),
        in_specs=[pl.BlockSpec((C, d_block), lambda d: (0, d))],
        out_specs=pl.BlockSpec((C,), lambda d: (0,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(u)


def cohort_clip_noise_kernel(u, noise, weights, mask, *, clip: float,
                             noise_scale: float, d_block: int = 128,
                             interpret: bool = True):
    """Operand-noise path.  u, noise: (C, D); D % d_block == 0, C % 8 == 0.

    Returns (out (C, D), agg (D,)).
    """
    C, D = u.shape
    assert D % d_block == 0, (D, d_block)
    nd = D // d_block
    sq = (_row_sqsum(u, d_block=d_block, interpret=interpret)
          if clip > 0.0 else jnp.zeros((C,), jnp.float32))

    return pl.pallas_call(
        functools.partial(_clip_noise_kernel, clip=clip,
                          noise_scale=noise_scale),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((C,), lambda d: (0,)),
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((C,), lambda d: (0,)),
            pl.BlockSpec((C,), lambda d: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((d_block,), lambda d: (d,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, D), jnp.float32),
            jax.ShapeDtypeStruct((D,), jnp.float32),
        ],
        interpret=interpret,
    )(u, sq, noise, mask, weights)


def cohort_clip_noise_prng_kernel(u, seed, weights, mask, *, clip: float,
                                  noise_scale: float, d_block: int = 128):
    """In-kernel-PRNG path (TPU only — no interpret/CPU lowering).

    seed: (1,) int32.  Returns (out (C, D), agg (D,)).
    """
    C, D = u.shape
    assert D % d_block == 0, (D, d_block)
    nd = D // d_block
    sq = (_row_sqsum(u, d_block=d_block, interpret=False)
          if clip > 0.0 else jnp.zeros((C,), jnp.float32))

    return pl.pallas_call(
        functools.partial(_clip_noise_prng_kernel, clip=clip,
                          noise_scale=noise_scale),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((C,), lambda d: (0,)),
            pl.BlockSpec((C,), lambda d: (0,)),
            pl.BlockSpec((C,), lambda d: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((d_block,), lambda d: (d,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, D), jnp.float32),
            jax.ShapeDtypeStruct((D,), jnp.float32),
        ],
        interpret=False,
    )(seed, u, sq, mask, weights)
