"""Pure-jnp references for the fused tick kernels.

Every expression here mirrors, token for token, the float arithmetic
the device engine historically inlined in its tick body — same ops,
same reduction order, same ``jnp.where`` guards.  The parity contract
(host engine vs device engine bitwise on the operand-noise path) rests
on these references being the CPU dispatch target, so DO NOT "clean
up" the arithmetic: a reassociated sum or an unguarded add on an empty
bucket (``-0.0`` hazard) breaks byte-identical golden fixtures.
"""
import jax.numpy as jnp


def bucket_apply_ref(v, rows, dec, flag):
    """Apply decayed bucket rows to the server vector.

    v      [D]    server model vector
    rows   [A, D] contribution rows (arrival bucket / flush buffer /
                  per-stratum kvec rows)
    dec    [A]    per-row decay weights (ones for the paper strategy)
    flag   []     bool: whether anything arrived / flushed this tick

    A == 1 is the paper / FedBuff shape: the contribution is the single
    row scaled by its weight.  ``rows[0] * dec[0]`` (with dec == 1.0 a
    bitwise identity) matches the engines' historical ``v - arr_due``;
    a ``jnp.sum`` over the size-1 axis would compute ``0.0 + x`` and
    flip a ``-0.0`` row.  A > 1 is the stratified shape and matches
    ``_make_strat_apply`` / the device tick verbatim.
    """
    if rows.shape[0] == 1:
        contrib = rows[0] * dec[0]
    else:
        contrib = jnp.sum(rows * dec[:, None], axis=0)
    return jnp.where(flag, v - contrib, v)


def tick_deliver_ref(w, U, bc_v, best, take, eta):
    """Deliver the freshest eligible broadcast to taking clients.

    w     [C, D] client weights
    U     [C, D] client round updates
    bc_v  [B, D] broadcast ring vectors
    best  [C]    int32 ring index of the freshest eligible broadcast
    take  [C]    bool per-client take mask
    eta   [C]    per-client round stepsize

    Matches the device tick's ``bc_v[best] - eta[:, None] * st.U``
    receive expression (and the host engine's ``_isr_receive``).
    """
    return jnp.where(take[:, None], bc_v[best] - eta[:, None] * U, w)


def tick_scatter_ref(sent, w, U, upd, wgt, any_g, done, eta, *, dp_on):
    """Scatter finished rounds into the update ring; settle w and U.

    sent  [C, D] per-client sent update (DP-noised when dp_on)
    w     [C, D] client weights
    U     [C, D] raw (pre-noise) client round updates
    upd   [G, D] update-ring rows (flattened [L*R, D] when stratified)
    wgt   [G, C] per-row scatter weights: ``eta * in_g`` per client
    any_g [G]    bool/int: whether row g receives any client this tick
    done  [C]    bool finished-round mask
    eta   [C]    per-client round stepsize
    dp_on        static: DP w-consistency update enabled

    Per ring row: the full-client-axis weighted sum in the engines'
    historical reduction order, added under the ``jnp.any`` guard that
    keeps untouched rows byte-identical (no ``-0.0`` flips from adding
    a zero vector).  The U reset (historically the last statement of
    ``do_complete``) folds in here: the far tier reads ``sent``, not U.
    """
    out = upd
    for g in range(upd.shape[0]):
        vec = jnp.sum(sent * wgt[g][:, None], axis=0)
        out = out.at[g].set(jnp.where(any_g[g] != 0, out[g] + vec,
                                      out[g]))
    if dp_on:
        w_new = jnp.where(done[:, None],
                          w + eta[:, None] * (sent - U), w)
    else:
        w_new = w
    U_new = jnp.where(done[:, None], 0.0, sent)
    return w_new, U_new, out
