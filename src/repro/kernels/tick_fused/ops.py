"""Jitted dispatch wrappers for the fused tick kernels, with padding.

Backend-aware dispatch: ``use_kernel=None`` (the engine default) means
"kernel on TPU/GPU, pure-jnp reference on CPU", and ``interpret=None``
means "infer interpret mode from ``jax.default_backend()``".  On CPU
the reference path therefore traces the exact expressions the device
engine historically inlined — the golden fixtures and host-vs-device
parity stay byte-identical by construction — while an accelerator
backend runs the fused kernels unpadded-equivalently.

Kernel-path padding: C to the f32 sublane multiple (8), D to the lane
block.  Padded clients carry weight/mask/take 0 and padded model lanes
are zero, so they are sliced off unchanged.  (Known accepted hazard:
zero-padded client rows append ``+0.0`` terms to the scatter sums,
which could flip an exactly ``-0.0`` total; the CPU parity path is
unpadded and numpy comparisons treat the two zeros as equal.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tick_fused.kernel import (bucket_apply_kernel,
                                             tick_deliver_kernel,
                                             tick_scatter_kernel)
from repro.kernels.tick_fused.ref import (bucket_apply_ref,
                                          tick_deliver_ref,
                                          tick_scatter_ref)


def _resolve(use_kernel, interpret):
    if use_kernel is None:
        use_kernel = jax.default_backend() != "cpu"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return use_kernel, interpret


def _shrink(d_block: int, D: int) -> int:
    # interpret path has no 128-lane constraint: shrink the tile to the
    # model dim's power-of-two (min 8) so a small D is not padded
    # many-fold
    return min(d_block, max(8, 1 << (D - 1).bit_length()))


@functools.partial(jax.jit, static_argnames=("d_block", "use_kernel",
                                             "interpret"))
def bucket_apply(v, rows, dec, flag, *, d_block: int = 512,
                 use_kernel=None, interpret=None):
    """v: (D,), rows: (A, D), dec: (A,), flag: scalar bool -> (D,)."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return bucket_apply_ref(v, rows, dec, flag)
    D = v.shape[0]
    if interpret:
        d_block = _shrink(d_block, D)
    v = v.astype(jnp.float32)
    rows = rows.astype(jnp.float32)
    pad_d = (-D) % d_block
    if pad_d:
        v = jnp.pad(v, (0, pad_d))
        rows = jnp.pad(rows, ((0, 0), (0, pad_d)))
    flag_i = jnp.asarray(flag, jnp.int32).reshape((1,))
    out = bucket_apply_kernel(v, rows, dec.astype(jnp.float32), flag_i,
                              d_block=d_block, interpret=interpret)
    return out[:D]


@functools.partial(jax.jit, static_argnames=("d_block", "use_kernel",
                                             "interpret"))
def tick_deliver(w, U, bc_v, best, take, eta, *, d_block: int = 512,
                 use_kernel=None, interpret=None):
    """w, U: (C, D); bc_v: (B, D); best: (C,) int; take: (C,) bool;
    eta: (C,) -> updated weights (C, D)."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return tick_deliver_ref(w, U, bc_v, best, take, eta)
    C, D = w.shape
    if interpret:
        d_block = _shrink(d_block, D)
    w = w.astype(jnp.float32)
    U = U.astype(jnp.float32)
    bc_v = bc_v.astype(jnp.float32)
    best_i = best.astype(jnp.int32)
    take_i = take.astype(jnp.int32)
    eta = eta.astype(jnp.float32)
    pad_c = (-C) % 8
    pad_d = (-D) % d_block
    if pad_c or pad_d:
        w = jnp.pad(w, ((0, pad_c), (0, pad_d)))
        U = jnp.pad(U, ((0, pad_c), (0, pad_d)))
        bc_v = jnp.pad(bc_v, ((0, 0), (0, pad_d)))
        best_i = jnp.pad(best_i, (0, pad_c))
        take_i = jnp.pad(take_i, (0, pad_c))
        eta = jnp.pad(eta, (0, pad_c))
    out = tick_deliver_kernel(w, U, bc_v, best_i, take_i, eta,
                              d_block=d_block, interpret=interpret)
    return out[:C, :D]


@functools.partial(jax.jit, static_argnames=("dp_on", "d_block",
                                             "use_kernel", "interpret"))
def tick_scatter(sent, w, U, upd, wgt, any_g, done, eta, *, dp_on: bool,
                 d_block: int = 512, use_kernel=None, interpret=None):
    """sent, w, U: (C, D); upd: (G, D); wgt: (G, C); any_g: (G,) bool;
    done: (C,) bool; eta: (C,)
    -> (w_new (C, D), U_new (C, D), upd_new (G, D))."""
    use_kernel, interpret = _resolve(use_kernel, interpret)
    if not use_kernel:
        return tick_scatter_ref(sent, w, U, upd, wgt, any_g, done, eta,
                                dp_on=dp_on)
    C, D = sent.shape
    if interpret:
        d_block = _shrink(d_block, D)
    sent = sent.astype(jnp.float32)
    w = w.astype(jnp.float32)
    U = U.astype(jnp.float32)
    upd = upd.astype(jnp.float32)
    wgt = wgt.astype(jnp.float32)
    any_i = any_g.astype(jnp.int32)
    done_i = done.astype(jnp.int32)
    eta = eta.astype(jnp.float32)
    pad_c = (-C) % 8
    pad_d = (-D) % d_block
    if pad_c or pad_d:
        sent = jnp.pad(sent, ((0, pad_c), (0, pad_d)))
        w = jnp.pad(w, ((0, pad_c), (0, pad_d)))
        U = jnp.pad(U, ((0, pad_c), (0, pad_d)))
        upd = jnp.pad(upd, ((0, 0), (0, pad_d)))
        wgt = jnp.pad(wgt, ((0, 0), (0, pad_c)))
        done_i = jnp.pad(done_i, (0, pad_c))
        eta = jnp.pad(eta, (0, pad_c))
    w_new, u_new, upd_new = tick_scatter_kernel(
        sent, w, U, upd, wgt, any_i, done_i, eta, dp_on=dp_on,
        d_block=d_block, interpret=interpret)
    return w_new[:C, :D], u_new[:C, :D], upd_new[:, :D]
