"""Fused device-tick kernels: delivery gather, bucket apply, ring scatter.

Pallas kernels over the [C, D] client block with pure-jnp references
(`ref.py`) that mirror the device engine's historical expressions
bitwise.  Dispatch (`ops.py`) routes to the reference on CPU and to the
kernels on TPU/GPU, so the host-vs-device parity contract is preserved
by construction on the backend the goldens pin.
"""
from repro.kernels.tick_fused.ops import (bucket_apply, tick_deliver,
                                          tick_scatter)
from repro.kernels.tick_fused.ref import (bucket_apply_ref,
                                          tick_deliver_ref,
                                          tick_scatter_ref)

__all__ = [
    "bucket_apply", "tick_deliver", "tick_scatter",
    "bucket_apply_ref", "tick_deliver_ref", "tick_scatter_ref",
]
