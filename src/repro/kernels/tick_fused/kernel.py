"""Fused device-tick Pallas kernels (TPU target).

Three kernels over the [C, D] client block, one per tick pass the
device engine historically ran as separate XLA ops.  The apply →
cascade → deliver data dependency (the cascade writes the post-apply
server vector into the broadcast ring that delivery then gathers from)
forces the three-way split; within each kernel the gather, the bucket
reduction, and the ring scatter fuse with their masks and stepsize
scaling so the [C, D] traffic is a single HBM pass.

Grid: D tiles only (``grid=(nd,)``).  The client axis is deliberately
NOT tiled — every reduction over clients keeps the engines' historical
full-axis ``jnp.sum`` order, which the bitwise host-vs-device parity
contract pins.  Ring axes (B broadcast slots, G scatter rows) are
small powers of two and unroll as Python loops: the broadcast gather
is a select-accumulate (pure selection, no float sums, bitwise equal
to ``bc_v[best]``) and each scatter row is a static store.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bucket_apply_kernel(v_ref, rows_ref, dec_ref, flag_ref, out_ref, *,
                         single: bool):
    v = v_ref[...]
    rows = rows_ref[...]
    dec = dec_ref[...]
    if single:
        # size-1 bucket: scale-by-weight, not a sum — jnp.sum over a
        # size-1 axis computes 0.0 + x and flips a -0.0 row
        contrib = rows[0] * dec[0]
    else:
        contrib = jnp.sum(rows * dec[:, None], axis=0)
    out_ref[...] = jnp.where(flag_ref[0] != 0, v - contrib, v)


def _tick_deliver_kernel(w_ref, u_ref, bc_ref, best_ref, take_ref,
                         eta_ref, out_ref, *, B: int):
    w = w_ref[...]
    bc = bc_ref[...]
    best = best_ref[...]
    gathered = jnp.zeros_like(w)
    for b in range(B):
        gathered = jnp.where((best == b)[:, None], bc[b][None, :],
                             gathered)
    take = take_ref[...] != 0
    out_ref[...] = jnp.where(take[:, None],
                             gathered - eta_ref[...][:, None] * u_ref[...],
                             w)


def _tick_scatter_kernel(sent_ref, w_ref, u_ref, upd_ref, wgt_ref,
                         any_ref, done_ref, eta_ref, w_out, u_out,
                         upd_out, *, G: int, dp_on: bool):
    sent = sent_ref[...]
    wgt = wgt_ref[...]
    any_g = any_ref[...]
    upd = upd_ref[...]
    for g in range(G):
        vec = jnp.sum(sent * wgt[g][:, None], axis=0)
        upd_out[g, :] = jnp.where(any_g[g] != 0, upd[g] + vec, upd[g])
    done = done_ref[...] != 0
    if dp_on:
        w_out[...] = jnp.where(
            done[:, None],
            w_ref[...] + eta_ref[...][:, None] * (sent - u_ref[...]),
            w_ref[...])
    else:
        w_out[...] = w_ref[...]
    u_out[...] = jnp.where(done[:, None], jnp.zeros_like(sent), sent)


def bucket_apply_kernel(v, rows, dec, flag, *, d_block: int = 512,
                        interpret: bool = True):
    """v: (D,), rows: (A, D), dec: (A,), flag: (1,) int32; D % d_block == 0.

    Returns the updated server vector (D,).
    """
    A, D = rows.shape
    assert D % d_block == 0, (D, d_block)
    nd = D // d_block
    return pl.pallas_call(
        functools.partial(_bucket_apply_kernel, single=(A == 1)),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((d_block,), lambda d: (d,)),
            pl.BlockSpec((A, d_block), lambda d: (0, d)),
            pl.BlockSpec((A,), lambda d: (0,)),
            pl.BlockSpec((1,), lambda d: (0,)),
        ],
        out_specs=pl.BlockSpec((d_block,), lambda d: (d,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(v, rows, dec, flag)


def tick_deliver_kernel(w, U, bc_v, best, take, eta, *,
                        d_block: int = 512, interpret: bool = True):
    """w, U: (C, D); bc_v: (B, D); best, take: (C,) int32; eta: (C,).

    C % 8 == 0, D % d_block == 0.  Returns the updated weights (C, D).
    """
    C, D = w.shape
    B = bc_v.shape[0]
    assert D % d_block == 0, (D, d_block)
    nd = D // d_block
    return pl.pallas_call(
        functools.partial(_tick_deliver_kernel, B=B),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((B, d_block), lambda d: (0, d)),
            pl.BlockSpec((C,), lambda d: (0,)),
            pl.BlockSpec((C,), lambda d: (0,)),
            pl.BlockSpec((C,), lambda d: (0,)),
        ],
        out_specs=pl.BlockSpec((C, d_block), lambda d: (0, d)),
        out_shape=jax.ShapeDtypeStruct((C, D), jnp.float32),
        interpret=interpret,
    )(w, U, bc_v, best, take, eta)


def tick_scatter_kernel(sent, w, U, upd, wgt, any_g, done, eta, *,
                        dp_on: bool, d_block: int = 512,
                        interpret: bool = True):
    """sent, w, U: (C, D); upd: (G, D); wgt: (G, C); any_g: (G,) int32;
    done: (C,) int32; eta: (C,).  C % 8 == 0, D % d_block == 0.

    Returns (w_new (C, D), U_new (C, D), upd_new (G, D)).
    """
    C, D = sent.shape
    G = upd.shape[0]
    assert D % d_block == 0, (D, d_block)
    nd = D // d_block
    return pl.pallas_call(
        functools.partial(_tick_scatter_kernel, G=G, dp_on=dp_on),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((G, d_block), lambda d: (0, d)),
            pl.BlockSpec((G, C), lambda d: (0, 0)),
            pl.BlockSpec((G,), lambda d: (0,)),
            pl.BlockSpec((C,), lambda d: (0,)),
            pl.BlockSpec((C,), lambda d: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((C, d_block), lambda d: (0, d)),
            pl.BlockSpec((G, d_block), lambda d: (0, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, D), jnp.float32),
            jax.ShapeDtypeStruct((C, D), jnp.float32),
            jax.ShapeDtypeStruct((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(sent, w, U, upd, wgt, any_g, done, eta)
