# TPU Pallas kernels for the paper's compute hot spots.
# Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit
# wrapper w/ padding + ref fallback), ref.py (pure-jnp oracle).
from repro.kernels import (cohort_dp, dp_clip, flash_attention,  # noqa: F401
                           ssd_scan)
