"""Flash attention Pallas kernel (TPU target).

Design for the TPU memory hierarchy:
  * grid = (batch, q_heads, S // q_block); each program owns one q tile of
    shape (q_block, head_dim) resident in VMEM (q_block = 128 aligns the
    MXU's 128x128 systolic array; head_dim is a multiple of 64/128 for
    every assigned arch).
  * K/V for the program's kv-head are streamed through VMEM in kv_block
    chunks with an online-softmax running (max, sum, acc) carry — the
    S x S score matrix never materializes (the XLA baseline's dominant
    memory term, see EXPERIMENTS.md §Perf).
  * causal masking, sliding windows, and gemma2/grok logit soft-capping
    are fused into the score tile; fully-masked kv blocks are SKIPPED
    (the flop saving the dense baseline cannot express).
  * GQA: kv-head index = q_head * n_kv // n_q resolved in the BlockSpec
    index maps, so no KV replication in HBM.

Numerics follow Rabe-Staats/FlashAttention: f32 accumulators in VMEM,
inputs may be bf16.  Validated in interpret mode against ``ref.py``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, seq_len: int,
               causal: bool, window: Optional[int],
               softcap: Optional[float], q_block: int):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)               # (q_block, hd)
    scale = 1.0 / math.sqrt(q.shape[-1])
    q = q * scale

    nkv = seq_len // kv_block
    q_start = qi * q_block

    # kv blocks beyond the causal frontier contribute nothing; with a
    # window, blocks older than (q_start - window - q_block) are dead too.
    if causal:
        hi = jax.lax.div(q_start + q_block - 1, kv_block) + 1
    else:
        hi = nkv
    if window is not None:
        lo = jnp.maximum(0, jax.lax.div(q_start - window - kv_block + 1,
                                        kv_block))
    else:
        lo = 0

    acc0 = jnp.zeros((q_block, q.shape[-1]), jnp.float32)
    m0 = jnp.full((q_block,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_block,), jnp.float32)

    def body(ki, carry):
        acc, m, l = carry
        k = k_ref[pl.ds(ki * kv_block, kv_block), :].astype(jnp.float32)
        v = v_ref[pl.ds(ki * kv_block, kv_block), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qb, kvb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (q_block, kv_block), 0)
        cols = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        mask = jnp.ones((q_block, kv_block), bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (rows - cols < window)
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return acc_new, m_new, l_new

    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = o.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = True):
    """q: (B, S, H, hd); k/v: (B, S, KV, hd).  Returns (B, S, H, hd).

    S must be a multiple of q_block and kv_block (the ops wrapper pads).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    assert S % q_block == 0 and S % kv_block == 0, (S, q_block, kv_block)
    group = H // KV
    nq = S // q_block

    kernel = functools.partial(
        _fa_kernel, kv_block=kv_block, seq_len=S, causal=causal,
        window=window, softcap=softcap, q_block=q_block)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq),
        in_specs=[
            pl.BlockSpec((None, q_block, None, hd),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, S, None, hd),
                         lambda b, h, i: (b, 0, h // group, 0)),
            pl.BlockSpec((None, S, None, hd),
                         lambda b, h, i: (b, 0, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, None, hd),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
