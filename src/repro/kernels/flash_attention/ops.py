"""Jitted wrapper: pads to block multiples, dispatches kernel or ref.

On this CPU container the kernel runs in interpret mode (slow, exact);
production TPU runs compile the same pallas_call natively.  ``use_kernel``
False falls back to the oracle (what the XLA dry-run lowers).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "q_block", "kv_block", "use_kernel",
    "interpret"))
def attend(q, k, v, *, causal: bool = True, window: Optional[int] = None,
           softcap: Optional[float] = None, q_block: int = 128,
           kv_block: int = 128, use_kernel: bool = True,
           interpret: bool = True):
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    B, S, H, hd = q.shape
    blk = max(q_block, kv_block)
    pad = (-S) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, q_block=q_block,
                          kv_block=kv_block, interpret=interpret)
    return out[:, :S] if pad else out
