"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None):
    """q: (B,S,H,hd); k/v: (B,S,KV,hd) -> (B,S,H,hd).  Dense reference."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    qg = q.astype(jnp.float32).reshape(B, S, KV, group, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (cols <= rows)
    if window is not None:
        mask = mask & (rows - cols < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
