"""Pure-jnp oracle: the chunked SSD implementation in repro.models.ssm."""
from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, B, C, chunk: int = 128):
    y, _ = ssd_chunked(x, dt, A, B, C, chunk)
    return y


__all__ = ["ssd_ref", "ssd_chunked"]
