"""Mamba-2 SSD chunked-scan Pallas kernel (TPU target).

Grid = (batch, heads, S // chunk) with the chunk axis SEQUENTIAL: the
(state_n, head_p) recurrent state lives in a VMEM scratch ref that
persists across grid steps (TPU revisiting semantics), so the inter-chunk
recurrence never round-trips HBM.  Per program:

  * intra-chunk: build the (Q, Q) decay matrix L from the cumulative
    dt*A, compute Y_diag = (C Bᵀ ∘ L) (dt x) with two MXU matmuls
    (Q = 128 aligns the systolic array; n/p are 64/128-multiples),
  * inter-chunk: Y_off = C h_prev * exp(dA_cum); then update
    h <- h * exp(dA_sum) + (decay-weighted B)ᵀ (dt x).

All accumulation in f32.  Validated in interpret mode against the
pure-jnp oracle ``repro.models.ssm.ssd_chunked`` (re-exported in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)      # (Q,)
    A = a_ref[...].astype(jnp.float32)        # (1,) scalar per head
    B = b_ref[...].astype(jnp.float32)        # (Q, N)
    C = c_ref[...].astype(jnp.float32)        # (Q, N)
    Q = x.shape[0]

    dA = dt * A[0]                             # (Q,)
    dA_cum = jnp.cumsum(dA)                    # (Q,)

    # decay matrix L[i,j] = exp(dA_cum[i] - dA_cum[j]) for j <= i
    seg = dA_cum[:, None] - dA_cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    L = jnp.where(tril, jnp.exp(seg), 0.0)

    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (Q, Q)
    scores = CB * L * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())))  # (Q, P)

    # inter-chunk: read previous state, add off-diagonal contribution
    h_prev = h_ref[...].astype(jnp.float32)    # (N, P)
    y = y + jnp.exp(dA_cum)[:, None] * jax.lax.dot_general(
        C, h_prev, (((1,), (0,)), ((), ())))

    # state update: h = h * exp(dA_sum) + sum_j w_j B_j x_j^T
    w = jnp.exp(dA_cum[-1] - dA_cum) * dt      # (Q,)
    new_state = jax.lax.dot_general(B * w[:, None], x,
                                    (((0,), (0,)), ((), ())))  # (N, P)
    h_ref[...] = h_prev * jnp.exp(dA_cum[-1]) + new_state
    y_ref[...] = y.astype(y_ref.dtype)


def ssd_scan_kernel(x, dt, A, B, C, chunk: int = 128, *,
                    interpret: bool = True):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,n) -> y (b,s,h,p).

    s must be a multiple of chunk (ops wrapper pads).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, None, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((None, chunk, None),
                         lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((None, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, None, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
