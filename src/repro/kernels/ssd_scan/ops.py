"""Jitted wrapper for the SSD scan kernel (pads sequence; ref fallback)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel",
                                             "interpret"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, use_kernel: bool = True,
        interpret: bool = True):
    if not use_kernel:
        return ssd_ref(x, dt, A, B, C, chunk)
    b, s, h, p = x.shape
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_kernel(x, dt, A, B, C, chunk, interpret=interpret)
    return y[:, :s] if pad else y
