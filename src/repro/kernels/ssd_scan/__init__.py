from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_ref

__all__ = ["ssd_scan_kernel", "ssd", "ssd_chunked", "ssd_ref"]
