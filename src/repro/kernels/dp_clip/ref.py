"""Pure-jnp oracle for the DP clip-accumulate kernel."""
from __future__ import annotations

import jax.numpy as jnp


def clip_accumulate_ref(g, clip: float):
    """g: (N, D) -> (D,): sum_n g[n] * min(1, clip/||g[n]||)."""
    g = g.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(g * g, axis=1))
    scale = 1.0 / jnp.maximum(1.0, norms / clip)
    return jnp.sum(g * scale[:, None], axis=0)
