from repro.kernels.dp_clip.kernel import clip_accumulate_kernel
from repro.kernels.dp_clip.ops import clip_accumulate, clip_accumulate_tree
from repro.kernels.dp_clip.ref import clip_accumulate_ref

__all__ = ["clip_accumulate_kernel", "clip_accumulate",
           "clip_accumulate_tree", "clip_accumulate_ref"]
