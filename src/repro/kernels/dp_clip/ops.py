"""Jitted wrapper for the DP clip kernel, with pytree support.

``clip_accumulate_tree`` flattens a per-example gradient pytree into one
(N, D) matrix (padding D to the block multiple), runs the kernel, and
unflattens — the layout a real DP-SGD trainer feeds the TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip.kernel import clip_accumulate_kernel
from repro.kernels.dp_clip.ref import clip_accumulate_ref


@functools.partial(jax.jit, static_argnames=("clip", "d_block",
                                             "use_kernel", "interpret"))
def clip_accumulate(g, *, clip: float, d_block: int = 512,
                    use_kernel: bool = True, interpret: bool = True):
    if not use_kernel:
        return clip_accumulate_ref(g, clip)
    N, D = g.shape
    pad = (-D) % d_block
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    out = clip_accumulate_kernel(g, clip, d_block=d_block,
                                 interpret=interpret)
    return out[:D] if pad else out


def clip_accumulate_tree(grads, *, clip: float, **kw):
    """grads: pytree, every leaf (N, ...).  Returns clipped-sum pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    N = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(N, -1).astype(jnp.float32) for l in leaves], axis=1)
    out = clip_accumulate(flat, clip=clip, **kw)
    outs, off = [], 0
    for l in leaves:
        size = int(l.size // N)
        outs.append(out[off:off + size].reshape(l.shape[1:]))
        off += size
    return jax.tree_util.tree_unflatten(treedef, outs)
