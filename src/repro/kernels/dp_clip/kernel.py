"""Fused per-example clip-accumulate Pallas kernel (TPU target).

The DP-SGD hot spot (Algorithm 1 line 17-18): given per-example gradients
G (n_examples, D) — D is the flattened parameter dimension — compute

    out[d] = sum_n  G[n, d] * min(1, C / ||G[n]||_2)

Two fused passes, both tiled for VMEM:
  1. ``_sqsum_kernel``: grid (n_d_blocks,) sequential; each program loads a
     (N, d_block) tile (8x128-aligned lanes) and accumulates per-example
     squared sums into an (N,)-shaped f32 accumulator that lives in the
     output ref across grid steps (TPU sequential-grid revisiting).
  2. ``_scale_sum_kernel``: grid (n_d_blocks,); each program re-loads its
     tile, scales rows by min(1, C/norm) and reduces over examples.

The XLA baseline materializes the scaled copy of all per-example grads
(N x D); the kernel's working set is one tile, and the accumulate fuses
into the reduction — memory-bound win of ~N on the clip step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqsum_kernel(g_ref, out_ref):
    di = pl.program_id(0)

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)              # (N, d_block)
    out_ref[...] += jnp.sum(g * g, axis=1)


def _scale_sum_kernel(g_ref, sq_ref, out_ref, *, clip: float):
    g = g_ref[...].astype(jnp.float32)              # (N, d_block)
    norms = jnp.sqrt(sq_ref[...])                   # (N,)
    scale = 1.0 / jnp.maximum(1.0, norms / clip)
    out_ref[...] = jnp.sum(g * scale[:, None], axis=0).astype(out_ref.dtype)


def clip_accumulate_kernel(g, clip: float, *, d_block: int = 512,
                           interpret: bool = True):
    """g: (N, D) per-example grads -> (D,) clipped sum.  D % d_block == 0."""
    N, D = g.shape
    assert D % d_block == 0, (D, d_block)
    nd = D // d_block

    sq = pl.pallas_call(
        _sqsum_kernel,
        grid=(nd,),
        in_specs=[pl.BlockSpec((N, d_block), lambda d: (0, d))],
        out_specs=pl.BlockSpec((N,), lambda d: (0,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(g)

    return pl.pallas_call(
        functools.partial(_scale_sum_kernel, clip=clip),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((N, d_block), lambda d: (0, d)),
            pl.BlockSpec((N,), lambda d: (0,)),
        ],
        out_specs=pl.BlockSpec((d_block,), lambda d: (d,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(g, sq)
