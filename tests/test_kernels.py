"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dp_clip import (clip_accumulate, clip_accumulate_ref,
                                   clip_accumulate_tree)
from repro.kernels.flash_attention import attend, attention_ref
from repro.kernels.ssd_scan import ssd, ssd_ref


# --- flash attention -------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (2, 256, 4, 2, 64),
    (1, 128, 2, 1, 128),     # MQA
    (2, 384, 8, 8, 32),      # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, S, H, KV, hd, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(dtype)
    out = attend(q, k, v, q_block=128, kv_block=128)
    ref = attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 64))
    out = attend(q, k, v, window=window)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(2)
    q = 3.0 * jax.random.normal(key, (1, 128, 2, 64))
    k = 3.0 * jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 64))
    out = attend(q, k, v, softcap=30.0)
    ref = attention_ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_pads_odd_seq():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 200, 2, 64))   # not a block multiple
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 200, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 200, 2, 64))
    out = attend(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --- dp clip ----------------------------------------------------------------

@pytest.mark.parametrize("N,D,clip", [
    (8, 512, 0.5), (16, 1024, 1.0), (32, 2048, 0.1), (4, 300, 2.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dp_clip_sweep(N, D, clip, dtype):
    g = (jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 3.0) \
        .astype(dtype)
    out = clip_accumulate(g, clip=clip)
    ref = clip_accumulate_ref(g, clip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-3 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-3 if dtype == jnp.bfloat16 else 1e-6)


def test_dp_clip_tree_roundtrip():
    key = jax.random.PRNGKey(1)
    grads = {"w1": jax.random.normal(key, (8, 16, 16)),
             "b1": jax.random.normal(jax.random.fold_in(key, 1), (8, 16))}
    out = clip_accumulate_tree(grads, clip=0.7)
    # oracle via flattening
    flat = jnp.concatenate([grads["w1"].reshape(8, -1),
                            grads["b1"].reshape(8, -1)], axis=1)
    ref = clip_accumulate_ref(flat, 0.7)
    np.testing.assert_allclose(np.asarray(out["w1"]).ravel(),
                               np.asarray(ref[:256]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["b1"]).ravel(),
                               np.asarray(ref[256:]), rtol=1e-5)


# --- ssd scan ----------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 4, 32, 16, 64),
    (1, 128, 2, 64, 32, 128),
    (2, 192, 3, 32, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, s, h, p, n, chunk, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(0.1 * jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    out = ssd(x, dt, A, B, C, chunk=chunk)
    ref = ssd_ref(x, dt, A, B, C, chunk)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    rel = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32)))) / scale
    assert rel < (1e-5 if dtype == jnp.float32 else 3e-2)


def test_ssd_pads_odd_seq():
    key = jax.random.PRNGKey(1)
    b, s, h, p, n = 1, 100, 2, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(0.1 * jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    out = ssd(x, dt, A, B, C, chunk=64)
    ref = ssd_ref(x, dt, A, B, C, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
