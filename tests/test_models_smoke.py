"""Per-architecture smoke tests: reduced variants of each assigned family.

One forward/train step on CPU asserting output shapes + no NaNs, plus
decode-path consistency checks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.data import make_batch
from repro.models import (forward_prefill, init_cache, init_params,
                          serve_step, train_loss)
from repro.models import encdec, transformer


def _setup(arch, seq=64, batch=2):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch_np = make_batch(cfg, batch, seq, seed=0)
    batch_j = {k: jnp.asarray(v) for k, v in batch_np.items()}
    return cfg, params, batch_j


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg, params, batch = _setup(arch)
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(cfg, p, batch))(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert 0.0 < float(loss) < 20.0
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0.0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_smoke(arch):
    cfg, params, batch = _setup(arch, seq=32)
    logits = forward_prefill(cfg, params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_smoke(arch):
    cfg, params, batch = _setup(arch, seq=16)
    cache = init_cache(cfg, 2, 16, jnp.float32)
    if cfg.family == "encdec":
        enc_out = encdec.encode(cfg, params, batch["encoder_embeds"])
        cache = encdec.prime_cross_cache(cfg, params, cache, enc_out)
    logits, new_cache = serve_step(cfg, params, cache,
                                   batch["tokens"][:, :1], jnp.int32(0),
                                   seq_len=16)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache must actually change
    diff = sum(float(jnp.sum(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(cache),
                               jax.tree_util.tree_leaves(new_cache)))
    assert diff > 0.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-780m", "hymba-1.5b",
                                  "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch):
    """Sequential decode logits == teacher-forced forward logits."""
    cfg = reduced(get_config(arch))
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, n_experts=0, moe_top_k=0,
                                  n_shared_experts=0, d_ff=128)
        # (MoE capacity-dropping differs between batch and step-wise paths;
        #  dense variant isolates the cache mechanics.)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    S = 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                                cfg.vocab_size)
    hidden, _ = transformer.forward(cfg, params, tokens, remat=False)
    from repro.models.common import unembed
    full_logits = unembed(cfg, params, hidden)       # (1, S, V)

    cache = init_cache(cfg, 1, S, jnp.float32)
    outs = []
    for pos in range(S):
        lg, cache = serve_step(cfg, params, cache, tokens[:, pos:pos + 1],
                               jnp.int32(pos), seq_len=S)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-2, atol=2e-3)


def test_sliding_window_masked_vs_chunked():
    """attend_chunked (block-local) == attend_full with window mask."""
    from repro.models import attention as attn
    cfg = dataclasses.replace(
        reduced(get_config("gemma2-2b")), sliding_window=32,
        local_global_period=None, attn_softcap=None)
    lp = jax.tree_util.tree_map(
        lambda a: a[0],
        attn.init_attention(cfg, jax.random.PRNGKey(0), jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    full = attn.attend_full(cfg, lp, x, pos, window=32)
    chunked = attn.attend_chunked(cfg, lp, x, pos, window=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_ring_cache_decode_matches_full_cache():
    """Windowed ring decode == full cache decode with the same window."""
    cfg = dataclasses.replace(reduced(get_config("gemma-2b")),
                              sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    S = 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                                cfg.vocab_size)
    full_cache = init_cache(cfg, 1, S, jnp.float32)
    ring_cache = init_cache(cfg, 1, 8, jnp.float32)   # window-sized ring
    for pos in range(S):
        lf, full_cache = serve_step(cfg, params, full_cache,
                                    tokens[:, pos:pos + 1],
                                    jnp.int32(pos), seq_len=S)
        lr, ring_cache = serve_step(cfg, params, ring_cache,
                                    tokens[:, pos:pos + 1],
                                    jnp.int32(pos), seq_len=S)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   rtol=2e-3, atol=1e-4)


@pytest.mark.slow
def test_ssd_decode_matches_chunked_scan():
    """Recurrent SSM decode == full-sequence SSD on the same inputs."""
    from repro.models import ssm
    cfg = reduced(get_config("mamba2-780m"))
    lp = jax.tree_util.tree_map(
        lambda a: a[0], ssm.init_ssm(cfg, jax.random.PRNGKey(0),
                                     jnp.float32))
    S = 16
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model))
    y_full = ssm.apply_ssm(cfg, lp, x)
    d_inner, H, N, conv_dim, _ = ssm.ssm_dims(cfg)
    h = jnp.zeros((1, H, N, cfg.ssm_head_dim), jnp.float32)
    conv = jnp.zeros((1, cfg.ssm_conv_width - 1, conv_dim), jnp.float32)
    outs = []
    for t in range(S):
        o, h, conv = ssm.decode_ssm(cfg, lp, x[:, t:t + 1], h, conv)
        outs.append(o)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=5e-3, atol=5e-4)


def test_moe_capacity_drops_gracefully():
    from repro.models import moe as moe_mod
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    lp = jax.tree_util.tree_map(
        lambda a: a[0], moe_mod.init_moe(cfg, jax.random.PRNGKey(0),
                                         jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe_mod.apply_moe(cfg, lp, x, capacity_factor=0.25)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0


def test_logit_softcap_bounds_logits():
    cfg = reduced(get_config("gemma2-2b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    # blow up the embedding to force big logits
    params["embed"] = params["embed"] * 100.0
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    logits = forward_prefill(cfg, params, batch)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3
