"""Hierarchical aggregator tree (Supp. A) — correctness + accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregators import Aggregator, build_tree, \
    tree_message_counts
from repro.core.protocol import Server, UpdateMsg


def test_aggregator_sums_children():
    agg = Aggregator(0, [0, 1, 2])
    U = lambda v: {"w": jnp.full((4,), float(v))}
    assert agg.receive(UpdateMsg(0, 0, U(1))) is None
    assert agg.receive(UpdateMsg(0, 1, U(2))) is None
    out = agg.receive(UpdateMsg(0, 2, U(3)))
    assert out is not None
    np.testing.assert_allclose(np.asarray(out.U["w"]), 6.0)


def test_aggregator_interleaved_rounds():
    agg = Aggregator(0, [0, 1])
    U = lambda v: {"w": jnp.asarray([float(v)])}
    assert agg.receive(UpdateMsg(0, 0, U(1))) is None
    assert agg.receive(UpdateMsg(1, 0, U(10))) is None   # round 1 early
    out0 = agg.receive(UpdateMsg(0, 1, U(2)))
    assert out0.round_idx == 0
    out1 = agg.receive(UpdateMsg(1, 1, U(20)))
    assert out1.round_idx == 1
    np.testing.assert_allclose(np.asarray(out1.U["w"]), 30.0)


def test_tree_equivalent_to_flat_server():
    """server(client msgs) == server(aggregated msgs), same global model."""
    n = 4
    w0 = {"w": jnp.zeros((3,))}
    flat = Server(dict(w0), n_clients=n, round_stepsizes=[0.1])
    tree_srv = Server(dict(w0), n_clients=2, round_stepsizes=[0.1])
    aggs = build_tree(n, fan_in=2)
    key = jax.random.PRNGKey(0)
    Us = [{"w": jax.random.normal(jax.random.fold_in(key, c), (3,))}
          for c in range(n)]
    for c in range(n):
        flat.receive(UpdateMsg(0, c, Us[c]))
        up = aggs[c // 2].receive(UpdateMsg(0, c, Us[c]))
        if up is not None:
            tree_srv.receive(up)
    np.testing.assert_allclose(np.asarray(flat.v["w"]),
                               np.asarray(tree_srv.v["w"]), rtol=1e-6)
    assert flat.k == tree_srv.k == 1


def test_aggregator_forwards_min_k_send():
    """Regression: the summed upstream message must carry the bucket's
    MINIMUM k_send (the conservative, i.e. largest, staleness of any
    summed child update).  It previously fell through to the dataclass
    default 0, so the staleness-at-apply census read tau = server_k for
    every aggregator-tree message."""
    agg = Aggregator(0, [0, 1, 2])
    U = lambda v: {"w": jnp.asarray([float(v)])}  # noqa: E731
    assert agg.receive(UpdateMsg(3, 0, U(1), k_send=7)) is None
    assert agg.receive(UpdateMsg(3, 1, U(2), k_send=5)) is None
    out = agg.receive(UpdateMsg(3, 2, U(3), k_send=6))
    assert out is not None
    assert out.k_send == 5
    np.testing.assert_allclose(np.asarray(out.U["w"]), 6.0)


def test_message_accounting():
    mc = tree_message_counts(n_clients=100, fan_in=10, T=195)
    assert mc["aggregator_to_server"] == 10 * 195
    assert mc["server_inbound_reduction"] == 10.0
