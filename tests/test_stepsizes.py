"""Step-size schedules and the Lemma-2 round transform."""
import math

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (eta_t, per_iteration_stepsizes, round_stepsizes,
                        sample_sizes, theorem5_round_stepsizes)


def test_eta_schemes():
    c = StepSizeConfig(kind="constant", eta0=0.1)
    assert eta_t(c, 1000) == 0.1
    it = StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001)
    assert abs(eta_t(it, 1000) - 0.1 / 2.0) < 1e-12
    sq = StepSizeConfig(kind="inv_sqrt", eta0=0.1, beta=0.01)
    assert abs(eta_t(sq, 10_000) - 0.1 / 2.0) < 1e-12


def test_round_transform_freezes_eta_within_round():
    sizes = [10, 20, 30]
    cfg = StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.1)
    etas = round_stepsizes(cfg, sizes)
    assert etas[0] == eta_t(cfg, 0)
    assert etas[1] == eta_t(cfg, 10)
    assert etas[2] == eta_t(cfg, 30)
    assert etas[0] > etas[1] > etas[2]


def test_per_iteration_vs_round():
    sizes = [5, 5]
    cfg = StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.01)
    per = per_iteration_stepsizes(cfg, sizes)
    rnd = round_stepsizes(cfg, sizes)
    assert per[0][0] == rnd[0]
    assert per[1][0] == rnd[1]
    assert per[0][-1] < per[0][0]  # diminishing within a round


def test_theorem5_round_stepsizes_O_logi_over_i2():
    mu = 1.0
    seq_cfg = SampleSequenceConfig(kind="ilog", s0=1, m=100, d=1)
    sizes = sample_sizes(seq_cfg, 500)
    etas = theorem5_round_stepsizes(mu, sizes, m=100, d=1)
    assert all(b <= a for a, b in zip(etas, etas[1:]))
    # eta_bar_i ~ 12/(mu * t(i)): check against the closed form loosely
    cum = sum(sizes[:400])
    assert etas[400] < 12.0 / (mu * cum) * 1.1


def test_lemma2_bound_eta_ratio():
    """Lemma 2: alpha_t within [a0, 3 a0] <=> round eta within 3x of eta_t."""
    seq_cfg = SampleSequenceConfig(kind="ilog", s0=1, m=100, d=1)
    sizes = sample_sizes(seq_cfg, 200)
    cfg = StepSizeConfig(kind="inv_t", eta0=1.0, beta=1.0)
    etas = round_stepsizes(cfg, sizes)
    cum = 0
    for i, s in enumerate(sizes):
        for h in range(s):
            ratio = etas[i] / eta_t(cfg, cum + h)
            assert 1.0 <= ratio <= 3.01
        cum += s
