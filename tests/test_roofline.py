"""Roofline extraction: HLO collective parsing + model FLOP accounting."""
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.roofline import (RooflineReport, collective_bytes,
                                   model_flops)


HLO = """
ENTRY %main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %all-reduce.5 = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[256,64]{1,0} all-gather(%p0), dimensions={0}
  %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%p0, %p0)
  %cp = u32[100]{0} collective-permute(%p0)
  %noise = f32[999]{0} add(%p0, %p0)
}
"""


def test_collective_bytes_parses_ops_and_tuples():
    out = collective_bytes(HLO)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 256 * 64 * 2
    assert out["all-to-all"] == 2 * 8 * 8 * 4
    assert out["collective-permute"] == 100 * 4
    assert out["reduce-scatter"] == 0


def test_model_flops_train_vs_decode():
    cfg = get_config("gemma-2b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"], backward=True)
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"], backward=False)
    # train: 6*N*B*S tokens;  decode: 2*N*B tokens
    assert tr > de * 1000
    n = cfg.param_count()
    assert abs(tr - 6.0 * n * 256 * 4096) / tr < 0.01  # tied: no subtraction


def test_model_flops_moe_uses_active_params():
    moe = get_config("qwen2-moe-a2.7b")
    full_equiv = 6.0 * moe.param_count() * 256 * 4096
    active = model_flops(moe, INPUT_SHAPES["train_4k"], backward=True)
    assert active < 0.5 * full_equiv     # top-4 of 60 experts


def test_roofline_report_terms_and_dominant():
    r = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=256 * 197e12 * 2.0,          # => compute 2 s
        hlo_bytes=256 * 819e9 * 5.0,           # => memory 5 s
        coll_bytes=256 * 50e9 * 1.0,           # => collective 1 s
        coll_breakdown={}, model_flops_total=256 * 197e12)
    assert abs(r.compute_s - 2.0) < 1e-9
    assert abs(r.memory_s - 5.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.useful_ratio - 0.5) < 1e-9
