"""int8-quantized KV cache: numerics vs the f32 cache decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_cache, init_params, serve_step
from repro.models.attention import dequantize_kv, quantize_kv


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64))
    q, s = quantize_kv(x)
    x2 = dequantize_kv(q, s)
    rel = float(jnp.max(jnp.abs(x2 - x)) / jnp.max(jnp.abs(x)))
    assert rel < 1.0 / 100          # 7-bit mantissa => <1% absmax error
    assert q.dtype == jnp.int8


@pytest.mark.slow
def test_int8_decode_matches_f32_cache():
    cfg = reduced(get_config("gemma-2b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                              cfg.vocab_size)
    c_f = init_cache(cfg, 1, S, jnp.float32)
    c_q = init_cache(cfg, 1, S, jnp.int8)
    assert "k_scale" in c_q["kv"]
    for pos in range(S):
        lf, c_f = serve_step(cfg, params, c_f, toks[:, pos:pos + 1],
                             jnp.int32(pos), seq_len=S)
        lq, c_q = serve_step(cfg, params, c_q, toks[:, pos:pos + 1],
                             jnp.int32(pos), seq_len=S)
        np.testing.assert_allclose(
            np.asarray(jax.nn.softmax(lq, -1)),
            np.asarray(jax.nn.softmax(lf, -1)), atol=2e-3)


def test_int8_cache_halves_bytes():
    cfg = reduced(get_config("gemma-2b"))
    c_f = init_cache(cfg, 2, 64, jnp.bfloat16)
    c_q = init_cache(cfg, 2, 64, jnp.int8)
    bf = sum(l.size * l.dtype.itemsize
             for l in jax.tree_util.tree_leaves(c_f))
    qb = sum(l.size * l.dtype.itemsize
             for l in jax.tree_util.tree_leaves(c_q))
    assert qb < 0.65 * bf
