"""Engine parity (event vs host-cohort vs device-resident) and the
cohort DP kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort import (CohortSimulator, DeviceCohortSimulator,
                          as_cohort_task)
from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (AsyncFLSimulator, LogRegTask, round_stepsizes,
                        rounds_for_budget)
from repro.data import make_binary_dataset
from repro.kernels.cohort_dp import cohort_clip_noise, cohort_clip_noise_ref


# --- engine parity ----------------------------------------------------------

def test_cohort_matches_event_sim_paper_logreg():
    """Same LogRegTask seed/config (noise off): round counts and final
    model agree across engines on the paper_logreg recipe (Fig 1a kinds,
    reduced budget)."""
    X, y = make_binary_dataset(1_000, 32, seed=1, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=11)
    n_clients = 5
    sizes = rounds_for_budget(
        SampleSequenceConfig(kind="linear", s0=50, a=50.0), 800)
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001), sizes)
    kw = dict(
        n_clients=n_clients,
        sizes_per_client=[[max(1, s // n_clients) for s in sizes]]
        * n_clients,
        round_stepsizes=etas, d=1, seed=0,
        speeds=[1.0, 0.8, 1.2, 0.9, 1.1])

    res_ev = AsyncFLSimulator(task, **kw).run(max_rounds=len(sizes))
    res_co = CohortSimulator(task, **kw).run(max_rounds=len(sizes))

    assert res_ev["final"]["round"] == res_co["final"]["round"]
    np.testing.assert_allclose(np.asarray(res_ev["model"]["w"]),
                               np.asarray(res_co["model"]["w"]),
                               atol=1e-4)
    np.testing.assert_allclose(float(res_ev["model"]["b"]),
                               float(res_co["model"]["b"]), atol=1e-4)
    assert abs(res_ev["final"]["accuracy"]
               - res_co["final"]["accuracy"]) < 1e-3


def test_three_way_parity_event_cohort_device_d1():
    """Same sample-seeded task, d=1: the two cohort engines are
    bit-identical (same tick quantization, same integer credit, same
    deterministic 1-tick latency), and both match the event simulator's
    trajectory to float tolerance (bucketed vs per-message server adds
    reorder float sums)."""
    X, y = make_binary_dataset(500, 16, seed=7, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=13)
    n_clients = 4
    sizes = [[10, 20, 30, 40]] * n_clients
    etas = [0.1, 0.08, 0.06, 0.05]
    kw = dict(n_clients=n_clients, sizes_per_client=sizes,
              round_stepsizes=etas, d=1, seed=0,
              speeds=[1.0, 0.8, 1.2, 0.9])

    res_ev = AsyncFLSimulator(task, **kw).run(max_rounds=4)
    res_co = CohortSimulator(task, **kw).run(max_rounds=4)
    res_dv = DeviceCohortSimulator(task, **kw).run(max_rounds=4)

    assert (res_ev["final"]["round"] == res_co["final"]["round"]
            == res_dv["final"]["round"] == 4)
    assert (res_ev["final"]["messages"] == res_co["final"]["messages"]
            == res_dv["final"]["messages"])
    # cohort <-> device: bit-for-bit
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    # event <-> cohort engines: same trajectory up to summation order
    np.testing.assert_allclose(np.asarray(res_ev["model"]["w"]),
                               np.asarray(res_dv["model"]["w"]),
                               atol=1e-4)


def test_device_matches_host_cohort_bitwise_with_dp_and_gate():
    """DP noise (fused kernel), round clip, d=2 mid-round ISRRECEIVE and
    multi-tick latency all preserve host-cohort <-> device bit parity."""
    X, y = make_binary_dataset(300, 12, seed=9, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / 300, dp_clip=0.1, dp_sigma=2.0,
                      sample_seed=21)
    kw = dict(n_clients=5, sizes_per_client=[4, 6, 8],
              round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=3,
              speeds=[1.0, 0.6, 1.4, 0.8, 1.1], block=4,
              dp_round_clip=0.5)
    # dt = 4 / 1.4; a 5-virtual-second latency spans 2 ticks
    res_co = CohortSimulator(task, latency_fn=lambda r: 5.0, **kw).run(
        max_rounds=3)
    res_dv = DeviceCohortSimulator(task, latency=5.0, **kw).run(
        max_rounds=3)
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]
    assert res_co["final"]["broadcasts"] == res_dv["final"]["broadcasts"]


def test_device_stochastic_latency_runs_and_converges():
    """(lo, hi) latency range: device draws its own arrival ticks — a
    valid async schedule; protocol completes and the loss drops."""
    X, y = make_binary_dataset(400, 16, seed=4, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / 400, sample_seed=3)
    sim = DeviceCohortSimulator(
        task, n_clients=6, sizes_per_client=[4, 5, 6, 7, 8],
        round_stepsizes=[0.1, 0.08, 0.06, 0.05, 0.04], d=2, seed=1,
        speeds=[1.0, 0.5, 1.5, 0.7, 1.2, 0.9], block=4,
        latency=(2.0, 9.0))
    loss0 = task.metrics(task.init_model())["loss"]
    res = sim.run(max_rounds=5)
    assert res["final"]["round"] == 5
    assert res["final"]["loss"] < loss0
    assert res["final"]["messages"] >= 6 * 5


def test_device_rejects_host_latency_callable():
    X, y = make_binary_dataset(100, 8, seed=0)
    task = LogRegTask(X, y, sample_seed=0)
    with pytest.raises(TypeError, match="latency"):
        DeviceCohortSimulator(task, n_clients=2, sizes_per_client=[2],
                              round_stepsizes=[0.1], d=1, seed=0,
                              latency=lambda r: 0.05)


@pytest.mark.parametrize("engine_cls", [CohortSimulator,
                                        DeviceCohortSimulator])
def test_heterogeneous_speed_ratio_no_spurious_stall(engine_cls):
    """Regression: max_ticks was derived from block alone, so a speed
    ratio >= 16 made the slowest client outlive the tick budget and
    raised a bogus 'cohort engine stalled' RuntimeError."""
    X, y = make_binary_dataset(200, 8, seed=5, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / 200, sample_seed=2)
    res = engine_cls(
        task, n_clients=2, sizes_per_client=[8] * 3,
        round_stepsizes=[0.1, 0.08, 0.06], d=1, seed=0,
        speeds=[1.0, 1.0 / 512.0], block=8).run(max_rounds=3)
    assert res["final"]["round"] == 3


@pytest.mark.parametrize("engine_cls", [CohortSimulator,
                                        DeviceCohortSimulator])
def test_increasing_sizes_no_spurious_stall(engine_cls):
    """Regression: max_ticks was derived from ROUND-0 sizes, so an
    increasing schedule (the paper's central regime) whose later rounds
    dwarf s_0 outlived the tick budget and raised a bogus stall error."""
    X, y = make_binary_dataset(200, 8, seed=5, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / 200, sample_seed=2)
    res = engine_cls(
        task, n_clients=2, sizes_per_client=[1, 5000],
        round_stepsizes=[0.1, 0.05], d=1, seed=0,
        block=4).run(max_rounds=2)
    assert res["final"]["round"] == 2


def test_cohort_gate_d2_runs_and_converges():
    """d=2 regime (mid-round ISRRECEIVE): protocol completes, loss drops."""
    X, y = make_binary_dataset(600, 16, seed=2, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=3)
    sim = CohortSimulator(
        task, n_clients=6, sizes_per_client=[4, 5, 6, 7, 8],
        round_stepsizes=[0.1, 0.08, 0.06, 0.05, 0.04], d=2, seed=1,
        speeds=[1.0, 0.5, 1.5, 0.7, 1.2, 0.9], block=4)
    loss0 = task.metrics(task.init_model())["loss"]
    res = sim.run(max_rounds=5)
    assert res["final"]["round"] == 5
    assert res["final"]["loss"] < loss0
    # every client sent one update per completed round (+ gate slack)
    assert res["final"]["messages"] >= 6 * 5


def test_cohort_dp_noise_perturbs_model():
    X, y = make_binary_dataset(400, 16, seed=4, noise=0.3)
    clean = LogRegTask(X, y, l2=1.0 / 400, sample_seed=5)
    noisy = LogRegTask(X, y, l2=1.0 / 400, dp_clip=0.1, dp_sigma=4.0,
                       sample_seed=5)
    kw = dict(n_clients=4, sizes_per_client=[6, 8],
              round_stepsizes=[0.1, 0.08], d=1, seed=0)
    w_clean = CohortSimulator(clean, **kw).run(max_rounds=2)["model"]["w"]
    w_noisy = CohortSimulator(noisy, **kw).run(max_rounds=2)["model"]["w"]
    assert float(jnp.max(jnp.abs(w_clean - w_noisy))) > 1e-5


# --- fused clip+noise kernel vs oracle --------------------------------------

@pytest.mark.parametrize("C,D,clip,noise_scale", [
    (12, 300, 0.5, 0.2),       # clip + noise, padded both axes
    (16, 512, 0.0, 0.2),       # noise only (example-granularity DP)
    (8, 256, 1.0, 0.0),        # clip only
    (5, 100, 0.3, 0.1),        # heavy padding
])
def test_cohort_dp_kernel_matches_ref(C, D, clip, noise_scale):
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(jax.random.fold_in(key, 1), (C, D)) * 2.0
    mask = jnp.arange(C) % 3 != 0
    wgt = mask * jnp.linspace(0.1, 0.5, C)
    out_k, agg_k = cohort_clip_noise(u, key, wgt, mask, clip=clip,
                                     noise_scale=noise_scale,
                                     use_kernel=True, interpret=True)
    out_r, agg_r = cohort_clip_noise(u, key, wgt, mask, clip=clip,
                                     noise_scale=noise_scale,
                                     use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(agg_k), np.asarray(agg_r),
                               rtol=1e-5, atol=1e-5)


def test_cohort_dp_kernel_passthrough_and_agg_semantics():
    """Masked-out rows pass through untouched; agg is the weighted sum."""
    C, D = 8, 128
    u = jax.random.normal(jax.random.PRNGKey(2), (C, D))
    mask = jnp.array([1, 0, 1, 0, 1, 0, 1, 0], bool)
    wgt = mask * 0.25
    out, agg = cohort_clip_noise(u, jax.random.PRNGKey(3), wgt, mask,
                                 clip=0.5, noise_scale=0.1)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(u[1]),
                               atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(agg),
        np.asarray(jnp.sum(out * wgt[:, None], axis=0)), atol=1e-5)


def test_host_engine_steady_segments_reject_hidden_transfers():
    """Regression: CohortEngine.run wraps warm (post-first-eval) ticks
    in jax.transfer_guard("disallow"), like DeviceCohortEngine.run.  A
    scenario wrapper that implicitly stages a host scalar per broadcast
    — the exact bug host_broadcast_ticks used to have — must raise
    instead of silently serializing every cascade on a transfer."""
    X, y = make_binary_dataset(200, 10, seed=7, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=11)
    sim = CohortSimulator(
        task, n_clients=5, sizes_per_client=[4, 6, 8],
        round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=3, block=4,
        speeds=[1.0, 0.6, 1.4, 0.8, 1.1], scenario="geo_regional")
    eng = sim.engine
    plan = eng._plan
    # the guard only bites on the traced-draw path — constant-latency
    # plans short-circuit before touching the device
    assert not plan._ticks_const
    eng._bcast_ticks = lambda k: np.asarray(   # pre-fix implicit form
        plan._host_bc(jnp.int32(k)), np.int64)
    with pytest.raises(Exception, match="[Tt]ransfer"):
        sim.run(max_rounds=4)


def test_as_cohort_task_rejects_unknown():
    with pytest.raises(TypeError):
        as_cohort_task(object(), 4)


def test_make_simulator_reads_fl_config():
    from repro.cohort import make_simulator
    from repro.configs.base import FLConfig
    from repro.core.simulator import AsyncFLSimulator

    X, y = make_binary_dataset(200, 16, seed=0, noise=0.3)
    task = LogRegTask(X, y, sample_seed=0)
    kw = dict(n_clients=2, sizes_per_client=[2],
              round_stepsizes=[0.1], d=1, seed=0)
    sim = make_simulator(FLConfig(engine="cohort", cohort_block=7),
                         task, **kw)
    assert isinstance(sim, CohortSimulator)
    assert sim.engine.block == 7
    assert isinstance(make_simulator(FLConfig(engine="event"), task, **kw),
                      AsyncFLSimulator)
    with pytest.raises(ValueError):
        make_simulator("vmap", task, **kw)
