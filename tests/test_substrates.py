"""Optimizers, checkpointing, data pipeline, sharding specs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (load_fl_state, load_pytree, save_fl_state,
                              save_pytree)
from repro.configs import get_config, reduced
from repro.data import (TokenStream, client_sample_sizes, make_batch,
                        make_binary_dataset, unbiased_split)
from repro.optim import SGD, AdamW


def test_sgd_descends_quadratic():
    opt = SGD()
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(50):
        g = jax.grad(lambda p: p["x"] ** 2)(params)
        params, state = opt.update(g, state, params, 0.1)
    assert abs(float(params["x"])) < 0.01


def test_sgd_momentum_faster_on_illconditioned():
    def loss(p):
        return p["x"][0] ** 2 + 50.0 * p["x"][1] ** 2
    results = {}
    for momentum in (0.0, 0.8):
        opt = SGD(momentum=momentum)
        params = {"x": jnp.asarray([3.0, 3.0])}
        state = opt.init(params)
        for _ in range(120):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, 0.005)
        results[momentum] = float(loss(params))
    assert results[0.8] < results[0.0]


def test_adamw_converges():
    opt = AdamW(weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 4.0}
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params, 0.05)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree, metadata={"round": 7})
    restored = load_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_fl_state_roundtrip(tmp_path):
    model = {"w": jnp.ones((8,))}
    save_fl_state(str(tmp_path), global_model=model, server_k=42,
                  client_states={0: {"i": 5, "k": 4}})
    restored, k = load_fl_state(str(tmp_path), model)
    assert k == 42
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(model["w"]))


def test_token_stream_deterministic_and_client_dependent():
    ts = TokenStream(1024, seed=3)
    b1 = ts.batch(2, 32, step=5, client_id=1)
    b2 = ts.batch(2, 32, step=5, client_id=1)
    b3 = ts.batch(2, 32, step=5, client_id=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].max() < 1024


def test_make_batch_encdec_includes_stub():
    cfg = reduced(get_config("whisper-large-v3"))
    b = make_batch(cfg, 2, 16, seed=0)
    assert b["encoder_embeds"].shape == (2, cfg.encoder_seq_len,
                                         cfg.d_model)


def test_client_sample_sizes_expectation():
    sizes = [100] * 50
    p = [0.5, 0.3, 0.2]
    per = client_sample_sizes(sizes, p, seed=0)
    means = [np.mean(c) for c in per]
    assert abs(means[0] - 50) < 5
    assert abs(means[1] - 30) < 5
    per_exact = client_sample_sizes(sizes, p, exact=True)
    assert per_exact[0][0] == 50


def test_unbiased_split_partitions():
    X, y = make_binary_dataset(100, 4, seed=0)
    shards = unbiased_split(X, y, 3, seed=0)
    assert sum(len(s[0]) for s in shards) == 100


def test_param_pspecs_divisibility_fallback():
    """Odd vocab (whisper 51866) must not be sharded on the model axis."""
    import os
    from jax.sharding import PartitionSpec as P
    from repro.sharding import param_pspecs
    from repro.models import init_params

    cfg = get_config("whisper-large-v3")
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, jnp.bfloat16), jax.random.PRNGKey(0))
    devs = jax.devices()
    if len(devs) < 2:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        specs = param_pspecs(mesh, shapes)
        # single-device mesh: everything replicated (sizes 1 skipped)
        assert specs["embed"] == P(None, None)
    else:
        pytest.skip("multi-device local mesh covered by dry-run")
