"""Convergence experiments at test scale (the paper's §4 claims).

Fig 1a: diminishing step sizes + increasing sample sizes reach the same or
better accuracy than constant/constant, in FEWER communication rounds.
Fig 2: biased client datasets converge comparably to unbiased.
"""
import numpy as np
import pytest

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (AsyncFLSimulator, LogRegTask, round_stepsizes,
                        rounds_for_budget, run_sync_baseline)
from repro.data import biased_split, make_binary_dataset, unbiased_split

# whole-budget convergence runs: CI exercises these in the slow job
pytestmark = pytest.mark.slow


K = 8_000
N_CLIENTS = 4


def _dataset():
    return make_binary_dataset(4_000, 32, seed=1, noise=0.3)


def _run_async(task, sizes, etas, d=1, seed=0):
    per_client = [[max(1, s // N_CLIENTS) for s in sizes]] * N_CLIENTS
    sim = AsyncFLSimulator(task, n_clients=N_CLIENTS,
                           sizes_per_client=per_client,
                           round_stepsizes=etas, d=d, seed=seed,
                           speeds=[1.0, 0.8, 1.2, 1.0])
    return sim.run(max_rounds=len(sizes))


def test_fig1a_increasing_sizes_fewer_rounds_same_accuracy():
    X, y = _dataset()
    task = LogRegTask(X, y, l2=1.0 / len(X))

    # paper setting: linear increasing sizes + diminishing eta
    seq = SampleSequenceConfig(kind="linear", s0=100, a=100.0)
    sizes_inc = rounds_for_budget(seq, K)
    etas_inc = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001), sizes_inc)
    res_inc = _run_async(task, sizes_inc, etas_inc)

    # constant baseline with the same budget
    n_rounds_const = K // 200
    res_const = run_sync_baseline(task, n_clients=N_CLIENTS,
                                  n_rounds=n_rounds_const,
                                  sample_size=200 // N_CLIENTS,
                                  eta=0.0025)
    acc_inc = res_inc["final"]["accuracy"]
    acc_const = res_const["final"]["accuracy"]
    rounds_inc = res_inc["final"]["round"]
    assert rounds_inc < n_rounds_const          # fewer communication rounds
    assert acc_inc >= acc_const - 0.02          # same-or-better accuracy


def test_fig2_biased_vs_unbiased_clients():
    X, y = _dataset()
    ub = unbiased_split(X, y, 2, seed=0)
    bi = biased_split(X, y, 2, bias=1.0, seed=0)

    accs = {}
    for name, shards in [("unbiased", ub), ("biased", bi)]:
        sizes = rounds_for_budget(
            SampleSequenceConfig(kind="linear", s0=100, a=100.0), 4_000)
        etas = round_stepsizes(
            StepSizeConfig(kind="inv_t", eta0=0.01, beta=0.001), sizes)
        # each client samples from its own shard: model via combined task
        # with client-specific data handled by per-client LogRegTask
        from repro.core.protocol import Client, Server
        from repro.core.simulator import AsyncFLSimulator
        tasks = [LogRegTask(sx, sy, l2=1.0 / len(sx)) for sx, sy in shards]
        global_task = LogRegTask(X, y, l2=1.0 / len(X))
        sim = AsyncFLSimulator(
            global_task, n_clients=2,
            sizes_per_client=[[max(1, s // 2) for s in sizes]] * 2,
            round_stepsizes=etas, d=1, seed=0)
        # swap client tasks to their biased shards
        for c, t in enumerate(tasks):
            sim.clients[c].task = t
        res = sim.run(max_rounds=len(sizes))
        accs[name] = res["final"]["accuracy"]

    assert accs["biased"] >= accs["unbiased"] - 0.08   # "no significant difference"


def test_dp_training_converges_with_example3_parameters():
    """Fig 1b regime: sigma=8, clipped single-sample SGD still learns."""
    X, y = make_binary_dataset(2_000, 8, seed=11, noise=0.2)
    task = LogRegTask(X, y, l2=1.0 / len(X), dp_clip=0.1, dp_sigma=8.0)
    sizes = [16 + int(1.322 * i) for i in range(40)]
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.15, beta=0.001), sizes)
    res = _run_async(task, sizes, etas, seed=2)
    assert res["final"]["accuracy"] > 0.7   # learns despite DP noise


def test_dp_noise_hurts_relative_to_clean():
    X, y = make_binary_dataset(1_000, 8, seed=5, noise=0.2)
    sizes = [50 + 25 * i for i in range(10)]
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001), sizes)
    clean = _run_async(LogRegTask(X, y, l2=1e-3), sizes, etas, seed=1)
    noisy = _run_async(LogRegTask(X, y, l2=1e-3, dp_clip=0.05,
                                  dp_sigma=32.0), sizes, etas, seed=1)
    assert clean["final"]["loss"] <= noisy["final"]["loss"] + 1e-6
