"""Theorem 1's ρ map (B.1) and the C.1 mask recursion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.masks import (apply_masked_update, expectation_check,
                              make_partition, mask_for_group,
                              masked_update_nbytes)
from repro.core.ordering import (client_sizes, is_bijection,
                                 make_assignment, rho, rho_inverse)


@given(seed=st.integers(0, 50), n_clients=st.integers(1, 5),
       n_rounds=st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_rho_is_bijection(seed, n_clients, n_rounds):
    sizes = [3 + 2 * i for i in range(n_rounds)]
    p = [1.0 / n_clients] * n_clients
    a = make_assignment(sizes, p, seed=seed)
    assert is_bijection(a, n_clients)


def test_rho_inverse_roundtrip():
    a = make_assignment([5, 8, 11], [0.5, 0.5], seed=3)
    total = 5 + 8 + 11
    for t in range(total):
        c, i, h = rho_inverse(a, t)
        assert rho(a, c, i, h) == t


def test_client_sizes_sum_to_round_sizes():
    sizes = [10, 20, 30]
    a = make_assignment(sizes, [0.3, 0.7], seed=0)
    per = client_sizes(a, 2)
    for i, s in enumerate(sizes):
        assert per[0][i] + per[1][i] == s


def test_partition_balanced_and_complete():
    params = {"w": jnp.zeros((13, 7)), "b": jnp.zeros((5,))}
    D = 4
    part = make_partition(params, D, seed=0)
    for leaf in jax.tree_util.tree_leaves(part):
        assert int(leaf.min()) >= 0 and int(leaf.max()) < D
    # every coordinate in exactly one group
    total = sum(int(jnp.sum(mask_for_group(part, u)["w"]))
                for u in range(D))
    assert total == 13 * 7


def test_masked_update_unbiased():
    """Equation (10): d_ξ E[S_u] = I  =>  E_u[masked update] == grad."""
    key = jax.random.PRNGKey(0)
    grad = {"w": jax.random.normal(key, (32, 8)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (8,))}
    D = 4
    part = make_partition(grad, D, seed=1)
    recon = expectation_check(grad, part, D)
    np.testing.assert_allclose(np.asarray(recon["w"]),
                               np.asarray(grad["w"]), rtol=1e-5)


def test_masked_update_reduces_communication():
    grad = {"w": jnp.ones((1000,), jnp.float32)}
    D = 10
    part = make_partition(grad, D, seed=0)
    upd = apply_masked_update(grad, part, 0, D)
    nbytes = masked_update_nbytes(upd, part, 0)
    assert nbytes == 100 * 4          # 1/D of the dense 4000 bytes
