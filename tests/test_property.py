"""Property-based tests (hypothesis) for system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (Theorem5Delay, lemma1_sequence, round_stepsizes,
                        sample_sizes, satisfies_condition3)
from repro.core.delay import t_minus_tau_increasing
from repro.dp import clip_tree, moments_delta, r0_sigma, r_from_r0, tree_norm
from repro.dp.accountant import select_parameters


# --- sequences ---------------------------------------------------------------

@given(m=st.integers(0, 5000), d=st.integers(0, 4),
       n=st.integers(10, 300))
@settings(max_examples=30, deadline=None)
def test_lemma1_recipe_always_satisfies_condition3(m, d, n):
    seq = lemma1_sequence(n, g=2.0, m=m, d=d)
    tau = Theorem5Delay(m=m, d=d)
    assert satisfies_condition3(seq, tau, d)
    assert all(s >= 1 for s in seq)


@given(s0=st.integers(1, 100), a=st.floats(0.1, 20.0),
       n=st.integers(2, 100))
@settings(max_examples=30, deadline=None)
def test_linear_sizes_nondecreasing(s0, a, n):
    cfg = SampleSequenceConfig(kind="linear", s0=s0, a=a)
    s = sample_sizes(cfg, n)
    assert all(b >= x for x, b in zip(s, s[1:]))


@given(m=st.integers(0, 2000), d=st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_theorem5_delay_monotone(m, d):
    tau = Theorem5Delay(m=m, d=d)
    assert t_minus_tau_increasing(tau, 50_000, step=97)


@given(eta0=st.floats(1e-4, 1.0), beta=st.floats(1e-5, 1.0),
       kind=st.sampled_from(["inv_t", "inv_sqrt"]))
@settings(max_examples=30, deadline=None)
def test_round_stepsizes_nonincreasing(eta0, beta, kind):
    cfg = StepSizeConfig(kind=kind, eta0=eta0, beta=beta)
    sizes = [5 + 3 * i for i in range(50)]
    etas = round_stepsizes(cfg, sizes)
    assert all(b <= a + 1e-15 for a, b in zip(etas, etas[1:]))
    assert etas[0] == eta0


# --- DP ----------------------------------------------------------------------

@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_clip_never_exceeds_bound(data):
    dims = data.draw(st.lists(st.integers(1, 20), min_size=1, max_size=3))
    scale = data.draw(st.floats(0.01, 100.0))
    clip = data.draw(st.floats(0.01, 10.0))
    rng = np.random.default_rng(0)
    tree = {"x": jnp.asarray(scale * rng.standard_normal(dims),
                             jnp.float32)}
    clipped = clip_tree(tree, clip)
    assert float(tree_norm(clipped)) <= clip * (1 + 1e-4)


@given(sigma=st.floats(1.2, 16.0))
@settings(max_examples=20, deadline=None)
def test_r0_fixed_point_valid(sigma):
    r0 = r0_sigma(sigma, 1.0)
    assert 0.0 < r0 < 1.0 / math.e + 1e-9
    r = r_from_r0(r0, sigma)
    # fixed point: r == target coefficient * (1-r0/sigma)^2
    target = (math.sqrt(3) - 1) / 2 * 4 / 6 * (1 - r0 / sigma) ** 2
    assert abs(r - target) < 1e-6


@given(sigma=st.floats(2.0, 12.0), T=st.integers(10, 400))
@settings(max_examples=20, deadline=None)
def test_moments_delta_in_unit_interval_and_monotone_in_eps(sigma, T):
    sizes = [16] * T
    d1 = moments_delta(sizes, 10_000, sigma, epsilon=0.5)
    d2 = moments_delta(sizes, 10_000, sigma, epsilon=1.0)
    assert 0.0 <= d2 <= d1 <= 1.0


@given(K_epochs=st.floats(1.0, 8.0), sigma=st.floats(6.0, 12.0))
@settings(max_examples=15, deadline=None)
def test_parameter_selection_always_reduces_rounds(K_epochs, sigma):
    # sigma >= 6: the paper's closed-form T approximation is valid in its
    # regime (small gamma = m/T); tiny sigma shrinks K* so much that the
    # sequence degenerates toward constant and the formula overestimates T.
    N_c = 10_000
    sel = select_parameters(s0c=16, N_c=N_c, p=1.0, epsilon=1.0,
                            sigma=sigma, K=int(K_epochs * N_c),
                            r0=1.0 / math.e)
    assert sel.T < sel.T_constant
    assert sel.sizes[-1] >= sel.sizes[0]
    assert 0.0 < sel.delta <= 1.0


# --- flat-params adapter -----------------------------------------------------

_FLAT_DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


def _draw_tree(data, max_leaves=6):
    """Random nested pytree of float arrays with mixed shapes/dtypes."""
    n = data.draw(st.integers(1, max_leaves))
    seed = data.draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    leaves = []
    for _ in range(n):
        shape = tuple(data.draw(
            st.lists(st.integers(1, 5), min_size=0, max_size=3)))
        dt = data.draw(st.sampled_from(_FLAT_DTYPES))
        leaves.append(jnp.asarray(
            8.0 * rng.standard_normal(shape), dt))
    cut = (n + 1) // 2
    return {"head": leaves[:cut], "tail": tuple(leaves[cut:])}


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_pytree_flattener_roundtrip_exact(data):
    """Arbitrary nested trees with mixed shapes/dtypes: D is the total
    leaf size, flatten is [D] f32, and the round trip is bit-exact
    (f32 is a value superset of every <=32-bit float dtype)."""
    from repro.cohort import PyTreeFlattener
    tree = _draw_tree(data)
    leaves = jax.tree_util.tree_leaves(tree)
    flt = PyTreeFlattener(tree)
    assert flt.D == sum(int(np.prod(l.shape)) for l in leaves)
    vec = flt.flatten(tree)
    assert vec.shape == (flt.D,) and vec.dtype == jnp.float32
    back = flt.unflatten(vec)
    assert (jax.tree_util.tree_structure(back)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(leaves, jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert bool(jnp.all(a == b))


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_pytree_flattener_flat_update_matches_treewise(data):
    """SGD in the flat layout == SGD tree-wise: flatten params and grads,
    apply p - eta * g on the [D] vectors, unflatten — bitwise identical
    to tree_map on f32 trees (what run_block relies on)."""
    from repro.cohort import PyTreeFlattener
    seed = data.draw(st.integers(0, 2 ** 31 - 1))
    eta = data.draw(st.floats(1e-4, 1.0))
    rng = np.random.default_rng(seed)
    shapes = [tuple(data.draw(
        st.lists(st.integers(1, 4), min_size=0, max_size=2)))
        for _ in range(data.draw(st.integers(1, 4)))]
    p = {"p": [jnp.asarray(rng.standard_normal(s), jnp.float32)
               for s in shapes]}
    g = {"p": [jnp.asarray(rng.standard_normal(s), jnp.float32)
               for s in shapes]}
    flt = PyTreeFlattener(p)
    flat = flt.unflatten(flt.flatten(p) - jnp.float32(eta) * flt.flatten(g))
    tree = jax.tree_util.tree_map(
        lambda a, b: a - jnp.float32(eta) * b, p, g)
    for a, b in zip(jax.tree_util.tree_leaves(flat),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pytree_flattener_rejects_empty_template():
    from repro.cohort import PyTreeFlattener
    with pytest.raises(ValueError, match="leaf"):
        PyTreeFlattener({"empty": ()})


# --- scenarios: latency tables + availability invariants ---------------------

@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_latency_table_construction_roundtrip(data):
    """Tables built from arbitrary positive traces are valid
    distributions, their alias decomposition encodes exactly the bin
    probabilities, and the JSON round trip is exact."""
    from repro.scenarios import LatencyTable, implied_probs
    n = data.draw(st.integers(1, 200))
    seed = data.draw(st.integers(0, 2 ** 31 - 1))
    n_bins = data.draw(st.integers(1, 32))
    scale = data.draw(st.floats(1e-3, 1e3))
    rng = np.random.default_rng(seed)
    samples = scale * (0.05 + rng.lognormal(0.0, 1.0, n))
    t = LatencyTable.from_samples(samples, n_bins=n_bins)
    assert abs(sum(t.probs) - 1.0) < 1e-9
    assert all(b >= a for a, b in zip(t.values, t.values[1:]))
    assert samples.min() <= t.mean() <= samples.max() + 1e-9
    np.testing.assert_allclose(implied_probs(*t.alias_arrays()),
                               np.asarray(t.probs), atol=1e-7)
    assert LatencyTable.from_json(t.to_json()) == t
    # tick quantization: every bin maps to >= 1 tick, monotone in value
    dt = data.draw(st.floats(1e-2, 1e2))
    ticks = t.tick_values(dt)
    assert (ticks >= 1).all()
    assert (np.diff(ticks) >= 0).all()


@given(period=st.floats(64.0, 4096.0), on_frac=st.floats(0.3, 0.9),
       seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_availability_mask_invariant_no_credit_no_update(period, on_frac,
                                                         seed):
    """Engine-level availability invariant: every client masked off at
    the first ticks has taken no iteration, accrued no credit, and sent
    no update after those ticks — only on clients contribute messages."""
    from repro.cohort import CohortSimulator
    from repro.core import LogRegTask
    from repro.data import make_binary_dataset
    from repro.scenarios import Diurnal, LatencyTable, Scenario
    X, y = make_binary_dataset(60, 4, seed=0, noise=0.3)
    task = LogRegTask(X, y, sample_seed=0)
    scn = Scenario("prop", LatencyTable.constant(1.0),
                   Diurnal(period_s=period, on_frac=on_frac))
    eng = CohortSimulator(task, n_clients=4, sizes_per_client=[64] * 3,
                          round_stepsizes=[0.1] * 3, d=2, seed=seed,
                          block=4, scenario=scn).engine
    n_ticks = 4
    off = np.ones(eng.C, bool)
    for t in range(1, n_ticks + 1):
        off &= ~np.asarray(eng._plan.host_avail(t))
    for _ in range(n_ticks):
        eng.step()
    st = eng.state
    assert (st.h[off] == 0).all() and (st.credit[off] == 0).all()
    assert (st.i[off] == 0).all()
    assert eng.total_messages == int(st.i[~off].sum())


@given(on_rate=st.floats(0.05, 0.5), off_rate=st.floats(0.05, 0.5),
       seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_renewal_churn_stationary_duty(on_rate, off_rate, seed):
    """RenewalChurn's per-tick mask hits the analytic stationary duty
    on_rate / (on_rate + off_rate): epoch-spaced samples are
    independent Bernoulli(duty), so the empirical mean lands within a
    5-sigma binomial band."""
    from repro.scenarios import RenewalChurn
    av = RenewalChurn(on_rate=on_rate, off_rate=off_rate)
    duty = av.duty
    C, E = 24, 48
    mask = av.tick_plan(C=C, dt=1.0, seed=seed)
    epoch_t = max(1, round(av.epoch_cycles * av.mean_cycle_s))
    on = sum(int(np.asarray(mask(jnp.int32(e * epoch_t + 1))).sum())
             for e in range(E))
    n = C * E
    band = 5.0 * math.sqrt(duty * (1.0 - duty) / n)
    assert abs(on / n - duty) < band + 1e-9, (on / n, duty)


@given(p=st.floats(0.4, 0.9), margin=st.floats(0.02, 0.1),
       seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_regional_churn_duty_and_correlation_sign(p, margin, seed):
    """RegionalChurn: marginal duty equals the advertised p_available,
    within-region masks correlate positively, cross-region pairs stay
    uncorrelated (draws from independent chains)."""
    from repro.scenarios import RegionalChurn
    p_reg = min(1.0, p + margin)
    av = RegionalChurn(n_regions=2, p_available=p, p_region_up=p_reg,
                       epoch_s=2.0)
    C, E = 8, 256
    mask = av.tick_plan(C=C, dt=1.0, seed=seed)
    reg = av.regions(C)
    M = np.stack([np.asarray(mask(jnp.int32(2 * e)))
                  for e in range(E)]).astype(np.float64)
    n = C * E
    band = 5.0 * math.sqrt(p * (1.0 - p) / n)
    assert abs(M.mean() - p) < band + 1e-9
    corr = np.corrcoef(M.T)
    same = (reg[:, None] == reg[None, :]) & ~np.eye(C, dtype=bool)
    # analytic within-region correlation: p (1/p_reg - 1) / (1 - p)
    rho = p * (1.0 / p_reg - 1.0) / (1.0 - p)
    within = corr[same].mean()
    cross = corr[~(reg[:, None] == reg[None, :])].mean()
    assert within > rho - 0.25, (within, rho)
    if rho > 0.3:            # a real regional factor must show up as
        assert within > 0.05  # strictly positive correlation
    assert abs(cross) < 0.2, cross


# --- MoE dispatch conservation -------------------------------------------------

@given(seed=st.integers(0, 100), cf=st.floats(0.5, 2.0))
@settings(max_examples=10, deadline=None)
def test_moe_combine_weights_bounded(seed, cf):
    from repro.configs import get_config, reduced
    from repro.models import moe as moe_mod
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    lp = jax.tree_util.tree_map(
        lambda a: a[0],
        moe_mod.init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32, cfg.d_model))
    out, aux = moe_mod.apply_moe(cfg, lp, x, capacity_factor=cf)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.0


# --- simulator invariant ---------------------------------------------------------

@given(seed=st.integers(0, 50), d=st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_simulator_gate_invariant_random_network(seed, d):
    from repro.core import AsyncFLSimulator, LogRegTask
    from repro.data import make_binary_dataset
    X, y = make_binary_dataset(100, 4, seed=seed)
    task = LogRegTask(X, y)
    rng = np.random.default_rng(seed)
    speeds = list(0.5 + rng.random(3) * 2.0)
    sim = AsyncFLSimulator(
        task, n_clients=3, sizes_per_client=[[2 + i for i in range(8)]] * 3,
        round_stepsizes=[0.05] * 8, d=d, seed=seed, speeds=speeds,
        latency_fn=lambda r: 0.001 + 0.5 * r.random())
    res = sim.run(max_rounds=8)
    assert res["final"]["round"] == 8
    for cl in sim.clients:
        assert cl.i - cl.k <= d          # the wait-gate invariant held
