"""Trace invariant checker (repro.analysis.invariants): real engine
traces must model-check clean, and corrupted JSONL must trip each
invariant family — τ bound, bytes census, round conservation, latch
monotonicity, segment monotonicity.
"""
import io
import json

import pytest

from repro.analysis.invariants import check_report, check_trace, read_trace
from repro.cohort import CohortSimulator, DeviceCohortSimulator
from repro.core import AsyncFLSimulator, LogRegTask
from repro.data import make_binary_dataset
from repro.scenarios import LatencyTable, Scenario


def _task(**kw):
    X, y = make_binary_dataset(200, 10, seed=9, noise=0.3)
    return LogRegTask(X, y, l2=0.005, sample_seed=21, **kw)


def _rules(violations):
    return sorted({v.rule for v in violations})


def _event_trace(d=2, **task_kw):
    buf = io.StringIO()
    AsyncFLSimulator(_task(**task_kw), scenario="uniform", trace=buf,
                     n_clients=5, sizes_per_client=[3, 4],
                     round_stepsizes=[0.1, 0.08], d=d,
                     seed=3).run(max_rounds=3)
    return [json.loads(ln) for ln in buf.getvalue().strip().splitlines()]


def _device_trace(tmp_path, d=3, scenario="geo_regional", **task_kw):
    path = tmp_path / "device.jsonl"
    DeviceCohortSimulator(_task(**task_kw), scenario=scenario,
                          n_clients=6, sizes_per_client=[3, 4, 5],
                          round_stepsizes=[0.1, 0.08, 0.06], d=d, seed=5,
                          block=4, trace=str(path)).run(max_rounds=4,
                                                        eval_every=1)
    return str(path)


# --- clean traces model-check clean -------------------------------------------

def test_event_trace_clean(tmp_path):
    recs = _event_trace(d=2)
    assert check_trace(recs, d=2) == []


def test_event_trace_with_dp_clean():
    recs = _event_trace(d=2, dp_clip=1.0, dp_sigma=1.5)
    assert check_trace(recs, d=2) == []


def test_device_trace_golden_scenario_clean(tmp_path):
    """Golden-trajectory-style device run (churny geo_regional, d=3)."""
    path = _device_trace(tmp_path, d=3)
    assert check_trace(path, d=3) == []


def test_device_trace_dp_heavy_tail_churn_clean(tmp_path):
    """DP + heavy-tail latency + small ring (far tier + overflow HWM
    exercised) — the richest segment trace the engines emit."""
    scn = Scenario("tail", LatencyTable.from_uniform(1.0, 200.0, 16),
                   ring_cap=8)
    path = tmp_path / "tail.jsonl"
    res = DeviceCohortSimulator(
        _task(dp_clip=0.1, dp_sigma=2.0), scenario=scn, n_clients=6,
        sizes_per_client=[3, 4], round_stepsizes=[0.1, 0.08], d=2, seed=2,
        block=4, dp_round_clip=0.5, trace=str(path)).run(max_rounds=3,
                                                         eval_every=1)
    assert res["final"]["overflow_hwm"] > 0    # latch actually moved
    assert check_trace(str(path), d=2) == []


def test_host_cohort_trace_clean(tmp_path):
    path = tmp_path / "host.jsonl"
    CohortSimulator(_task(), scenario="mobile_diurnal", n_clients=5,
                    sizes_per_client=[3, 4], round_stepsizes=[0.1, 0.08],
                    d=2, seed=7, block=4,
                    trace=str(path)).run(max_rounds=3, eval_every=1)
    assert check_trace(str(path), d=2) == []


# --- corrupted JSONL trips each family ----------------------------------------

def test_corrupt_tau_exceeds_gate():
    """An apply recorded past the wait gate (τ > d-1) must fire INV-TAU."""
    recs = _event_trace(d=2)
    applied = [r for r in recs if r["kind"] == "update_applied"]
    applied[0]["staleness"] = 7                # d-1 == 1
    found = check_trace(recs, d=2)
    assert "INV-TAU" in _rules(found)
    assert any("wait-gate" in v.message for v in found)


def test_corrupt_negative_staleness():
    recs = _event_trace(d=2)
    applied = [r for r in recs if r["kind"] == "update_applied"]
    applied[-1]["staleness"] = -1
    assert "INV-TAU" in _rules(check_trace(recs, d=2))


def test_corrupt_bytes_census():
    """Report bytes_up no longer equal to Σ update_sent bytes."""
    recs = _event_trace(d=2)
    report = [r for r in recs if r["kind"] == "report"][0]
    report["bytes_up"] = list(report["bytes_up"])
    report["bytes_up"][0] += 1
    found = check_trace(recs, d=2)
    assert "INV-CENSUS" in _rules(found)


def test_corrupt_lost_apply_breaks_round_conservation():
    """Dropping one update_applied leaves a completed round at C-1
    applies — Algorithm 3's H set can't have filled."""
    recs = _event_trace(d=2)
    drop = next(i for i, r in enumerate(recs)
                if r["kind"] == "update_applied" and r["round"] == 0)
    del recs[drop]
    found = check_trace(recs, d=2)
    assert "INV-ROUND" in _rules(found)


def test_corrupt_time_regression():
    recs = _event_trace(d=2)
    events = [r for r in recs if "time" in r]
    events[-1]["time"] = events[0]["time"] - 1.0
    assert "INV-TIME" in _rules(check_trace(recs, d=2))


def test_corrupt_overflow_latch_regression(tmp_path):
    """The overflow HWM is a latch; a later segment reporting a lower
    mark means the census was rebuilt instead of latched."""
    scn = Scenario("tail", LatencyTable.from_uniform(1.0, 200.0, 16),
                   ring_cap=8)
    path = tmp_path / "tail.jsonl"
    DeviceCohortSimulator(
        _task(dp_clip=0.1, dp_sigma=2.0), scenario=scn, n_clients=6,
        sizes_per_client=[3, 4], round_stepsizes=[0.1, 0.08], d=2, seed=2,
        block=4, dp_round_clip=0.5, trace=str(path)).run(max_rounds=3,
                                                         eval_every=1)
    recs = read_trace(str(path))
    segs = [r for r in recs if r["kind"] == "segment"]
    assert len(segs) >= 2 and segs[-1]["overflow_hwm"] > 0
    segs[-1]["overflow_hwm"] = 0               # regress the latch
    found = check_trace(recs, d=2)
    assert "INV-LATCH" in _rules(found)


def test_corrupt_segment_counter_regression(tmp_path):
    path = _device_trace(tmp_path, d=3)
    recs = read_trace(path)
    segs = [r for r in recs if r["kind"] == "segment"]
    segs[-1]["messages"] = segs[0]["messages"] - 1
    found = check_trace(recs, d=3)
    assert "INV-MONO" in _rules(found)


def test_corrupt_staleness_hist_entrywise_regression(tmp_path):
    path = _device_trace(tmp_path, d=3)
    recs = read_trace(path)
    segs = [r for r in recs if r["kind"] == "segment"]
    assert segs[-1]["staleness_hist"][0] > 0
    segs[-1]["staleness_hist"] = list(segs[-1]["staleness_hist"])
    segs[-1]["staleness_hist"][0] -= 1
    assert "INV-MONO" in _rules(check_trace(recs, d=3))


# --- report-level checks --------------------------------------------------------

def test_check_report_census_identities():
    rep = {"clients": 2, "messages": 5, "broadcasts": 2,
           "participation": [3, 2], "update_msg_bytes": 10,
           "broadcast_msg_bytes": 8, "bytes_up": [30, 20],
           "bytes_down": [16, 16], "staleness_hist": [5, 0, 0, 0],
           "overflow_hwm": 1, "overflow_slots": 4}
    assert check_report(rep, d=1) == []
    bad = dict(rep, participation=[3, 3])       # Σ != messages
    assert _rules(check_report(bad, d=1)) == ["INV-CENSUS"]
    bad = dict(rep, staleness_hist=[4, 1, 0, 0])  # mass past d-1
    assert _rules(check_report(bad, d=1)) == ["INV-TAU"]
    bad = dict(rep, overflow_hwm=9)              # over capacity
    assert _rules(check_report(bad, d=1)) == ["INV-LATCH"]
    bad = dict(rep, bytes_down=[16, 24])
    assert _rules(check_report(bad, d=1)) == ["INV-CENSUS"]


def test_read_trace_rejects_malformed_lines(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"kind": "report"}\nnot json\n')
    with pytest.raises(ValueError, match="line 2"):
        read_trace(str(p))
    p.write_text('{"no_kind": 1}\n')
    with pytest.raises(ValueError, match="kind"):
        read_trace(str(p))


def test_check_trace_accepts_lines_and_paths(tmp_path):
    recs = _event_trace(d=2)
    lines = [json.dumps(r) for r in recs]
    assert check_trace(lines, d=2) == []        # iterable of JSONL lines
    p = tmp_path / "t.jsonl"
    p.write_text("\n".join(lines) + "\n")
    assert check_trace(str(p), d=2) == []       # path (where=path)


# --- op-census + timeline discipline (INV-SPAN, PR 9) -------------------------

def test_corrupt_segment_ops_regression(tmp_path):
    """Per-segment op-census counters are cumulative; one regressing
    entrywise means an increment site was rebuilt, not accumulated."""
    path = _device_trace(tmp_path, d=3)
    recs = read_trace(path)
    segs = [r for r in recs if r["kind"] == "segment"]
    assert segs[0]["ops"][0] > 0                # ticks counted
    segs[-1]["ops"] = list(segs[-1]["ops"])
    segs[-1]["ops"][0] = segs[0]["ops"][0] - 1  # below an earlier segment
    assert "INV-SPAN" in _rules(check_trace(recs, d=3))


def test_corrupt_report_ops_relations(tmp_path):
    """Report op census inconsistent with the message counts fires
    INV-SPAN (complete_ticks cannot exceed messages)."""
    path = _device_trace(tmp_path, d=3)
    recs = read_trace(path)
    report = [r for r in recs if r["kind"] == "report"][0]
    assert check_trace(recs, d=3) == []         # clean before corruption
    report["ops"] = dict(report["ops"],
                         complete_ticks=report["messages"] + 1)
    found = check_trace(recs, d=3)
    assert "INV-SPAN" in _rules(found)
    assert any("complete_ticks" in v.message for v in found)


def test_check_perfetto_overlap_and_shape(tmp_path):
    from repro.analysis.invariants import check_perfetto
    ok = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0, "dur": 5},
        {"ph": "X", "name": "b", "pid": 1, "tid": 1, "ts": 5, "dur": 3},
    ]}
    assert check_perfetto(ok) == []
    overlapping = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0, "dur": 5},
        {"ph": "X", "name": "b", "pid": 1, "tid": 1, "ts": 3, "dur": 3},
    ]}
    found = check_perfetto(overlapping)
    assert _rules(found) == ["INV-SPAN"]
    missing_dur = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0}]}
    assert _rules(check_perfetto(missing_dur)) == ["INV-SPAN"]
    # path form: a real exported document checks clean
    import json as _json
    from repro.telemetry import trace_to_perfetto, write_perfetto
    recs = _event_trace(d=2)
    out = tmp_path / "trace.json"
    write_perfetto(str(out), trace_to_perfetto(recs))
    assert check_perfetto(str(out)) == []
