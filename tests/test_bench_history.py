"""Bench regression gate (benchmarks/history.py): metric extraction
from BENCH_cohort.json-shaped reports, the tolerance math, and the
fingerprint comparability guard.  Pure-logic tests — no bench runs.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.history import (  # noqa: E402
    COMPARABLE_KEYS, check_regression, extract_metrics,
    fingerprint_mismatches, main)

BENCH = {
    "compute_r2_s8": {
        "4096": {
            "clients": 4096,
            "cohort": {"sec": 0.02, "phases": {
                "compile_s": 1.0, "warmup_s": 0.05, "steady_s": 0.02,
                "clients_per_sec": 200_000.0}},
            "device": {"sec": 0.01, "phases": {
                "compile_s": 2.0, "warmup_s": 0.05, "steady_s": 0.01,
                "clients_per_sec": 400_000.0}},
            # event leg has no phases block: not gateable, must be skipped
            "event": {"sec": 0.5, "client_rounds_per_sec": 16_384.0},
        },
    },
    "scenario_smoke": {
        "mobile_diurnal": {"64": {"device": {"phases": {
            "compile_s": 0.5, "warmup_s": 0.01, "steady_s": 0.005,
            "clients_per_sec": 12_800.0}}}},
    },
    "derived": "free-text summary, ignored",
}


def test_extract_metrics_flattens_phase_blocks():
    m = extract_metrics(BENCH)
    assert set(m) == {
        "compute_r2_s8/4096/cohort",
        "compute_r2_s8/4096/device",
        "scenario_smoke/mobile_diurnal/64/device",
    }
    dv = m["compute_r2_s8/4096/device"]
    assert dv == {"clients_per_sec": 400_000.0, "compile_s": 2.0,
                  "steady_s": 0.01}


def test_check_regression_tolerances():
    base = extract_metrics(BENCH)
    # identical numbers: clean
    assert check_regression(base, base) == []
    # 10% throughput drop: inside the 15% tolerance
    ok = {k: dict(v, clients_per_sec=v["clients_per_sec"] * 0.90)
          for k, v in base.items()}
    assert check_regression(ok, base) == []
    # 20% drop on one workload: exactly that workload flagged
    slow = {k: dict(v) for k, v in base.items()}
    slow["compute_r2_s8/4096/device"]["clients_per_sec"] *= 0.80
    problems = check_regression(slow, base)
    assert len(problems) == 1
    assert "compute_r2_s8/4096/device" in problems[0]
    assert "20%" in problems[0]
    # compile-time growth past 50% fires independently of throughput
    comp = {k: dict(v, compile_s=v["compile_s"] * 1.6)
            for k, v in base.items()}
    problems = check_regression(comp, base)
    assert len(problems) == len(base)
    assert all("compile_s" in p for p in problems)
    # disjoint keys (bench never ran): explicit problem, not silent pass
    assert check_regression({}, base) != []


def test_fingerprint_mismatch_guard():
    fp = {k: "x" for k in COMPARABLE_KEYS}
    assert fingerprint_mismatches(fp, dict(fp)) == []
    other = dict(fp, jax="y", cpus=999)      # cpus is NOT comparable
    mism = fingerprint_mismatches(fp, other)
    assert len(mism) == 1 and mism[0].startswith("jax:")


def test_cli_selftest_proves_gate(tmp_path):
    """The CI-blocking selftest: an injected 20% slowdown must trip the
    15% gate (exit 0 = gate fired), and a sub-tolerance injection must
    NOT (exit 1 = selftest correctly reports the gate as blind)."""
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps(
        {"ts": 0, "fingerprint": {}, "metrics": extract_metrics(BENCH)}))
    assert main(["selftest", "--baseline", str(baseline)]) == 0
    assert main(["selftest", "--baseline", str(baseline),
                 "--slowdown", "0.05"]) == 1


def test_cli_check_and_append(tmp_path):
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps(BENCH))
    baseline = tmp_path / "baseline.json"
    history = tmp_path / "hist.jsonl"
    assert main(["rebase", "--bench", str(bench),
                 "--baseline", str(baseline)]) == 0
    # same numbers vs own baseline: clean even under --strict
    assert main(["check", "--bench", str(bench), "--baseline",
                 str(baseline), "--strict"]) == 0
    # regressed bench vs baseline: gate fails
    slow = json.loads(json.dumps(BENCH))
    node = slow["compute_r2_s8"]["4096"]["device"]["phases"]
    node["clients_per_sec"] *= 0.5
    bench.write_text(json.dumps(slow))
    assert main(["check", "--bench", str(bench), "--baseline",
                 str(baseline), "--strict"]) == 1
    # fingerprint mismatch without --strict: advisory skip (exit 0)
    doc = json.loads(baseline.read_text())
    doc["fingerprint"]["jax"] = "0.0.0"
    baseline.write_text(json.dumps(doc))
    assert main(["check", "--bench", str(bench), "--baseline",
                 str(baseline)]) == 0
    # history rows accumulate with fingerprints
    assert main(["append", "--bench", str(bench), "--history",
                 str(history), "--note", "t"]) == 0
    assert main(["append", "--bench", str(bench), "--history",
                 str(history)]) == 0
    rows = [json.loads(ln) for ln in
            history.read_text().strip().splitlines()]
    assert len(rows) == 2
    assert rows[0]["note"] == "t"
    assert all(set(r["fingerprint"]) >= set(COMPARABLE_KEYS)
               for r in rows)
