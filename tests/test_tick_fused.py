"""Fused device-tick kernels (repro.kernels.tick_fused): ref-vs-kernel
fire tests on the CPU interpreter (padded and unpadded C / D), the
empty-bucket ``-0.0`` guarded-add hazard, the ``dp_rng`` knob, the
in-kernel-PRNG DP distribution (TPU only), and tick coalescing
(``fuse_ticks``) staying bitwise with the unfused loop."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort import DeviceCohortSimulator
from repro.core import LogRegTask
from repro.data import make_binary_dataset
from repro.kernels.tick_fused import (bucket_apply, tick_deliver,
                                      tick_scatter)


def _task(n=300, d=12, seed=9, sample_seed=21, **kw):
    X, y = make_binary_dataset(n, d, seed=seed, noise=0.3)
    return LogRegTask(X, y, l2=1.0 / n, sample_seed=sample_seed, **kw)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


# --- ref vs interpret-kernel fire tests -------------------------------------

@pytest.mark.parametrize("A", [1, 4])
@pytest.mark.parametrize("D", [8, 10])          # exact vs padded lanes
@pytest.mark.parametrize("flag", [False, True])
def test_bucket_apply_kernel_matches_ref(A, D, flag):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    v, rows = _rand(ks[0], D), _rand(ks[1], A, D)
    dec = jax.random.uniform(ks[2], (A,), jnp.float32)
    ref = bucket_apply(v, rows, dec, flag, use_kernel=False)
    ker = bucket_apply(v, rows, dec, flag, use_kernel=True,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


@pytest.mark.parametrize("C", [5, 8])           # padded vs exact clients
@pytest.mark.parametrize("D", [8, 10])
def test_tick_deliver_kernel_matches_ref(C, D):
    B = 4
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    w, U, bc_v = _rand(ks[0], C, D), _rand(ks[1], C, D), _rand(ks[2], B, D)
    best = jax.random.randint(ks[3], (C,), 0, B)
    take = jnp.asarray([True, False, True, True, False][:C] + [True] * 0)
    take = jnp.resize(take, (C,))
    eta = jnp.linspace(0.05, 0.1, C, dtype=jnp.float32)
    ref = tick_deliver(w, U, bc_v, best, take, eta, use_kernel=False)
    ker = tick_deliver(w, U, bc_v, best, take, eta, use_kernel=True,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


@pytest.mark.parametrize("C", [5, 8])
@pytest.mark.parametrize("D", [8, 10])
@pytest.mark.parametrize("dp_on", [False, True])
def test_tick_scatter_kernel_matches_ref(C, D, dp_on):
    G = 3
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    sent, w, U = (_rand(k, C, D) for k in ks[:3])
    upd = _rand(ks[3], G, D)
    wgt = jax.random.uniform(ks[4], (G, C), jnp.float32)
    # zero out one group's weights entirely (its guarded add must skip)
    wgt = wgt.at[1].set(0.0)
    any_g = jnp.asarray([True, False, True])
    done = jnp.asarray(([True, False] * C)[:C])
    eta = jnp.linspace(0.05, 0.1, C, dtype=jnp.float32)
    ref = tick_scatter(sent, w, U, upd, wgt, any_g, done, eta,
                       dp_on=dp_on, use_kernel=False)
    ker = tick_scatter(sent, w, U, upd, wgt, any_g, done, eta,
                       dp_on=dp_on, use_kernel=True, interpret=True)
    for r, k in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(k))


def test_empty_bucket_negative_zero_preserved():
    """Guarded adds: a flagged-off bucket apply and an empty scatter
    group must pass ``-0.0`` through bit-for-bit (the hazard that makes
    ``where(any(in_l), cur + vec, cur)`` mandatory — an unconditional
    ``+ 0.0`` would flip the sign bit and break host-vs-device parity).
    """
    D = 8
    neg = jnp.full((D,), -0.0, jnp.float32)
    for path in (dict(use_kernel=False),
                 dict(use_kernel=True, interpret=True)):
        out = bucket_apply(neg, jnp.ones((2, D), jnp.float32),
                           jnp.ones((2,), jnp.float32), False, **path)
        assert np.signbit(np.asarray(out)).all(), path
        w_new, u_new, upd_new = tick_scatter(
            jnp.zeros((4, D), jnp.float32), neg[None, :] * jnp.ones((4, 1)),
            jnp.zeros((4, D), jnp.float32), neg[None, :].repeat(2, axis=0),
            jnp.zeros((2, 4), jnp.float32), jnp.asarray([False, False]),
            jnp.zeros((4,), bool), jnp.full((4,), 0.1, jnp.float32),
            dp_on=False, **path)
        assert np.signbit(np.asarray(upd_new)).all(), path
        assert np.signbit(np.asarray(w_new)).all(), path
    # the A == 1 static branch: rows[0] * dec keeps -0.0 where a
    # size-1 jnp.sum would have flipped it to +0.0
    v = jnp.full((D,), -0.0, jnp.float32)
    row = jnp.full((1, D), -0.0, jnp.float32)
    ref = bucket_apply(v, row, jnp.ones((1,), jnp.float32), True,
                       use_kernel=False)
    ker = bucket_apply(v, row, jnp.ones((1,), jnp.float32), True,
                       use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.signbit(np.asarray(ref)),
                                  np.signbit(np.asarray(ker)))


# --- dp_rng knob ------------------------------------------------------------

def test_dp_rng_knob_validation():
    task = _task(dp_clip=0.1, dp_sigma=1.0)
    kw = dict(n_clients=4, sizes_per_client=[2], round_stepsizes=[0.1],
              d=1, seed=0, block=4)
    with pytest.raises(ValueError, match="dp_rng"):
        DeviceCohortSimulator(task, dp_rng="nope", **kw)
    if jax.default_backend() != "tpu":
        with pytest.raises(ValueError, match="TPU"):
            DeviceCohortSimulator(task, dp_rng="in_kernel", **kw)
    else:
        with pytest.raises(ValueError, match="use_dp_kernel"):
            DeviceCohortSimulator(task, dp_rng="in_kernel",
                                  use_dp_kernel=False, **kw)


def test_in_kernel_prng_noise_chi_square():
    """dp_rng='in_kernel' draws standard normals inside the kernel —
    distributionally equivalent to the operand path (chi-square over
    normal-quantile bins), never bitwise.  TPU only by contract."""
    if jax.default_backend() != "tpu":
        pytest.skip("in-kernel PRNG path needs a TPU backend "
                    "(pltpu.prng_random_bits has no CPU/GPU lowering)")
    from repro.kernels.cohort_dp.ops import cohort_clip_noise
    C, D = 64, 512
    u = jnp.zeros((C, D), jnp.float32)
    out, _ = cohort_clip_noise(
        u, jax.random.PRNGKey(5), jnp.ones((C,), jnp.float32),
        jnp.ones((C,), jnp.float32), clip=0.0, noise_scale=1.0,
        use_kernel=True, in_kernel_rng=True)
    s = np.asarray(out).ravel()
    assert abs(s.mean()) < 0.02 and abs(s.std() - 1.0) < 0.02
    edges = np.array([-2.0, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0])
    cdf = np.vectorize(
        lambda x: 0.5 * (1.0 + math.erf(x / math.sqrt(2.0))))
    probs = np.diff(np.concatenate([[0.0], cdf(edges), [1.0]]))
    counts, _ = np.histogram(s, bins=np.concatenate(
        [[-np.inf], edges, [np.inf]]))
    expected = probs * s.size
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    df = len(probs) - 1
    assert chi2 < df + 5.0 * math.sqrt(2.0 * df), (chi2, counts)


# --- tick coalescing --------------------------------------------------------

def test_fuse_ticks_bitwise_and_iter_relations():
    """fuse_ticks=True runs the SAME tick sequence as the unfused loop
    (bitwise state, identical op census) in fewer while_loop iterations;
    the ``iters`` census obeys block_iters <= loop_iters <= ticks <=
    2 * loop_iters, with the unfused loop pinning one tick (and one
    block tick) per iteration."""
    task = _task()
    kw = dict(n_clients=8, sizes_per_client=[1] * 8,
              round_stepsizes=[0.1] * 8, d=1, seed=0, block=4)
    sim_off = DeviceCohortSimulator(task, fuse_ticks=False, **kw)
    res_off = sim_off.run(max_rounds=8, eval_every=8)
    sim_on = DeviceCohortSimulator(task, fuse_ticks=True, **kw)
    res_on = sim_on.run(max_rounds=8, eval_every=8)
    np.testing.assert_array_equal(np.asarray(res_off["model"]["w"]),
                                  np.asarray(res_on["model"]["w"]))
    assert float(res_off["model"]["b"]) == float(res_on["model"]["b"])
    tel_off, tel_on = res_off["telemetry"], res_on["telemetry"]
    assert dict(tel_off.ops) == dict(tel_on.ops)
    assert tel_off.ticks == tel_on.ticks
    li_off, bi_off = sim_off.engine.fused_iters
    li_on, bi_on = sim_on.engine.fused_iters
    block_ticks = dict(tel_on.ops)["block_ticks"]
    # unfused: one tick per iteration, block attribution is exact
    assert li_off == tel_off.ticks and bi_off == block_ticks
    # fused: every iteration runs 1-2 ticks and holds <= 1 block tick
    assert bi_on <= li_on <= tel_on.ticks <= 2 * li_on
    assert block_ticks >= bi_on
    # coalescing actually fires on the FedSGD-shaped workload (half of
    # its ticks are overhead-only, so they ride along)
    assert li_on < li_off
