"""Permissible delay functions: monotonicity requirements."""
from repro.core import ConstantDelay, SqrtDelay, Theorem5Delay
from repro.core.delay import t_minus_tau_increasing


def test_theorem5_delay_t_minus_tau_increasing():
    for m, d in [(0, 1), (100, 1), (2900, 2)]:
        tau = Theorem5Delay(m=m, d=d)
        assert t_minus_tau_increasing(tau, 100_000)


def test_sqrt_delay_increasing_and_admissible():
    tau = SqrtDelay(c=1.0)
    assert t_minus_tau_increasing(tau, 100_000)
    # tau(t) <= sqrt(t/ln t) asymptotically
    import math
    for t in (1000, 10_000, 100_000):
        assert tau(t) <= math.sqrt(t / math.log(t)) + 1e-9


def test_constant_delay():
    tau = ConstantDelay(tau0=42.0)
    assert tau(0) == 42.0 and tau(10**6) == 42.0
    assert t_minus_tau_increasing(tau, 10_000)


def test_theorem5_M1_dominates_d():
    tau = Theorem5Delay(m=0, d=3)
    assert tau.M1 >= 4  # >= d+1
