"""DP accountant vs the paper's own worked numbers (Supp. D.3)."""
import math

import pytest

from repro.dp import (Theorem4Constants, delta_from_budget, moments_delta,
                      moments_epsilon, privacy_budget_B, r0_sigma,
                      r_from_r0, select_parameters,
                      sigma_lower_bound_case1, theorem4_simple_B)


# --- fixed points and constants (paper D.3.1) -----------------------------

def test_r0_sigma_paper_vectors():
    assert abs(r0_sigma(3.0, 1.0) - 0.0110) < 3e-4
    assert abs(r0_sigma(5.0, 1.0) - 0.0202) < 3e-4
    assert abs(r0_sigma(8.0, 1.0) - 0.0247) < 3e-4


def test_r0_sigma_requires_min_sigma():
    with pytest.raises(ValueError):
        r0_sigma(1.0)


def test_r_equation16_paper_value():
    r = r_from_r0(1.0 / math.e, 8.0)
    assert abs(r - 5.7460446671129635) < 1e-9


def test_u0_u1_guard():
    with pytest.raises(ValueError):
        r_from_r0(0.36, 1.2)   # sigma too small -> u0 >= 1


@pytest.mark.parametrize("r0,sigma", [
    (8.0, 8.0),     # r0 == sigma: zero denominator
    (9.5, 8.0),     # r0 > sigma: negative denominator, u0/u1 < 0 used to
    #                 slip past the >= 1 guard and return a bogus finite r
    (0.0, 8.0),     # degenerate r0
    (-0.1, 8.0),
])
def test_r_from_r0_rejects_r0_outside_open_interval(r0, sigma):
    """Regression: equation (16) is only defined for 0 < r0 < sigma."""
    with pytest.raises(ValueError, match="0 < r0 < sigma"):
        r_from_r0(r0, sigma)


def test_theorem4_simple_B():
    # B(p=1) = 0.5 * ((sqrt(3)-1)/2 * 3)^(2/3) = 0.53218...
    assert abs(theorem4_simple_B(1.0) - 0.5321797270231777) < 1e-12


def test_example1_Kminus_coefficient():
    # Example 1: K- = 0.8447826585127415 q^{-1/3} N_c at eps=2, p=1
    B = theorem4_simple_B(1.0)
    assert abs(B * 2 ** (2.0 / 3) - 0.8447826585127415) < 1e-10


def test_theorem6_constants_example3():
    c = Theorem4Constants(p=1.0, r0=1.0 / math.e, sigma=8.0, gamma=0.0)
    # K* coefficient: 0.5*(r0/sigma)^2 = 0.0010573069002860367
    assert abs(c.D - 0.0010573069002860367) < 1e-12
    # K- coefficient ~0.1369 (paper Example 3, gamma=0)
    assert abs(c.B - 0.1368988621622339) < 2e-3


# --- parameter selection (paper Examples) ---------------------------------

def test_select_parameters_example3():
    sel = select_parameters(s0c=16, N_c=10_000, p=1.0, epsilon=1.0,
                            sigma=8.0, K=25_000, r0=1.0 / math.e)
    assert abs(sel.T - 195) <= 3
    assert abs(sel.m - 12.1) < 0.5
    assert abs(sel.budget_B - 5.78) < 0.05
    assert sel.delta < 1e-7
    assert 7.5 < sel.round_reduction < 8.5          # 1563 -> ~195
    assert sel.aggregated_noise < sel.aggregated_noise_constant
    # s_{i,c} = 16 + ~1.322 i
    assert sel.sizes[0] in (16, 17)
    slope = (sel.sizes[50] - sel.sizes[0]) / 50.0
    assert 1.2 < slope < 1.5


def test_select_parameters_example5():
    sel = select_parameters(s0c=16, N_c=25_000, p=1.0, epsilon=2.0,
                            sigma=8.0, K=5 * 25_000, r0=1.0 / math.e)
    assert abs(sel.T - 364) <= 6
    assert abs(sel.budget_B - 6.96) < 0.1
    # reduction 7813 -> ~364
    assert 20 < sel.round_reduction < 23
    # aggregated noise 615 -> ~153
    assert sel.aggregated_noise < 0.3 * sel.aggregated_noise_constant


def test_select_parameters_r0sigma_default():
    sel = select_parameters(s0c=16, N_c=10_000, p=1.0, epsilon=1.0,
                            sigma=8.0, K=25_000)
    # with the conservative r0(sigma), K* binds => fewer rounds reduction
    assert sel.binding in ("K-", "K*")
    assert sel.T > 0 and sel.delta < 1.0


def test_budget_roundtrip():
    B = privacy_budget_B(2.0, 1e-5)
    assert abs(delta_from_budget(B, 2.0) - 1e-5) < 1e-12


def test_case1_sigma_bound_monotone_in_gamma():
    lo = sigma_lower_bound_case1(1.0, 1e-6, p=1.0, r0=0.0247, sigma=8.0,
                                 gamma=0.0)
    hi = sigma_lower_bound_case1(1.0, 1e-6, p=1.0, r0=0.0247, sigma=8.0,
                                 gamma=0.1)
    assert hi > lo


# --- numerical moments accountant -----------------------------------------

def test_moments_matches_constant_q_regime():
    """Constant q: eps from moments ~ q sqrt(T log(1/delta)) / sigma scale."""
    sizes = [16] * 500
    eps = moments_epsilon(sizes, 10_000, sigma=4.0, delta=1e-6)
    assert 0.005 < eps < 1.0


@pytest.mark.slow
def test_moments_increasing_beats_constant_for_same_budget():
    """Same K: increasing sizes (fewer rounds) => fewer compositions.

    The paper's claim is about aggregated noise at equal privacy; here we
    check the accountant is coherent: more rounds with smaller q_i gives
    comparable epsilon, and epsilon grows with K for fixed sigma.
    """
    inc = [16 + int(1.322 * i) for i in range(195)]
    eps_inc = moments_epsilon(inc, 10_000, sigma=8.0, delta=5.5e-8)
    assert eps_inc < math.inf
    const = [16] * (sum(inc) // 16)
    eps_const = moments_epsilon(const, 10_000, sigma=8.0, delta=5.5e-8)
    # same grad budget, same sigma: both finite, same order of magnitude
    assert eps_const < math.inf
    assert 0.1 < eps_inc / eps_const < 10.0


def test_moments_delta_decreases_with_sigma():
    sizes = [32] * 100
    d1 = moments_delta(sizes, 10_000, 4.0, epsilon=0.5)
    d2 = moments_delta(sizes, 10_000, 8.0, epsilon=0.5)
    assert d2 < d1


def test_moments_delta_increases_with_rounds():
    d1 = moments_delta([16] * 100, 10_000, 8.0, epsilon=0.5)
    d2 = moments_delta([16] * 1000, 10_000, 8.0, epsilon=0.5)
    assert d2 > d1


def test_plan_dp_fl_roundtrip():
    from repro.dp import compare_constant, plan_dp_fl
    fl, sel = plan_dp_fl(n_clients=5, N_c=10_000, K=25_000, epsilon=1.0,
                         sigma=8.0)
    assert fl.dp.enabled and fl.dp.sigma == 8.0
    assert fl.sample_seq.kind == "power"
    cmpd = compare_constant(sel)
    assert cmpd["rounds"]["reduction"] > 4
    assert cmpd["aggregated_noise"]["reduction"] > 1.5
