"""The async-aggregation zoo: strategy resolution, decay math, the
strategy-invariant message schedule, and host-vs-device bit parity for
every zoo member (incl. DP and stochastic scenario presets)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort import CohortSimulator, DeviceCohortSimulator
from repro.configs.base import FLConfig
from repro.cohort.simulator import make_simulator
from repro.core import (AsyncFLSimulator, FedAsyncStrategy,
                        FedBuffStrategy, LogRegTask, PaperStrategy,
                        get_strategy)
from repro.data import make_binary_dataset


# --- resolution ---------------------------------------------------------------

def test_get_strategy_resolution():
    assert isinstance(get_strategy(None), PaperStrategy)
    assert get_strategy(None).kind == "paper"
    assert isinstance(get_strategy("fedasync"), FedAsyncStrategy)
    s = get_strategy({"kind": "fedbuff", "buffer_size": 7})
    assert isinstance(s, FedBuffStrategy) and s.buffer_size == 7
    inst = FedAsyncStrategy(alpha=0.3, decay="hinge")
    assert get_strategy(inst) is inst
    with pytest.raises(ValueError):
        get_strategy("fedmystery")
    with pytest.raises(TypeError):
        get_strategy(42)
    with pytest.raises(ValueError):
        FedAsyncStrategy(decay="exponential")
    with pytest.raises(ValueError):
        FedBuffStrategy(buffer_size=0)


def test_fingerprints_distinguish_hyperparameters():
    """The device engine keys its compiled-segment cache on these."""
    fps = {get_strategy(s).fingerprint() for s in (
        None, "fedasync", {"kind": "fedasync", "alpha": 0.3},
        {"kind": "fedasync", "decay": "hinge"}, "fedbuff",
        {"kind": "fedbuff", "buffer_size": 2})}
    assert len(fps) == 6


# --- decay math ---------------------------------------------------------------

@pytest.mark.parametrize("decay", ["constant", "hinge", "poly"])
def test_fedasync_decay_weights_match_scalar_weight(decay):
    """The jnp [R] path (cohort engines) and the Python-float path
    (event simulator) are the same function of tau."""
    strat = FedAsyncStrategy(decay=decay, hinge_b=2)
    tau = jnp.arange(8, dtype=jnp.int32)
    vec = np.asarray(strat.decay_weights(tau))
    ref = np.asarray([strat.weight(t) for t in range(8)], np.float32)
    np.testing.assert_allclose(vec, ref, rtol=1e-6)
    assert vec.dtype == np.float32


def test_fedasync_decay_monotone_in_staleness():
    for decay in ("hinge", "poly"):
        strat = FedAsyncStrategy(decay=decay, hinge_b=1)
        w = [strat.weight(t) for t in range(6)]
        assert all(a >= b for a, b in zip(w, w[1:]))
        assert w[0] == pytest.approx(strat.alpha)


# --- engine behavior ----------------------------------------------------------

def _task(**kw):
    X, y = make_binary_dataset(300, 12, seed=9, noise=0.3)
    return LogRegTask(X, y, l2=1.0 / 300, sample_seed=21, **kw)


_KW = dict(n_clients=5, sizes_per_client=[4, 6, 8],
           round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=3, block=4,
           speeds=[1.0, 0.6, 1.4, 0.8, 1.1])

ZOO = [None, "fedasync", {"kind": "fedasync", "decay": "hinge"},
       {"kind": "fedasync", "decay": "constant"},
       {"kind": "fedbuff", "buffer_size": 3}]
_IDS = ["paper", "fedasync-poly", "fedasync-hinge", "fedasync-const",
        "fedbuff3"]


def test_event_sim_strategies_share_message_schedule():
    """Everything except the v-application is strategy-invariant: under
    one seed the zoo sees the exact same message/broadcast schedule,
    and the strategies differ only in the model they produce."""
    finals, models = [], []
    for spec in (None, "fedasync", {"kind": "fedbuff", "buffer_size": 3}):
        res = AsyncFLSimulator(
            _task(), n_clients=4, sizes_per_client=[4, 6, 8],
            round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=3,
            speeds=[1.0, 0.8, 1.2, 0.9],
            strategy=spec).run(max_rounds=3)
        finals.append((res["final"]["round"], res["final"]["messages"],
                       res["final"]["broadcasts"]))
        models.append(np.asarray(res["model"]["w"]))
    assert finals[0] == finals[1] == finals[2]
    assert not np.array_equal(models[0], models[1])
    assert not np.array_equal(models[0], models[2])


@pytest.mark.parametrize("spec", ZOO, ids=_IDS)
def test_zoo_host_vs_device_bitwise(spec):
    """Every zoo member holds the repo's flagship contract: the host
    cohort loop and the device-resident loop produce bit-identical
    models (same jnp expressions on the same operands)."""
    res_co = CohortSimulator(_task(), strategy=spec,
                             **_KW).run(max_rounds=3)
    res_dv = DeviceCohortSimulator(_task(), strategy=spec,
                                   **_KW).run(max_rounds=3)
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]
    assert res_co["final"]["broadcasts"] == res_dv["final"]["broadcasts"]


@pytest.mark.parametrize("spec,scenario", [
    ("fedasync", "mobile_diurnal"),
    ({"kind": "fedbuff", "buffer_size": 3}, "iot_straggler"),
], ids=["fedasync+dp+diurnal", "fedbuff+dp+straggler"])
def test_zoo_bitwise_parity_with_dp_and_stochastic_preset(spec, scenario):
    """DP noise (fused kernel), round clip, and a stochastic scenario
    preset preserve host<->device bit parity on the new strategies."""
    kw = dict(_KW, dp_round_clip=0.5, scenario=scenario)
    task_kw = dict(dp_clip=0.1, dp_sigma=2.0)
    res_co = CohortSimulator(_task(**task_kw), strategy=spec,
                             **kw).run(max_rounds=3)
    res_dv = DeviceCohortSimulator(_task(**task_kw), strategy=spec,
                                   **kw).run(max_rounds=3)
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]


def test_strategy_census_is_invariant_on_host_engine():
    """The telemetry census (participation, staleness histogram, bytes)
    is identical across strategies under one seed — the zoo changes how
    arrivals hit v, never which arrivals happen."""
    reports = []
    for spec in (None, "fedasync", {"kind": "fedbuff", "buffer_size": 3}):
        res = CohortSimulator(_task(), strategy=spec,
                              **_KW).run(max_rounds=3)
        reports.append(res["telemetry"])
    a = reports[0]
    for b in reports[1:]:
        assert list(a.participation) == list(b.participation)
        assert list(a.staleness_hist) == list(b.staleness_hist)
        assert int(a.bytes_up.sum()) == int(b.bytes_up.sum())


def test_fedbuff_event_server_flushes_every_buffer_size():
    """Direct Server-level check of the banked-apply semantics: v moves
    only on every buffer_size-th received update."""
    from repro.core.protocol import Server, UpdateMsg
    srv = Server({"w": jnp.zeros((2,))}, n_clients=3,
                 round_stepsizes=[1.0], strategy=FedBuffStrategy(2))
    U = {"w": jnp.ones((2,))}
    srv.receive(UpdateMsg(0, 0, U))
    np.testing.assert_array_equal(np.asarray(srv.v["w"]), 0.0)  # banked
    srv.receive(UpdateMsg(0, 1, U))
    np.testing.assert_array_equal(np.asarray(srv.v["w"]), -2.0)  # flush
    srv.receive(UpdateMsg(0, 2, U))
    np.testing.assert_array_equal(np.asarray(srv.v["w"]), -2.0)  # banked


def test_flconfig_aggregation_reaches_all_engines():
    cfg_kw = dict(n_clients=4, sizes_per_client=[4, 6],
                  round_stepsizes=[0.1, 0.08], d=1, seed=0)
    for engine in ("event", "cohort", "device"):
        cfg = FLConfig(engine=engine, cohort_block=4,
                       aggregation="fedasync")
        sim = make_simulator(cfg, _task(), **cfg_kw)
        target = sim if engine == "event" else sim.engine
        strat = (target.server.strategy if engine == "event"
                 else target.strategy)
        assert strat.kind == "fedasync"
