"""Sample-size sequences: recipes, condition (3), T ~ sqrt(K)."""
import math

import pytest

from repro.configs.base import SampleSequenceConfig
from repro.core import (ConstantDelay, SqrtDelay, Theorem5Delay,
                        communication_rounds_vs_constant, lemma1_sequence,
                        rounds_for_budget, sample_sizes,
                        satisfies_condition3)
from repro.core.sequences import cumulative


def test_constant_sequence():
    cfg = SampleSequenceConfig(kind="constant", s0=16)
    assert sample_sizes(cfg, 5) == [16] * 5


def test_linear_sequence_increasing():
    cfg = SampleSequenceConfig(kind="linear", s0=50, a=50.0)
    s = sample_sizes(cfg, 10)
    assert s[0] == 50
    assert all(b > a for a, b in zip(s, s[1:]))


def test_power_sequence_matches_paper_example3():
    # s_{i,c} = ceil(N_c q (i+m)) = 16 + ~1.32 i   (paper Example 3)
    cfg = SampleSequenceConfig(kind="power", p=1.0,
                               q=0.00013216327772100012,
                               m=12.106237281566509, N_c=10_000)
    s = sample_sizes(cfg, 4)
    assert s[0] == 17 or s[0] == 16   # ceil rounding
    diffs = [b - a for a, b in zip(s, s[1:])]
    assert all(1 <= d <= 2 for d in diffs)   # slope 1.32


def test_ilog_sequence_theta_i_over_log():
    cfg = SampleSequenceConfig(kind="ilog", s0=1, m=2900, d=0)
    s = sample_sizes(cfg, 2000)
    assert s[-1] > s[0]
    i = 1999
    z = cfg.m + i + 1
    expected = z / (16 * math.log(z / 2))
    assert abs(s[i] - expected) <= 1.0 + expected * 0.01


def test_rounds_for_budget_covers_K():
    cfg = SampleSequenceConfig(kind="linear", s0=50, a=50.0)
    K = 20_000
    sizes = rounds_for_budget(cfg, K)
    assert sum(sizes) >= K
    assert sum(sizes[:-1]) < K


def test_T_scales_like_sqrt_K():
    """The headline claim: T ~ sqrt(K) for linear sample-size growth."""
    cfg = SampleSequenceConfig(kind="linear", s0=1, a=1.0)
    t1 = len(rounds_for_budget(cfg, 10_000))
    t4 = len(rounds_for_budget(cfg, 40_000))
    ratio = t4 / t1
    assert 1.8 < ratio < 2.2    # 4x budget => ~2x rounds


def test_communication_reduction_report():
    cfg = SampleSequenceConfig(kind="linear", s0=16, a=1.322)
    rep = communication_rounds_vs_constant(cfg, 25_000)
    assert rep["T_constant"] == math.ceil(25_000 / 16)
    assert rep["reduction"] > 4.0


def test_lemma1_sequence_satisfies_condition3():
    d = 1
    m = 0
    seq = lemma1_sequence(400, g=2.0, m=m, d=d)
    tau = Theorem5Delay(m=m, d=d)
    assert satisfies_condition3(seq, tau, d)


def test_theorem5_ilog_respects_its_delay():
    d = 1
    m = 2 * (d + 1) * 1450      # paper: s_0 = 50 example
    cfg = SampleSequenceConfig(kind="ilog", s0=50, m=m, d=d)
    sizes = sample_sizes(cfg, 300)
    tau = Theorem5Delay(m=m, d=d)
    assert satisfies_condition3(sizes, tau, d)


def test_condition3_fails_for_too_aggressive_growth():
    # doubling sizes grow much faster than tau ~ sqrt => must violate (3)
    sizes = [2 ** i for i in range(1, 25)]
    tau = SqrtDelay(c=1.0)
    assert not satisfies_condition3(sizes, tau, 1)


def test_constant_delay_allows_bounded_sizes():
    sizes = [10] * 100
    tau = ConstantDelay(tau0=25.0)
    assert satisfies_condition3(sizes, tau, 1)     # 2 rounds * 10 <= 25
    assert not satisfies_condition3(sizes, tau, 4) # 5 rounds * 10 > 25


def test_cumulative():
    assert cumulative([1, 2, 3]) == [1, 3, 6]


def test_constant_stepsize_max_sample_size():
    """C.2.1: s <= 1/(eta mu (d+1)) keeps tau within the delay bound."""
    from repro.core.sequences import max_constant_sample_size
    s = max_constant_sample_size(eta=0.01, mu=0.1, d=1)
    assert s == 500
    assert (1 + 1) * s <= 1.0 / (0.01 * 0.1) + 1e-9
    assert max_constant_sample_size(10.0, 10.0, 10) == 1  # floor at 1
