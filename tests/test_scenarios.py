"""Scenario subsystem: latency tables + alias sampling, availability
models, the preset registry, and the unified spec across all three
engines (repro.scenarios)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort import CohortSimulator, DeviceCohortSimulator
from repro.core import AsyncFLSimulator, LogRegTask
from repro.data import make_binary_dataset
from repro.scenarios import (AlwaysOn, Churn, Diurnal, LatencyTable,
                             RegionalChurn, RenewalChurn, Scenario,
                             SpeedModel, TableAssignment, alias_sample,
                             get_scenario, implied_probs, key_uniforms,
                             scenario_from_trace, scenario_names,
                             scenario_plan)


def _task(n=300, d=12, seed=9, sample_seed=21, **kw):
    X, y = make_binary_dataset(n, d, seed=seed, noise=0.3)
    return LogRegTask(X, y, l2=1.0 / n, sample_seed=sample_seed, **kw)


# --- LatencyTable -----------------------------------------------------------

def test_table_validation():
    with pytest.raises(ValueError):
        LatencyTable((), ())
    with pytest.raises(ValueError):
        LatencyTable((1.0, 0.5), (0.5, 0.5))         # not ascending
    with pytest.raises(ValueError):
        LatencyTable((-1.0,), (1.0,))                # non-positive value
    with pytest.raises(ValueError):
        LatencyTable((1.0,), (-1.0,))                # negative prob
    t = LatencyTable((1.0, 2.0), (3.0, 1.0))         # normalizes
    assert t.probs == (0.75, 0.25)


def test_table_constructors_are_distributions():
    tables = [
        LatencyTable.constant(0.5),
        LatencyTable.from_uniform(0.05, 0.1, 8),
        LatencyTable.from_samples([0.1, 0.2, 0.2, 0.9, 1.4], n_bins=4),
        LatencyTable.from_lognormal(0.3, 0.8, 12),
        LatencyTable.from_pareto(0.1, 1.2, 12, q_hi=0.99),
        LatencyTable.mix([LatencyTable.constant(0.1),
                          LatencyTable.constant(1.0)], [0.7, 0.3]),
    ]
    for t in tables:
        assert abs(sum(t.probs) - 1.0) < 1e-12
        assert all(b >= a for a, b in zip(t.values, t.values[1:]))
        assert all(v > 0 for v in t.values)
        # alias decomposition encodes exactly the bin probabilities
        np.testing.assert_allclose(implied_probs(*t.alias_arrays()),
                                   np.asarray(t.probs), atol=1e-7)


def test_table_json_roundtrip_exact():
    t = LatencyTable.from_lognormal(0.3, 0.8, 12)
    assert LatencyTable.from_json(t.to_json()) == t


def test_table_tick_quantization_matches_legacy_rule():
    t = LatencyTable((0.5, 4.0, 4.0001, 9.9), (0.25,) * 4)
    np.testing.assert_array_equal(t.tick_values(dt=4.0), [1, 1, 2, 3])
    assert LatencyTable.constant(5.0).tick_values(dt=4.0) == [2]


def test_table_stats():
    t = LatencyTable((1.0, 3.0), (0.5, 0.5))
    assert t.mean() == 2.0
    assert t.quantile(0.4) == 1.0 and t.quantile(0.9) == 3.0
    assert t.max_s == 3.0


def test_trace_ingestion_json_and_csv(tmp_path):
    samples = list(np.random.default_rng(0).lognormal(-1.0, 0.5, 200))
    pj = tmp_path / "trace.json"
    pj.write_text(json.dumps({"latency_s": samples}))
    pc = tmp_path / "trace.csv"
    pc.write_text("client,latency_s\n"
                  + "\n".join(f"{i % 5},{s}" for i, s in enumerate(samples)))
    tj = LatencyTable.from_trace(str(pj), n_bins=8)
    tc = LatencyTable.from_trace(str(pc), n_bins=8)
    assert tj == tc                       # same samples, same histogram
    assert min(samples) <= tj.mean() <= max(samples)
    # pre-quantized table JSON passes through exactly
    pq = tmp_path / "table.json"
    pq.write_text(tj.to_json())
    assert LatencyTable.from_trace(str(pq)) == tj
    scn = scenario_from_trace(str(pj), name="measured")
    assert scn.name == "measured" and isinstance(scn.availability, AlwaysOn)
    # headerless CSV: first column
    ph = tmp_path / "bare.csv"
    ph.write_text("\n".join(str(s) for s in samples))
    assert LatencyTable.from_trace(str(ph), n_bins=8) == tc
    with pytest.raises(ValueError):
        LatencyTable.from_trace(str(tmp_path / "trace.txt"))
    # a header without latency_s must not silently guess a column
    pb = tmp_path / "bad.csv"
    pb.write_text("client,latency\n1,0.5\n2,0.7\n")
    with pytest.raises(ValueError, match="latency_s"):
        LatencyTable.from_trace(str(pb))


# --- alias sampling on the threefry chain ----------------------------------

def _chi2_bound(df: int, z: float = 5.0) -> float:
    """Normal-approx upper band: chi2_df < df + z * sqrt(2 df)."""
    return df + z * np.sqrt(2.0 * df)


def test_alias_sampling_chi_square_matches_table():
    """On-device alias draws over fold_in keys reproduce the bin
    probabilities (the satellite acceptance test)."""
    t = LatencyTable.from_lognormal(0.3, 0.8, 10)
    prob, alias = (jnp.asarray(a) for a in t.alias_arrays())
    N = 1 << 15
    base = jax.random.PRNGKey(7)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        base, jnp.arange(N))
    j = np.asarray(alias_sample(key_uniforms(keys), prob, alias))
    counts = np.bincount(j, minlength=len(t.probs))
    expected = np.asarray(t.probs) * N
    assert (expected > 5).all()           # chi-square validity
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert chi2 < _chi2_bound(len(t.probs) - 1), (chi2, counts)


def test_update_ticks_deterministic_and_message_addressed():
    """Draws are pure functions of (client, round): recomputing gives
    identical ticks; changing the round changes them."""
    scn = Scenario("s", LatencyTable.from_uniform(1.0, 50.0, 8))
    plan = scenario_plan(scn, C=16, seed=3, dt=1.0)
    i0 = jnp.zeros(16, jnp.int32)
    a = np.asarray(plan.host_update_ticks(i0))
    b = np.asarray(plan.host_update_ticks(i0))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(plan.host_update_ticks(i0 + 1))
    assert (a != c).any()
    assert (a >= 1).all() and (a <= plan.max_lat_ticks).all()
    bc = plan.host_broadcast_ticks(2)
    np.testing.assert_array_equal(bc, plan.host_broadcast_ticks(2))
    assert (bc != plan.host_broadcast_ticks(3)).any()


# --- per-client latency tables ----------------------------------------------

def test_per_client_table_gather_chi_square():
    """Each client's empirical draw distribution matches ITS assigned
    table (the [T, K]-stack + table_id gather), pinned per client by a
    chi-square test over the message-addressed update draws."""
    tA = LatencyTable.from_uniform(1.0, 5.0, 4)
    tB = LatencyTable((10.0, 20.0, 40.0), (0.5, 0.3, 0.2))
    scn = Scenario("pc", (tA, tB),
                   assignment=TableAssignment("explicit",
                                              table_id=(0, 1, 1, 0)))
    plan = scenario_plan(scn, C=4, seed=5)
    N = 1024
    draws = np.stack([plan.update_latencies_s(i) for i in range(N)])
    for c, t in zip(range(4), (tA, tB, tB, tA)):
        vals = np.asarray(t.values, np.float32)
        j = np.argmin(np.abs(draws[:, c][:, None]
                             - vals[None, :].astype(np.float64)), axis=1)
        counts = np.bincount(j, minlength=len(vals))
        expected = np.asarray(t.probs) * N
        assert (expected > 5).all()
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        assert chi2 < _chi2_bound(len(vals) - 1), (c, chi2, counts)


def test_per_client_tables_event_bins_match_cohort_ticks():
    """The event simulator's continuous-seconds draw and the cohort
    engines' tick draw pick the SAME bin for every message, including
    under per-client tables — ticks are exactly the legacy
    max(1, ceil(s / dt)) quantization of the seconds."""
    scn = Scenario("pc2", (LatencyTable.from_uniform(1.0, 50.0, 8),
                           LatencyTable.from_lognormal(4.0, 0.6, 6)))
    dt = 3.0
    pt = scenario_plan(scn, C=6, seed=11, dt=dt)
    ps = scenario_plan(scn, C=6, seed=11)
    for i in range(4):
        iv = jnp.full(6, i, jnp.int32)
        ticks = np.asarray(pt.host_update_ticks(iv))
        secs = ps.update_latencies_s(i)
        np.testing.assert_array_equal(
            ticks, np.maximum(1, np.ceil(secs / dt)).astype(np.int64))
    bc_t = np.asarray(pt.host_broadcast_ticks(2))
    bc_s = ps.broadcast_latencies_s(2)
    np.testing.assert_array_equal(
        bc_t, np.maximum(1, np.ceil(bc_s / dt)).astype(np.int64))


def test_update_latencies_s_batched_matches_scalar_and_caches():
    scn = Scenario("b", LatencyTable.from_uniform(0.5, 3.0, 8))
    plan = scenario_plan(scn, C=8, seed=1)
    vec = plan.update_latencies_s(3)
    for c in range(8):
        assert plan.update_latency_s(c, 3) == vec[c]
    assert plan.update_latencies_s(3) is vec          # cached per round
    assert (plan.update_latencies_s(4) != vec).any()


def test_table_assignment_kinds_and_validation():
    tabs = (LatencyTable.constant(1.0), LatencyTable.constant(2.0),
            LatencyTable.constant(3.0))
    cyc = TableAssignment("cycle").resolve(7, 3, seed=0)
    np.testing.assert_array_equal(cyc, np.arange(7) % 3)
    drawn = TableAssignment("draw").resolve(256, 3, seed=0)
    assert set(drawn) == {0, 1, 2}
    # drawn assignment is deterministic in the seed
    np.testing.assert_array_equal(
        drawn, TableAssignment("draw").resolve(256, 3, seed=0))
    w = TableAssignment("draw", weights=(1.0, 0.0, 0.0)).resolve(
        64, 3, seed=1)
    assert (w == 0).all()
    with pytest.raises(ValueError, match="table_id length"):
        scenario_plan(Scenario(
            "bad", tabs,
            assignment=TableAssignment("explicit", table_id=(0, 1))),
            C=4, seed=0)
    with pytest.raises(ValueError, match="lie in"):
        TableAssignment("explicit", table_id=(0, 3, 1, 0)).resolve(
            4, 3, seed=0)
    with pytest.raises(ValueError, match="one weight per table"):
        TableAssignment("draw", weights=(0.5, 0.5)).resolve(4, 3, seed=0)
    with pytest.raises(ValueError, match="cycle|explicit|draw"):
        TableAssignment("nope")
    with pytest.raises(ValueError, match="table_id"):
        TableAssignment("explicit")


def test_table_assignment_draw_is_threefry_addressed_and_pinned():
    """kind='draw' ids come from the TABLE_SALT threefry chain — the
    jitted derivation equals resolve() (the multi-host prerequisite:
    every host re-derives the ids in-jit from the seed), and the exact
    ids are pinned so the chain never drifts silently."""
    from repro.scenarios.registry import draw_table_ids
    ids = TableAssignment("draw", weights=(0.6, 0.4)).resolve(8, 2,
                                                              seed=3)
    jit_ids = jax.jit(draw_table_ids,
                      static_argnames=("C", "T", "weights"))(
        8, 2, (0.6, 0.4), jnp.int32(3))
    np.testing.assert_array_equal(ids, np.asarray(jit_ids))
    np.testing.assert_array_equal(ids, [0, 0, 0, 0, 1, 1, 0, 0])
    uni = TableAssignment("draw").resolve(12, 3, seed=0)
    np.testing.assert_array_equal(uni, [1, 1, 2, 1, 2, 0, 0, 2, 2, 2,
                                        1, 0])


def test_error_paths_tables_and_legacy_specs(tmp_path):
    from repro.scenarios import legacy_latency_scenario
    with pytest.raises(ValueError, match="0 < lo <= hi"):
        LatencyTable.from_uniform(0.5, 0.1)
    with pytest.raises(ValueError, match="lo <= hi"):
        legacy_latency_scenario((0.5, 0.1))
    with pytest.raises(ValueError, match="positive and finite"):
        LatencyTable.from_samples([0.5, -1.0])
    with pytest.raises(ValueError, match="empty latency trace"):
        LatencyTable.from_samples([])
    pe = tmp_path / "empty.csv"
    pe.write_text("\n")
    with pytest.raises(ValueError, match="empty latency trace"):
        LatencyTable.from_trace(str(pe))
    ph = tmp_path / "only_header.csv"
    ph.write_text("client,latency_s\n")
    with pytest.raises(ValueError, match="empty latency trace"):
        LatencyTable.from_trace(str(ph))
    pz = tmp_path / "zero.json"
    pz.write_text(json.dumps({"values": [1.0, 2.0], "probs": [0.0, 0.0]}))
    with pytest.raises(ValueError, match="sum to > 0"):
        LatencyTable.from_trace(str(pz))
    with pytest.raises(ValueError, match="ring_cap"):
        Scenario("r", LatencyTable.constant(1.0), ring_cap=1)
    with pytest.raises(TypeError, match="LatencyTable"):
        Scenario("t", 3.0)


def test_per_client_trace_ingestion(tmp_path):
    rng = np.random.default_rng(0)
    fast = list(0.05 + 0.05 * rng.random(100))
    slow = list(1.0 + rng.random(100))
    pj = tmp_path / "per_client.json"
    pj.write_text(json.dumps({"clients": {"7": slow, "3": fast}}))
    pc = tmp_path / "per_client.csv"
    pc.write_text("client,latency_s\n"
                  + "\n".join(f"3,{s}" for s in fast)
                  + "\n" + "\n".join(f"7,{s}" for s in slow))
    sj = scenario_from_trace(str(pj), per_client=True, n_bins=4)
    sc = scenario_from_trace(str(pc), per_client=True, n_bins=4)
    assert sj.tables == sc.tables           # ids sort numerically
    assert len(sj.tables) == 2
    assert sj.tables[0].mean() < 0.2 < sj.tables[1].mean()
    assert sj.assignment.kind == "cycle"
    # engine clients alternate tables 0/1 cyclically
    plan = scenario_plan(sj, C=4, seed=0, dt=0.05)
    np.testing.assert_array_equal(plan.table_id, [0, 1, 0, 1])
    assert plan.max_lat_ticks > 1
    pb = tmp_path / "no_client.csv"
    pb.write_text("latency_s\n0.5\n")
    with pytest.raises(ValueError, match="client"):
        scenario_from_trace(str(pb), per_client=True)
    pm = tmp_path / "flat.json"
    pm.write_text(json.dumps([0.1, 0.2]))
    with pytest.raises(ValueError, match="clients"):
        scenario_from_trace(str(pm), per_client=True)


# --- availability models ----------------------------------------------------

def test_diurnal_tick_mask_and_windows_agree_on_duty():
    av = Diurnal(period_s=64.0, on_frac=0.5)
    mask = av.tick_plan(C=8, dt=1.0, seed=0)
    on = np.mean([np.asarray(mask(jnp.int32(t))).mean()
                  for t in range(128)])
    assert abs(on - 0.5) < 0.1
    w = av.windows(C=8, seed=0)
    for c in range(8):
        assert abs(w.on_time(c, 0.0, 640.0) / 640.0 - 0.5) < 1e-6
        # advance() inverts on_time()
        t1 = w.advance(c, 3.0, 10.0)
        assert abs(w.on_time(c, 3.0, t1) - 10.0) < 1e-9


def test_churn_mask_duty_and_validation():
    av = Churn(p_available=0.7, epoch_s=2.0)
    mask = av.tick_plan(C=64, dt=1.0, seed=0)
    on = np.mean([np.asarray(mask(jnp.int32(t))).mean()
                  for t in range(0, 64, 2)])
    assert abs(on - 0.7) < 0.15
    with pytest.raises(ValueError):
        Churn(p_available=0.0)
    with pytest.raises(ValueError):
        Diurnal(on_frac=1.5)


def test_regional_churn_duty_correlation_and_validation():
    """Within-region availability is positively correlated (the shared
    per-(epoch, region) outage factor), cross-region draws stay
    independent, and the marginal duty is the advertised p_available."""
    av = RegionalChurn(n_regions=2, p_available=0.7, p_region_up=0.8,
                       epoch_s=4.0)
    assert av.duty == 0.7
    C, E = 16, 384
    mask = av.tick_plan(C=C, dt=1.0, seed=3)
    reg = av.regions(C)
    # one sample per epoch: draws are independent across epochs
    M = np.stack([np.asarray(mask(jnp.int32(4 * e))) for e in range(E)])
    duty = M.mean()
    assert abs(duty - 0.7) < 0.05
    X = M.astype(np.float64)
    corr = np.corrcoef(X.T)
    same = reg[:, None] == reg[None, :]
    off_diag = ~np.eye(C, dtype=bool)
    within = corr[same & off_diag]
    cross = corr[~same]
    # analytic within-region corr: (p^2/p_reg - p^2) / (p (1-p)) ~ 0.58
    assert within.mean() > 0.3, within.mean()
    assert abs(cross.mean()) < 0.1, cross.mean()
    # explicit region ids + validation
    av2 = RegionalChurn(n_regions=2, region_of=(0, 0, 1, 1))
    np.testing.assert_array_equal(av2.regions(4), [0, 0, 1, 1])
    with pytest.raises(ValueError, match="region_of"):
        av2.regions(3)                       # length mismatch
    with pytest.raises(ValueError, match="lie in"):
        RegionalChurn(n_regions=2, region_of=(0, 5))
    with pytest.raises(ValueError, match="p_region_up"):
        RegionalChurn(p_available=0.9, p_region_up=0.5)


def test_renewal_churn_exact_schedule_duty_and_validation():
    """Path-wise contract: the cohort tick mask and the event sim's
    renewal windows consume the SAME per-(client, epoch) holding times
    from the fold_in chain, so when dt divides the epoch length exactly
    the mask equals the windows state at EVERY tick — an exact-schedule
    assertion, not just the duty chi-square (kept as backstop)."""
    av = RenewalChurn(on_rate=1.0 / 4.0, off_rate=1.0 / 12.0)
    duty = av.duty
    assert abs(duty - 0.75) < 1e-12
    # mean_cycle = 4 + 12 = 16 s, epoch_cycles = 4 -> E_s = 64 s; dt = 1
    # divides it exactly, so tick t and second t share (epoch, offset)
    assert av.epoch_cycles * av.mean_cycle_s == 64.0
    C, E = 32, 64
    mask = av.tick_plan(C=C, dt=1.0, seed=0)
    w = av.windows(C=C, seed=0)
    # exact schedule across three epochs incl. both epoch boundaries
    for t in range(0, 3 * 64 + 1, 3):
        m = np.asarray(mask(jnp.int32(t)))
        ws = np.array([w.on_at(c, float(t)) for c in range(C)])
        np.testing.assert_array_equal(m, ws, err_msg=f"t={t}")
    epoch_t = max(1, round(av.epoch_cycles * av.mean_cycle_s / 1.0))
    # one sample per epoch and client: independent Bernoulli(duty)
    on = sum(int(np.asarray(mask(jnp.int32(e * epoch_t + 3))).sum())
             for e in range(E))
    n = C * E
    exp_on, exp_off = n * duty, n * (1.0 - duty)
    chi2 = ((on - exp_on) ** 2 / exp_on
            + ((n - on) - exp_off) ** 2 / exp_off)
    assert chi2 < _chi2_bound(1), (chi2, on / n)
    # event-side: continuous on-time fraction integrates to the duty
    frac = np.mean([w.on_time(c, 0.0, 4000.0) / 4000.0 for c in range(8)])
    assert abs(frac - duty) < 0.05
    # advance() inverts on_time() across switch AND epoch boundaries
    for (c, t0, work) in [(0, 3.0, 25.0), (1, 0.0, 70.0), (2, 60.0, 5.0)]:
        t1 = w.advance(c, t0, work)
        assert abs(w.on_time(c, t0, t1) - work) < 1e-9, (c, t0, work)
    with pytest.raises(ValueError, match="on_rate"):
        RenewalChurn(on_rate=0.0)
    with pytest.raises(ValueError, match="epoch_cycles"):
        RenewalChurn(epoch_cycles=10.0, n_draws=8)


def test_masked_client_accrues_no_credit_and_sends_no_update():
    """The availability invariant, pinned at the engine level: while a
    client's window is off it takes no step, accrues no credit, and
    sends nothing — the cohort advances without it."""
    task = _task()
    C = 3
    # phases put client 0 OFF at t=0 (its window opens half a period in)
    av = Diurnal(period_s=1024.0, on_frac=0.5)
    scn = Scenario("inv", LatencyTable.constant(1.0), av)
    sim = CohortSimulator(task, n_clients=C, sizes_per_client=[64] * 4,
                          round_stepsizes=[0.1] * 4, d=2, seed=0,
                          block=8, scenario=scn)
    eng = sim.engine
    off0 = ~np.asarray(eng._plan.host_avail(1))
    assert off0.any() and (~off0).any(), "want a mixed on/off fleet"
    for _ in range(8):
        eng.step()
    st = eng.state
    assert (st.h[off0] == 0).all() and (st.credit[off0] == 0).all()
    assert (st.i[off0] == 0).all()
    assert (st.h[~off0] > 0).all() or (st.i[~off0] > 0).all()
    assert eng.total_messages == int(np.sum(st.i[~off0]))


def test_speed_models_normalized_and_long_tailed():
    for kind in ("uniform", "bimodal", "zipf", "lognormal"):
        s = SpeedModel(kind=kind).draw(256, seed=1)
        assert s.shape == (256,) and s.max() == 1.0 and s.min() > 0.0
    z = SpeedModel(kind="zipf", alpha=0.8).draw(256, seed=1)
    assert z.min() < 0.02                 # long tail reaches slow devices
    with pytest.raises(ValueError):
        SpeedModel(kind="nope").draw(4, seed=0)


# --- registry ---------------------------------------------------------------

def test_registry_presets_resolve():
    assert {"uniform", "mobile_diurnal", "iot_straggler",
            "geo_regional", "sensor_renewal"} <= set(scenario_names())
    scn = get_scenario("mobile_diurnal")
    assert get_scenario(scn) is scn       # passthrough
    with pytest.raises(KeyError):
        get_scenario("nope")
    with pytest.raises(TypeError):
        get_scenario(3.0)


@pytest.mark.parametrize("name", ["uniform", "mobile_diurnal",
                                  "iot_straggler"])
def test_presets_run_on_both_cohort_engines_bit_identical(name):
    """Every preset completes on host-cohort and device engines with
    bit-identical trajectories (the tentpole acceptance criterion)."""
    task = _task(sample_seed=5)
    kw = dict(n_clients=6, sizes_per_client=[4, 6], d=2, seed=2,
              round_stepsizes=[0.1, 0.08], block=4, scenario=name)
    res_co = CohortSimulator(task, **kw).run(max_rounds=2)
    res_dv = DeviceCohortSimulator(task, **kw).run(max_rounds=2)
    assert res_co["final"]["round"] == res_dv["final"]["round"] == 2
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])


# --- unified spec across engines -------------------------------------------

def test_three_way_parity_under_stochastic_scenario():
    """d=1 under a stochastic scenario (empirical latency table +
    diurnal availability): host-cohort and device are bit-identical,
    and both match the event simulator's trajectory to float tolerance
    (same argument as the deterministic-latency parity: at d=1 arrival
    timing only reorders float sums)."""
    task = _task(n=500, d=16, seed=7, sample_seed=13)
    scn = Scenario("stoch", LatencyTable.from_lognormal(2.0, 0.7, 8),
                   Diurnal(period_s=64.0, on_frac=0.6))
    kw = dict(n_clients=4, sizes_per_client=[[10, 20, 30, 40]] * 4,
              round_stepsizes=[0.1, 0.08, 0.06, 0.05], d=1, seed=0,
              speeds=[1.0, 0.8, 1.2, 0.9], scenario=scn)
    res_ev = AsyncFLSimulator(task, **kw).run(max_rounds=4)
    res_co = CohortSimulator(task, block=8, **kw).run(max_rounds=4)
    res_dv = DeviceCohortSimulator(task, block=8, **kw).run(max_rounds=4)
    assert (res_ev["final"]["round"] == res_co["final"]["round"]
            == res_dv["final"]["round"] == 4)
    assert (res_ev["final"]["messages"] == res_co["final"]["messages"]
            == res_dv["final"]["messages"])
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    np.testing.assert_allclose(np.asarray(res_ev["model"]["w"]),
                               np.asarray(res_dv["model"]["w"]),
                               atol=1e-4)


def test_stochastic_scenario_parity_with_dp_and_gate():
    """DP noise + round clip + d=2 + churn + multi-tick stochastic
    latency: host-cohort vs device stays bit-identical (extends the
    deterministic-latency DP parity test to stochastic scenarios)."""
    task = _task(dp_clip=0.1, dp_sigma=2.0)
    scn = Scenario("dpchurn", LatencyTable.from_uniform(4.0, 40.0, 6),
                   Churn(p_available=0.8, epoch_s=8.0))
    kw = dict(n_clients=5, sizes_per_client=[4, 6, 8],
              round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=3,
              speeds=[1.0, 0.6, 1.4, 0.8, 1.1], block=4,
              dp_round_clip=0.5, scenario=scn)
    res_co = CohortSimulator(task, **kw).run(max_rounds=3)
    res_dv = DeviceCohortSimulator(task, **kw).run(max_rounds=3)
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]
    assert res_co["final"]["broadcasts"] == res_dv["final"]["broadcasts"]


def test_three_way_parity_per_client_tables_with_dp():
    """Heterogeneity v2 acceptance: per-client latency tables + diurnal
    availability at d=1 — event vs cohort trajectory-equal (at d=1
    arrival timing only reorders float sums), host-cohort vs device
    bitwise, and STILL bitwise once DP noise + round clip are on (DP
    noise chains differ between the event and cohort engines by design,
    so the event leg of the DP comparison is message-count only)."""
    scn = Scenario(
        "pc3", (LatencyTable.from_lognormal(2.0, 0.7, 8),
                LatencyTable.from_uniform(1.0, 20.0, 6)),
        Diurnal(period_s=64.0, on_frac=0.6),
        assignment=TableAssignment("explicit", table_id=(0, 1, 1, 0)))
    kw = dict(n_clients=4, sizes_per_client=[[10, 20, 30]] * 4,
              round_stepsizes=[0.1, 0.08, 0.06], d=1, seed=0,
              speeds=[1.0, 0.8, 1.2, 0.9], scenario=scn)
    task = _task(n=500, d=16, seed=7, sample_seed=13)
    res_ev = AsyncFLSimulator(task, **kw).run(max_rounds=3)
    res_co = CohortSimulator(task, block=8, **kw).run(max_rounds=3)
    res_dv = DeviceCohortSimulator(task, block=8, **kw).run(max_rounds=3)
    assert (res_ev["final"]["messages"] == res_co["final"]["messages"]
            == res_dv["final"]["messages"])
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    np.testing.assert_allclose(np.asarray(res_ev["model"]["w"]),
                               np.asarray(res_dv["model"]["w"]),
                               atol=1e-4)
    # DP leg: same scenario, noise + round clip on — host-vs-device
    # stays bit-identical, messages match the event engine's schedule
    task_dp = _task(n=500, d=16, seed=7, sample_seed=13, dp_clip=0.1,
                    dp_sigma=1.0)
    dp_co = CohortSimulator(task_dp, block=8, dp_round_clip=0.5,
                            **kw).run(max_rounds=3)
    dp_dv = DeviceCohortSimulator(task_dp, block=8, dp_round_clip=0.5,
                                  **kw).run(max_rounds=3)
    np.testing.assert_array_equal(np.asarray(dp_co["model"]["w"]),
                                  np.asarray(dp_dv["model"]["w"]))
    assert float(dp_co["model"]["b"]) == float(dp_dv["model"]["b"])
    assert dp_co["final"]["messages"] == dp_dv["final"]["messages"] \
        == res_ev["final"]["messages"]


def test_regional_churn_parity_with_dp_and_gate():
    """RegionalChurn (correlated outages) + DP + round clip + d=2 +
    multi-tick latency: host-cohort vs device stays bit-identical."""
    task = _task(dp_clip=0.1, dp_sigma=2.0)
    scn = Scenario("regdp", LatencyTable.from_uniform(4.0, 40.0, 6),
                   RegionalChurn(n_regions=2, p_available=0.8,
                                 p_region_up=0.9, epoch_s=8.0))
    kw = dict(n_clients=5, sizes_per_client=[4, 6, 8],
              round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=3,
              speeds=[1.0, 0.6, 1.4, 0.8, 1.1], block=4,
              dp_round_clip=0.5, scenario=scn)
    res_co = CohortSimulator(task, **kw).run(max_rounds=3)
    res_dv = DeviceCohortSimulator(task, **kw).run(max_rounds=3)
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]
    assert res_co["final"]["broadcasts"] == res_dv["final"]["broadcasts"]


def test_renewal_churn_runs_on_all_three_engines():
    """RenewalChurn is the churn model the event simulator ACCEPTS
    (continuous renewal windows in its lazy-advance schedule) — it
    completes the run; the cohort engines run their per-tick
    approximation bit-identically to each other."""
    task = _task(sample_seed=3)
    scn = Scenario("ren", LatencyTable.constant(0.05),
                   RenewalChurn(on_rate=1.0 / 8.0, off_rate=1.0 / 24.0))
    kw = dict(n_clients=4, sizes_per_client=[8, 12],
              round_stepsizes=[0.1, 0.08], d=1, seed=1)
    res_ev = AsyncFLSimulator(task, scenario=scn, **kw).run(max_rounds=2)
    res_co = CohortSimulator(task, block=8, scenario=scn,
                             **kw).run(max_rounds=2)
    res_dv = DeviceCohortSimulator(task, block=8, scenario=scn,
                                   **kw).run(max_rounds=2)
    assert (res_ev["final"]["round"] == res_co["final"]["round"]
            == res_dv["final"]["round"] == 2)
    # off-windows stretch virtual time on every engine
    assert res_ev["final"]["time"] > 0.0
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    # d=1 hard gate: same message count on every engine regardless of
    # which churn sample path each engine realizes
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]


def test_overflow_bucket_bounded_ring_and_parity():
    """Heavy-tail ring cost acceptance: with a latency tail spanning
    far more ticks than Scenario.ring_cap, the device engine's update
    ring (and unrolled scatter) stays bounded at next_pow2(ring_cap)
    while far arrivals route through the overflow bucket — and the
    trajectory stays bit-identical to the host engine, which splits its
    arrival buckets at the same plan boundary."""
    task = _task(dp_clip=0.1, dp_sigma=2.0)
    scn = Scenario("tail", LatencyTable.from_uniform(1.0, 200.0, 16),
                   ring_cap=8)
    kw = dict(n_clients=6, sizes_per_client=[4, 6], d=2, seed=2,
              round_stepsizes=[0.1, 0.08], block=4, dp_round_clip=0.5,
              scenario=scn)
    co = CohortSimulator(task, **kw)
    dv = DeviceCohortSimulator(task, **kw)
    eng = dv.engine
    assert eng.L == 8                        # capped, not next_pow2(51)
    assert eng._plan.max_lat_ticks > eng.L   # tail really exceeds it
    assert eng.F > 0                         # overflow path is active
    res_co = co.run(max_rounds=3)
    res_dv = dv.run(max_rounds=3)
    np.testing.assert_array_equal(np.asarray(res_co["model"]["w"]),
                                  np.asarray(res_dv["model"]["w"]))
    assert float(res_co["model"]["b"]) == float(res_dv["model"]["b"])
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]
    assert res_co["final"]["broadcasts"] == res_dv["final"]["broadcasts"]


def test_event_sim_scenario_speeds_and_diurnal_slowdown():
    """Scenario speeds flow into the event sim when the caller gives
    none, and diurnal off-windows stretch virtual completion time
    without changing the d=1 trajectory or message count."""
    task = _task(sample_seed=3)
    on = Scenario("on", LatencyTable.constant(0.05))
    dn = Scenario("dn", LatencyTable.constant(0.05),
                  Diurnal(period_s=32.0, on_frac=0.5),
                  SpeedModel(kind="bimodal", slow=0.5, slow_frac=0.5))
    kw = dict(n_clients=4, sizes_per_client=[8, 12],
              round_stepsizes=[0.1, 0.08], d=1, seed=1)
    r_on = AsyncFLSimulator(task, scenario=on, **kw).run(max_rounds=2)
    sim = AsyncFLSimulator(task, scenario=dn, **kw)
    assert len(set(sim.speeds)) > 1       # bimodal draw applied
    r_dn = sim.run(max_rounds=2)
    assert r_on["final"]["round"] == r_dn["final"]["round"] == 2
    assert r_on["final"]["messages"] == r_dn["final"]["messages"]
    assert r_dn["final"]["time"] > r_on["final"]["time"]
    np.testing.assert_allclose(np.asarray(r_on["model"]["w"]),
                               np.asarray(r_dn["model"]["w"]), atol=1e-5)


def test_event_sim_rejects_churn_scenario():
    task = _task()
    kw = dict(n_clients=2, sizes_per_client=[2], round_stepsizes=[0.1],
              d=1, seed=0)
    with pytest.raises(ValueError, match="continuous"):
        AsyncFLSimulator(task, scenario="iot_straggler", **kw)
    # regional churn is tick-hash addressed too — rejected the same way
    with pytest.raises(ValueError, match="continuous"):
        AsyncFLSimulator(task, scenario="geo_regional", **kw)


def test_scenario_and_legacy_latency_are_exclusive():
    task = _task()
    kw = dict(n_clients=2, sizes_per_client=[2], round_stepsizes=[0.1],
              d=1, seed=0)
    with pytest.raises(ValueError, match="not both"):
        CohortSimulator(task, scenario="uniform",
                        latency_fn=lambda r: 1.0, **kw)
    with pytest.raises(ValueError, match="not both"):
        DeviceCohortSimulator(task, scenario="uniform", latency=1.0, **kw)
    with pytest.raises(ValueError, match="not both"):
        AsyncFLSimulator(task, scenario="uniform",
                         latency_fn=lambda r: 1.0, **kw)


def test_fl_config_scenario_flows_through_make_simulator():
    from repro.cohort import make_simulator
    from repro.configs.base import FLConfig
    task = _task()
    cfg = FLConfig(engine="device", cohort_block=4,
                   scenario="mobile_diurnal")
    sim = make_simulator(cfg, task, n_clients=4, sizes_per_client=[2],
                         round_stepsizes=[0.1], d=1, seed=0)
    assert sim.engine._plan.scenario.name == "mobile_diurnal"
    res = sim.run(max_rounds=1)
    assert res["final"]["round"] == 1


@pytest.mark.parametrize("engine_cls", [CohortSimulator,
                                        DeviceCohortSimulator])
def test_heavy_latency_tail_no_spurious_stall(engine_cls):
    """Regression (satellite): max_ticks scaled only by speed ratio, so
    a latency tail spanning many ticks per message outlived the budget
    and raised a bogus 'cohort engine stalled' RuntimeError."""
    task = _task(n=200, d=8, seed=5, sample_seed=2)
    scn = Scenario("tail", LatencyTable.constant(400.0))
    res = engine_cls(
        task, n_clients=2, sizes_per_client=[4] * 20,
        round_stepsizes=[0.1] * 20, d=1, seed=0, block=4,
        scenario=scn).run(max_rounds=20, eval_every=20)
    assert res["final"]["round"] == 20
