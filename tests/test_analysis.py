"""Static-analysis suite (repro.analysis): each rule family must fire
on a synthetic violation (the negative tests the ISSUE acceptance
demands) and stay silent on the real repo (CI runs the same pass as a
blocking job with an empty baseline).
"""
import io
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis import NOISE_SALT, REGISTRY
from repro.analysis.base import (Violation, apply_baseline, iter_py_files,
                                 load_baseline, module_name)
from repro.analysis import foldin, prng, purity, salts, structure
from repro.analysis.runner import main, run_analysis
from repro.cohort import CohortSimulator, DeviceCohortSimulator
from repro.core import LogRegTask
from repro.data import make_binary_dataset


def _rules(violations):
    return [v.rule for v in violations]


def _src(code: str) -> str:
    return textwrap.dedent(code)


# --- salt registry -----------------------------------------------------------

def test_registry_values_unique_and_clean():
    values = [s.value for s in REGISTRY.values()]
    assert len(values) == len(set(values))
    assert salts.check_registry() == []
    # the previously ad-hoc salts are now declared
    assert REGISTRY["SPEED_SALT"].value == 0x5BEED
    assert NOISE_SALT == 0x5EED


def test_noise_salt_has_both_engine_sites():
    """One DP chain, two roots BY DESIGN (parity needs identical noise)."""
    s = REGISTRY["NOISE_SALT"]
    assert set(s.sites) == {"repro.cohort.engine", "repro.cohort.device"}


def test_registry_collision_fires(monkeypatch):
    clone = dict(REGISTRY)
    clone["EVIL_SALT"] = salts.Salt("EVIL_SALT", NOISE_SALT,
                                    "collides with the DP chain", ("x",))
    monkeypatch.setattr(salts, "REGISTRY", clone)
    found = salts.check_registry()
    assert _rules(found) == ["PRNG-COLLISION"]
    assert "EVIL_SALT" in found[0].message
    assert "NOISE_SALT" in found[0].message


def test_declare_rejects_duplicate_name(monkeypatch):
    monkeypatch.setattr(salts, "REGISTRY", dict(REGISTRY))
    with pytest.raises(ValueError):
        salts._declare("NOISE_SALT", 0x1, chain="dup", sites=("x",))


# --- PRNG address-space auditor ----------------------------------------------

def test_prng_raw_literal_fires():
    """The PR's motivating case: the ad-hoc 0x5BEED before consolidation."""
    found = prng.check_file("fake/availability.py", _src("""
        import numpy as np
        def draw(seed):
            return np.random.default_rng(seed ^ 0x5BEED)
    """))
    assert _rules(found) == ["PRNG-UNDECLARED"]
    assert "0x5beed" in found[0].message


def test_prng_locally_assigned_salt_fires():
    found = prng.check_file("fake/mod.py", _src("""
        import jax
        MY_SALT = 0x1234
        def key(seed):
            return jax.random.PRNGKey(seed ^ MY_SALT)
    """))
    assert _rules(found) == ["PRNG-LOCAL"]


def test_prng_unknown_salt_name_fires():
    found = prng.check_file("fake/mod.py", _src("""
        from jax.random import PRNGKey
        def key(seed):
            return PRNGKey(seed ^ MYSTERY_SALT)
    """))
    assert _rules(found) == ["PRNG-UNKNOWN"]


def test_prng_wrong_import_origin_fires():
    found = prng.check_file("fake/mod.py", _src("""
        import jax
        from repro.scenarios.registry import LAT_SALT
        def key(seed):
            return jax.random.PRNGKey(seed ^ LAT_SALT)
    """))
    assert _rules(found) == ["PRNG-LOCAL"]
    assert "repro.scenarios.registry" in found[0].message


def test_prng_undeclared_site_fires():
    """NOISE_SALT keyed outside its two engine modules = one salt, two
    meanings — exactly the drift the registry exists to stop."""
    found = prng.check_file("src/repro/scenarios/rogue.py", _src("""
        import jax
        from repro.analysis.salts import NOISE_SALT
        def key(seed):
            return jax.random.PRNGKey(seed ^ NOISE_SALT)
    """))
    assert _rules(found) == ["PRNG-SITE"]
    assert "repro.scenarios.rogue" in found[0].message


def test_prng_declared_site_passes():
    found = prng.check_file("src/repro/cohort/engine.py", _src("""
        import jax
        from repro.analysis.salts import NOISE_SALT
        def key(seed):
            return jax.random.PRNGKey(seed ^ NOISE_SALT)
    """))
    assert found == []


def test_prng_registry_module_attribute_access_passes():
    found = prng.check_file("src/repro/scenarios/availability.py", _src("""
        import numpy as np
        from repro.analysis import salts
        def draw(seed):
            return np.random.default_rng(seed ^ salts.SPEED_SALT)
    """))
    assert found == []


def test_prng_xor_inside_larger_expression_is_audited():
    """RenewalChurn's real pattern: the XOR nested in mix arithmetic."""
    found = prng.check_file("fake/mod.py", _src("""
        import numpy as np
        def draw(seed, c):
            return np.random.default_rng(
                ((seed ^ 0xBAD) * 1_000_003 + c) & 0xFFFFFFFF)
    """))
    assert _rules(found) == ["PRNG-UNDECLARED"]


def test_prng_unsalted_roots_not_audited():
    found = prng.check_file("fake/mod.py", _src("""
        import jax
        import numpy as np
        def keys(seed, step):
            a = jax.random.PRNGKey(seed)
            b = np.random.default_rng(seed * 65_537 + step)
            return a, b
    """))
    assert found == []


# --- fold_in chain discipline -------------------------------------------------

def test_foldin_duplicate_constant_fires():
    found = foldin.check_file("fake/mod.py", _src("""
        import jax
        def keys(seed):
            base = jax.random.PRNGKey(seed ^ LAT_SALT)
            upd = jax.random.fold_in(base, 0)
            bc = jax.random.fold_in(base, 0)
            return upd, bc
    """))
    assert _rules(found) == ["PRNG-FOLDIN-DUP"]
    assert "LAT_SALT" in found[0].message


def test_foldin_const_variable_mix_fires():
    found = foldin.check_file("fake/mod.py", _src("""
        import jax
        def keys(seed, t):
            base = jax.random.PRNGKey(seed ^ LAT_SALT)
            upd = jax.random.fold_in(base, 0)
            return jax.random.fold_in(base, t)
    """))
    assert _rules(found) == ["PRNG-FOLDIN-MIXED"]


def test_foldin_conflicting_variable_addresses_fire():
    """Two different runtime domains folded at the same chain position
    can collide (tick == client aliases the noise streams)."""
    found = foldin.check_file("fake/mod.py", _src("""
        import jax
        def keys(seed, tick, client):
            base = jax.random.PRNGKey(seed ^ NOISE_SALT)
            k1 = jax.random.fold_in(base, tick)
            k2 = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
                base, client)
            return k1, k2
    """))
    assert _rules(found) == ["PRNG-FOLDIN-VAR"]
    assert "tick" in found[0].message and "client" in found[0].message


def test_foldin_parity_twins_and_const_branches_pass():
    """The repo's legitimate shapes: distinct constant branches, then
    IDENTICAL variable folds repeated across eager/jit twins."""
    found = foldin.check_file("fake/mod.py", _src("""
        import jax
        def keys(seed, k, cidx):
            base = jax.random.PRNGKey(seed ^ LAT_SALT)
            upd = jax.random.fold_in(base, 0)
            bc = jax.random.fold_in(base, 1)
            bk_eager = jax.random.fold_in(bc, k)
            bk_jit = jax.random.fold_in(bc, k)
            return jax.vmap(jax.random.fold_in,
                            in_axes=(None, 0))(upd, cidx)
    """))
    assert found == []


def test_foldin_chains_are_scoped_per_toplevel_unit():
    """The same salt may root differently-addressed chains in different
    classes (AVAIL_SALT: ``t // epoch`` in Churn, epoch in Renewal)."""
    found = foldin.check_file("fake/mod.py", _src("""
        import jax
        def markov(seed, t):
            base = jax.random.PRNGKey(seed ^ AVAIL_SALT)
            return jax.random.fold_in(base, t // 8)
        def renewal(seed, e):
            base = jax.random.PRNGKey(seed ^ AVAIL_SALT)
            return jax.random.fold_in(base, e)
    """))
    assert found == []


def test_foldin_unsalted_roots_not_audited():
    found = foldin.check_file("fake/mod.py", _src("""
        import jax
        def keys(seed, tick, client):
            base = jax.random.PRNGKey(seed)
            return (jax.random.fold_in(base, tick),
                    jax.random.fold_in(base, client))
    """))
    assert found == []


def test_foldin_repo_is_clean():
    files = iter_py_files(["src/repro"])
    assert files, "expected repo sources"
    assert foldin.check_files(files) == []


# --- traced-code purity -------------------------------------------------------

def test_purity_np_random_in_jitted_fn_fires():
    found = purity.check_file("fake/mod.py", _src("""
        import jax
        import numpy as np
        @jax.jit
        def step(x):
            return x + np.random.normal()
    """))
    assert _rules(found) == ["PURITY-NPRANDOM"]


def test_purity_branch_on_traced_value_fires():
    found = purity.check_file("fake/mod.py", _src("""
        import jax
        @jax.jit
        def step(x):
            if x > 0:
                return x
            return -x
    """))
    assert _rules(found) == ["PURITY-BRANCH"]


def test_purity_clock_item_coerce_fire():
    found = purity.check_file("fake/mod.py", _src("""
        import time
        import jax
        @jax.jit
        def step(x):
            t = time.perf_counter()
            y = x.item()
            z = float(x)
            return t + y + z
    """))
    assert sorted(_rules(found)) == ["PURITY-CLOCK", "PURITY-COERCE",
                                     "PURITY-ITEM"]


def test_purity_taint_propagates_through_assignment():
    found = purity.check_file("fake/mod.py", _src("""
        import jax
        @jax.jit
        def step(x):
            y = x * 2
            while y < 10:
                y = y + 1
            return y
    """))
    assert _rules(found) == ["PURITY-BRANCH"]


def test_purity_consumer_arg_and_maker_nesting_are_traced():
    found = purity.check_file("fake/mod.py", _src("""
        import jax
        import numpy as np

        def host_setup(n):
            return np.random.default_rng(n)     # host-side: fine

        def run(xs):
            def body(c, x):
                return c, float(x)              # traced via scan
            return jax.lax.scan(body, 0.0, xs)

        def tick_plan(n):
            def mask(t):
                return bool(t)                  # traced by convention
            return mask
    """))
    # host_setup's np.random never fires (host code); the scan body's
    # float() and the tick_plan closure's bool() both do
    assert _rules(found) == ["PURITY-COERCE", "PURITY-COERCE"]
    assert any("body()" in v.message for v in found)
    assert any("mask()" in v.message for v in found)


def test_purity_static_escapes_stay_silent():
    """The four deliberate taint exceptions: static_argnames, cfg.*,
    shape metadata, and is-None / dict-membership tests."""
    found = purity.check_file("fake/mod.py", _src("""
        import functools
        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("use_kernel",))
        def step(cfg, x, lp, window=None, *, use_kernel=True):
            if not use_kernel:
                return x
            b, s = x.shape
            pad = (-s) % 8
            if pad:
                x = jnp.pad(x, ((0, 0), (0, pad)))
            if cfg.family == "ssm":
                x = x * 2
            if window is not None:
                x = x + window
            if "bias" in lp:
                x = x + lp["bias"]
            return x
    """))
    assert found == []


def test_purity_cross_module_closure_fires():
    """check_files follows the module-alias attribute-call idiom
    (``attn.attend_full``-style) and from-imports into other analyzed
    files: impurities in the callee are flagged even though the callee's
    module has no traced roots of its own."""
    root = _src("""
        import jax
        from pkg.models import helper as hm
        from pkg.models.helper import leaf

        @jax.jit
        def step(x, w):
            y = hm.mix(x, w, 4)
            return leaf(y)
    """)
    helper = _src("""
        import numpy as np

        def mix(q, k, width):
            if width > 2:        # static at every call site: clean
                q = q + k
            if q.sum() > 0:      # tainted via call-site seed
                q = -q
            return q

        def leaf(z):
            return z * np.random.rand()
    """)
    srcs = {"pkg/models/root.py": root, "pkg/models/helper.py": helper}
    found = purity.check_files(list(srcs), srcs)
    assert _rules(found) == ["PURITY-BRANCH", "PURITY-NPRANDOM"]
    assert all(v.path == "pkg/models/helper.py" for v in found)
    # the width > 2 branch did NOT fire: call-site seeding keeps static
    # config untainted in the callee
    assert len([v for v in found if v.rule == "PURITY-BRANCH"]) == 1
    # single-file analysis of the caller alone stays silent
    assert purity.check_files(["pkg/models/root.py"],
                              {"pkg/models/root.py": root}) == []


def test_purity_closure_follows_init_reexport():
    """One level of package ``__init__`` re-export resolution."""
    init = "from pkg.models.helper import mix\n"
    helper = _src("""
        def mix(q, k):
            for row in q:        # tainted loop in the callee
                k = k + row
            return k
    """)
    use = _src("""
        import jax
        from pkg.models import mix

        @jax.jit
        def step(x):
            return mix(x, x)
    """)
    srcs = {"pkg/models/__init__.py": init,
            "pkg/models/helper.py": helper,
            "pkg/models/use.py": use}
    found = purity.check_files(list(srcs), srcs)
    assert _rules(found) == ["PURITY-BRANCH"]
    assert found[0].path == "pkg/models/helper.py"


def test_purity_kwonly_constant_default_is_static():
    """Keyword-only params with literal defaults are config knobs —
    branching on them in a traced function stays silent."""
    found = purity.check_file("fake/mod.py", _src("""
        import jax
        @jax.jit
        def step(x, *, window=None, chunk=128):
            if window is not None and chunk > 64:
                x = x[:chunk]
            flag = window is None
            if flag:
                x = x + 1
            return x
    """))
    assert found == []


def test_purity_repo_is_clean():
    files = iter_py_files(["src/repro"])
    assert purity.check_files(files) == []
    assert prng.check_files(files) == []


# --- structural completeness ---------------------------------------------------

def test_struct_missing_pspec_fires():
    found = structure.check_state_coverage(
        ["w", "new_field"], {"w": None})
    assert _rules(found) == ["STRUCT-PSPEC"]
    assert "new_field" in found[0].message


def test_struct_stale_spec_fires():
    found = structure.check_state_coverage(
        ["w"], {"w": None, "renamed_away": None})
    assert _rules(found) == ["STRUCT-STALE"]


def test_struct_dtype_discipline_fires():
    found = structure.check_state_dtypes({
        "w": np.zeros(3, np.float64),       # must be f32
        "k": np.zeros(3, np.int64),         # must be i32
        "flag": np.zeros(3, bool),          # non-numeric-class
        "ok_f": np.zeros(3, np.float32),
        "ok_i": np.zeros(3, np.int32),
    })
    assert sorted(_rules(found)) == ["STRUCT-DTYPE"] * 3
    assert {v.message.split("'")[1] for v in found} == {"w", "k", "flag"}


def test_struct_live_repo_is_complete():
    assert structure.check_cohort_structure() == []


# --- baseline / plumbing --------------------------------------------------------

def test_violation_key_survives_line_drift(tmp_path):
    a = Violation("R", "pkg/f.py", 10, "msg")
    b = Violation("R", "other/f.py", 99, "msg")
    assert a.key() == b.key()
    base = tmp_path / "baseline.txt"
    base.write_text(f"# comment\n{a.key()}\n")
    assert apply_baseline([a, b], load_baseline(str(base))) == []


def test_module_name_derivation():
    assert module_name("src/repro/cohort/engine.py") == "repro.cohort.engine"
    assert module_name("src/repro/analysis/__init__.py") == "repro.analysis"
    assert module_name("scratch.py") == "scratch"


# --- CLI -------------------------------------------------------------------------

def test_cli_clean_file_exits_zero(tmp_path, capsys):
    f = tmp_path / "clean.py"
    f.write_text("x = 1\n")
    assert main(["--no-structure", str(f)]) == 0
    assert "OK" in capsys.readouterr().out


def test_cli_finding_exits_one_and_baseline_suppresses(tmp_path, capsys):
    f = tmp_path / "bad.py"
    f.write_text("import jax\n"
                 "def key(seed):\n"
                 "    return jax.random.PRNGKey(seed ^ 0xBAD)\n")
    assert main(["--no-structure", str(f)]) == 1
    out = capsys.readouterr().out
    assert "PRNG-UNDECLARED" in out and "FAILED" in out
    # baseline: local triage channel (CI ships an empty one)
    all_v, _ = run_analysis([str(f)], structure=False)
    base = tmp_path / "baseline.txt"
    base.write_text("\n".join(v.key() for v in all_v) + "\n")
    assert main(["--no-structure", "--baseline", str(base), str(f)]) == 0
    assert "suppressed" in capsys.readouterr().out


def test_cli_list_salts(capsys):
    assert main(["--list-salts"]) == 0
    out = capsys.readouterr().out
    assert "NOISE_SALT" in out and "repro.cohort.device" in out


def test_cli_repo_pass_is_blocking_contract():
    """The exact invocation CI runs (structure included, no baseline)."""
    all_v, new_v = run_analysis(["src/repro"])
    assert new_v == [] and all_v == []


# --- runtime sanitizers ------------------------------------------------------------

def _task(**kw):
    X, y = make_binary_dataset(120, 6, seed=3, noise=0.3)
    return LogRegTask(X, y, l2=0.01, sample_seed=7, **kw)


def test_device_steady_segments_run_under_transfer_guard():
    """Regression gate for the parity contract's zero-transfer property:
    DeviceCohortEngine.run wraps every steady (post-compile) segment in
    jax.transfer_guard('disallow'), so ANY implicit host<->device
    transfer inside the jitted tick loop now raises instead of silently
    serializing it.  Multiple eval boundaries => multiple guarded
    segments; bitwise host parity pins that guarding changed nothing."""
    kw = dict(n_clients=4, sizes_per_client=[3, 4],
              round_stepsizes=[0.1, 0.08], d=2, seed=4, block=4,
              scenario="uniform")
    r_dv = DeviceCohortSimulator(_task(), **kw).run(max_rounds=4,
                                                    eval_every=1)
    assert len(r_dv["history"]) >= 3          # >= 2 steady segments
    r_co = CohortSimulator(_task(), **kw).run(max_rounds=4, eval_every=1)
    assert r_co["final"]["loss"] == r_dv["final"]["loss"]


def test_rank_promotion_raise_is_active():
    """conftest pins jax_numpy_rank_promotion='raise' suite-wide."""
    assert jax.config.jax_numpy_rank_promotion == "raise"
    with pytest.raises(ValueError, match="rank_promotion"):
        _ = jax.numpy.ones((2, 3)) + jax.numpy.ones((3,))
