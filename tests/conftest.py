import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

# Sanitizer: implicit NumPy rank promotion (rank-1 bias against a rank-3
# activation, etc.) is a silent-wrong-shape hazard under sharding — the
# whole suite runs with it hard-disabled.  src/repro broadcasts explicitly
# (see repro.models.common.expand_rank).
jax.config.update("jax_numpy_rank_promotion", "raise")


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="regenerate the golden-trajectory fixtures under "
             "tests/golden/ instead of asserting against them "
             "(commit the refreshed JSON with the change that moved "
             "the trajectories)")


@pytest.fixture
def regen_golden(request):
    return request.config.getoption("--regen-golden")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
