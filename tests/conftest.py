import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
