"""Protocol state machines + the async simulator (Theorem 1 regime)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AsyncFLSimulator, Client, LogRegTask, Server,
                        UpdateMsg)
from repro.data import make_binary_dataset


def _tiny_task(n=200, d=8, seed=0):
    X, y = make_binary_dataset(n, d, seed=seed)
    return LogRegTask(X, y, l2=1.0 / n)


def test_server_broadcasts_only_when_round_complete():
    task = _tiny_task()
    w0 = task.init_model()
    srv = Server(w0, n_clients=3, round_stepsizes=[0.1, 0.1])
    U = task.zero_update()
    assert srv.receive(UpdateMsg(0, 0, U)) == []
    assert srv.receive(UpdateMsg(0, 1, U)) == []
    bs = srv.receive(UpdateMsg(0, 2, U))
    assert [b.k for b in bs] == [1]


def test_server_handles_out_of_order_rounds():
    """A round-1 update may arrive before round 0 completes (async)."""
    task = _tiny_task()
    w0 = task.init_model()
    srv = Server(w0, n_clients=2, round_stepsizes=[0.1] * 4)
    U = task.zero_update()
    assert srv.receive(UpdateMsg(0, 0, U)) == []
    assert srv.receive(UpdateMsg(1, 0, U)) == []     # client 0 ahead
    bs = srv.receive(UpdateMsg(0, 1, U))             # round 0 now complete
    assert [b.k for b in bs] == [1]
    bs = srv.receive(UpdateMsg(1, 1, U))             # round 1 complete
    assert [b.k for b in bs] == [2]


def test_server_cascades_broadcasts_on_reordered_delivery():
    """Regression: if round k+1's last update arrives before round k's,
    both rounds complete on the same dequeue — the server must emit BOTH
    broadcasts (k and k+1), else wait-gated clients deadlock forever."""
    task = _tiny_task()
    w0 = task.init_model()
    srv = Server(w0, n_clients=2, round_stepsizes=[0.1] * 4)
    U = task.zero_update()
    assert srv.receive(UpdateMsg(0, 0, U)) == []
    assert srv.receive(UpdateMsg(1, 0, U)) == []
    assert srv.receive(UpdateMsg(1, 1, U)) == []     # round 1 full first
    bs = srv.receive(UpdateMsg(0, 1, U))             # completes rounds 0 AND 1
    assert [b.k for b in bs] == [1, 2]
    # the cascade left H clean: a fresh round 2 still needs both clients
    assert srv.receive(UpdateMsg(2, 0, U)) == []
    bs = srv.receive(UpdateMsg(2, 1, U))
    assert [b.k for b in bs] == [3]


def test_server_applies_updates_with_round_stepsize():
    task = _tiny_task()
    w0 = task.init_model()
    srv = Server(w0, n_clients=1, round_stepsizes=[0.5, 0.25])
    U = {"w": jnp.ones(8), "b": jnp.float32(2.0)}
    srv.receive(UpdateMsg(0, 0, U))
    np.testing.assert_allclose(np.asarray(srv.v["w"]),
                               np.asarray(w0["w"]) - 0.5, rtol=1e-6)
    srv.receive(UpdateMsg(1, 0, U))
    np.testing.assert_allclose(np.asarray(srv.v["b"]),
                               np.asarray(w0["b"]) - 0.5 * 2 - 0.25 * 2,
                               rtol=1e-6)


def test_client_gate_blocks_d_rounds_ahead():
    task = _tiny_task()
    w0 = task.init_model()
    cl = Client(0, w0, task, sizes=[4, 4, 4, 4],
                round_stepsizes=[0.1] * 4, d=1, seed=0)
    assert not cl.blocked          # i=0, k=0, d=1
    cl.run(4)
    cl.finish_round()              # i=1
    assert cl.blocked              # i == k + d
    from repro.core import BroadcastMsg
    cl.isr_receive(BroadcastMsg(v=w0, k=1))
    assert not cl.blocked


def test_client_isr_ignores_stale_broadcasts():
    task = _tiny_task()
    w0 = task.init_model()
    cl = Client(0, w0, task, sizes=[2] * 4, round_stepsizes=[0.1] * 4,
                d=2, seed=0)
    from repro.core import BroadcastMsg
    cl.isr_receive(BroadcastMsg(v=w0, k=2))
    assert cl.k == 2
    stale = jax.tree_util.tree_map(lambda a: a + 99.0, w0)
    cl.isr_receive(BroadcastMsg(v=stale, k=1))   # stale: ignored
    assert cl.k == 2
    assert float(jnp.max(jnp.abs(cl.w["w"] - w0["w"]))) < 50.0


def test_client_isr_subtracts_own_partial_round():
    """ISRRECEIVE: w = v - eta_i * U (paper Algorithm 4 line 5)."""
    task = _tiny_task()
    w0 = task.init_model()
    cl = Client(0, w0, task, sizes=[8] * 3, round_stepsizes=[0.3] * 3,
                d=2, seed=0)
    cl.run(4)   # mid-round: U nonzero
    U_before = jax.tree_util.tree_map(lambda a: a.copy(), cl.U)
    from repro.core import BroadcastMsg
    v = jax.tree_util.tree_map(lambda a: a * 0.0, w0)
    cl.isr_receive(BroadcastMsg(v=v, k=1))
    expect = jax.tree_util.tree_map(lambda vv, u: vv - 0.3 * u, v, U_before)
    np.testing.assert_allclose(np.asarray(cl.w["w"]),
                               np.asarray(expect["w"]), rtol=1e-5)


@pytest.mark.parametrize("d", [1, 2])
def test_simulator_invariant_i_minus_k_bounded(d):
    task = _tiny_task()
    sizes = [[4 + i for i in range(12)]] * 3
    etas = [0.05] * 12
    sim = AsyncFLSimulator(task, n_clients=3, sizes_per_client=sizes,
                           round_stepsizes=etas, d=d, seed=1,
                           speeds=[1.0, 0.5, 2.0],
                           latency_fn=lambda r: 0.01 + 0.2 * r.random())
    max_gap = 0

    orig = sim._on_round_complete
    def watched(ev):
        orig(ev)
        nonlocal max_gap
        for cl in sim.clients:
            max_gap = max(max_gap, cl.i - cl.k)
    sim._on_round_complete = watched
    sim.run(max_rounds=10)
    assert max_gap <= d
    assert sim.server.k >= 10


def test_simulator_messages_equal_rounds_times_clients():
    task = _tiny_task()
    sim = AsyncFLSimulator(task, n_clients=4,
                           sizes_per_client=[[3] * 6] * 4,
                           round_stepsizes=[0.05] * 6, d=1, seed=0)
    res = sim.run(max_rounds=6)
    # every client sends exactly one U per round
    assert res["final"]["messages"] >= 6 * 4
    assert res["final"]["broadcasts"] == 6


@pytest.mark.slow
def test_simulator_converges_on_logreg():
    from repro.data import make_binary_dataset
    from repro.configs.base import SampleSequenceConfig, StepSizeConfig
    from repro.core import round_stepsizes, rounds_for_budget
    X, y = make_binary_dataset(1000, 10, seed=3, noise=0.2)
    task = LogRegTask(X, y, l2=1e-3)
    sizes = rounds_for_budget(
        SampleSequenceConfig(kind="linear", s0=50, a=50.0), 10_000)
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001), sizes)
    per_client = [[max(1, s // 4) for s in sizes]] * 4
    sim = AsyncFLSimulator(task, n_clients=4, sizes_per_client=per_client,
                           round_stepsizes=etas, d=1, seed=0)
    res = sim.run(max_rounds=len(sizes))
    assert res["final"]["accuracy"] > 0.9
