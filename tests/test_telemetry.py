"""Telemetry subsystem: the cross-engine counter parity contract, DP
accounting rows, JSONL traces, and profiling (repro.telemetry).

The contract under test (ISSUE 6 acceptance): integer telemetry
counters — per-client participation, bytes-on-wire, the
staleness-at-apply histogram, and the overflow high-water mark — are
bitwise identical between the host and device cohort engines, and
exactly equal to the event simulator's ground truth at d = 1 under
deterministic-compatible scenarios (at d > 1 the event sim applies
updates message-by-message while the cohort engines merge each tick's
arrivals before the cascade, so only the cohort pair is pinned there).
"""
import io
import json
import math

import numpy as np
import pytest

from repro.cohort import CohortSimulator, DeviceCohortSimulator
from repro.core import AsyncFLSimulator, LogRegTask
from repro.data import make_binary_dataset
from repro.dp import moments_epsilon, per_client_accounting
from repro.scenarios import LatencyTable, Scenario
from repro.telemetry import (HEADER_BYTES, OP_NAMES, STALE_BINS,
                             JsonlTraceWriter, MetricsReport, PhaseTimer,
                             SpanRecorder, build_report, check_ops,
                             cost_decomposition, model_flat_dim,
                             participation_sizes, staleness_bin,
                             trace_to_perfetto, update_msg_bytes,
                             validate_trace_events, write_perfetto)


def _task(n=300, d=12, seed=9, sample_seed=21, **kw):
    X, y = make_binary_dataset(n, d, seed=seed, noise=0.3)
    return LogRegTask(X, y, l2=1.0 / n, sample_seed=sample_seed, **kw)


def _counters(report: MetricsReport):
    return dict(messages=report.messages, broadcasts=report.broadcasts,
                participation=list(report.participation),
                bytes_up=list(report.bytes_up),
                bytes_down=list(report.bytes_down),
                staleness_hist=list(report.staleness_hist),
                overflow_hwm=report.overflow_hwm,
                far_messages=report.far_messages)


# --- wire model -------------------------------------------------------------

def test_wire_model_is_engine_invariant():
    task = _task()
    kw = dict(n_clients=4, sizes_per_client=[4, 6],
              round_stepsizes=[0.1, 0.08], d=1, seed=0)
    r_ev = AsyncFLSimulator(task, scenario="uniform", **kw).run(max_rounds=2)
    r_dv = DeviceCohortSimulator(task, scenario="uniform", block=4,
                                 **kw).run(max_rounds=2)
    t_ev, t_dv = r_ev["telemetry"], r_dv["telemetry"]
    # event sim counts pytree scalars, cohort engines use ctask.D == d+1
    assert t_ev.flat_dim == t_dv.flat_dim == 13
    assert t_ev.update_msg_bytes == update_msg_bytes(13) \
        == HEADER_BYTES + 4 * 13
    # per-message byte identity: bytes_up == participation * msg_bytes
    for t in (t_ev, t_dv):
        np.testing.assert_array_equal(
            t.bytes_up, t.participation * t.update_msg_bytes)
        np.testing.assert_array_equal(
            t.bytes_down,
            np.full(t.clients, t.broadcasts * t.broadcast_msg_bytes))


# --- counter parity: event-sim ground truth at d = 1 ------------------------

@pytest.mark.parametrize("preset", ["uniform", "mobile_diurnal"])
def test_counters_match_event_ground_truth(preset):
    """Staleness histogram + bytes-on-wire exactly equal the event sim's
    on presets with a continuous-time form, at the d = 1 hard gate."""
    task = _task()
    kw = dict(n_clients=6, sizes_per_client=[4, 6, 8],
              round_stepsizes=[0.1, 0.08, 0.06], d=1, seed=2)
    r_ev = AsyncFLSimulator(task, scenario=preset, **kw).run(max_rounds=3)
    r_co = CohortSimulator(task, scenario=preset, block=4,
                           **kw).run(max_rounds=3)
    r_dv = DeviceCohortSimulator(task, scenario=preset, block=4,
                                 **kw).run(max_rounds=3)
    want = _counters(r_ev["telemetry"])
    assert _counters(r_co["telemetry"]) == want
    assert _counters(r_dv["telemetry"]) == want
    # d = 1 wait gate: every update applies at zero staleness
    assert want["staleness_hist"][0] == want["messages"] != 0
    assert sum(want["staleness_hist"][1:]) == 0


def test_counters_bitwise_host_vs_device_geo_regional():
    """Host-cohort vs device bitwise on geo_regional (epoch-hash churn —
    no event-sim form) at d = 3 with DP: staleness spreads past bin 0
    and the histograms still agree exactly."""
    task = _task(dp_clip=1.0, dp_sigma=1.5)
    kw = dict(n_clients=8, sizes_per_client=[4, 6, 8],
              round_stepsizes=[0.1, 0.08, 0.06], d=3, seed=5,
              block=4, scenario="geo_regional")
    r_co = CohortSimulator(task, **kw).run(max_rounds=4)
    r_dv = DeviceCohortSimulator(task, **kw).run(max_rounds=4)
    co, dv = _counters(r_co["telemetry"]), _counters(r_dv["telemetry"])
    assert co == dv
    # the d = 3 gate admits staleness >= 1; this seed realizes it, so
    # the test is sensitive to a broken histogram, not vacuous
    assert sum(co["staleness_hist"][1:]) > 0
    # trajectory parity still holds alongside the counters
    assert r_co["final"]["loss"] == r_dv["final"]["loss"]
    # the op census joins the bitwise contract (PR 9)
    assert r_co["telemetry"].ops == r_dv["telemetry"].ops


def test_overflow_hwm_parity_and_run_results():
    """Heavy-tail + small ring_cap routes updates through the far tier:
    the overflow high-water mark and far-message census agree bitwise
    host-vs-device and surface in run() results for ring_cap tuning."""
    task = _task(dp_clip=0.1, dp_sigma=2.0)
    scn = Scenario("tail", LatencyTable.from_uniform(1.0, 200.0, 16),
                   ring_cap=8)
    kw = dict(n_clients=6, sizes_per_client=[4, 6], d=2, seed=2,
              round_stepsizes=[0.1, 0.08], block=4, dp_round_clip=0.5,
              scenario=scn)
    dv = DeviceCohortSimulator(task, **kw)
    assert dv.engine.F > 0                     # far tier active
    r_co = CohortSimulator(task, **kw).run(max_rounds=3)
    r_dv = dv.run(max_rounds=3)
    co, dvc = _counters(r_co["telemetry"]), _counters(r_dv["telemetry"])
    assert co == dvc
    assert dvc["far_messages"] > 0
    assert dvc["overflow_hwm"] > 0
    # surfaced in run() results (ROADMAP carry-over): hwm vs capacity
    assert r_dv["final"]["overflow_hwm"] == dvc["overflow_hwm"]
    assert r_dv["final"]["far_messages"] == dvc["far_messages"]
    assert 0 < r_dv["final"]["overflow_hwm"] \
        <= r_dv["final"]["overflow_slots"] == dv.engine.Q
    assert r_co["final"]["overflow_hwm"] == dvc["overflow_hwm"]
    # far-tier op-census counters agree bitwise and actually fired
    ops = r_dv["telemetry"].ops
    assert r_co["telemetry"].ops == ops
    assert ops["far_groups"] > 0 and ops["far_ticks"] > 0


# --- staleness histogram semantics ------------------------------------------

def test_staleness_bin_clamps_to_last():
    assert staleness_bin(0) == 0
    assert staleness_bin(STALE_BINS - 2) == STALE_BINS - 2
    assert staleness_bin(STALE_BINS - 1) == STALE_BINS - 1
    assert staleness_bin(STALE_BINS + 40) == STALE_BINS - 1


def test_staleness_bounded_by_gate():
    """The wait gate bounds staleness-at-apply by d - 1 on every engine."""
    task = _task()
    d = 3
    kw = dict(n_clients=4, sizes_per_client=[2, 3],
              round_stepsizes=[0.1, 0.08], d=d, seed=1, block=4,
              scenario="uniform")
    r = DeviceCohortSimulator(task, **kw).run(max_rounds=4)
    hist = r["telemetry"].staleness_hist
    assert hist[:d].sum() == hist.sum() != 0


# --- DP accounting ----------------------------------------------------------

def test_per_client_accounting_rows():
    rows = per_client_accounting([[4, 6, 8], [4, 6], [], [4, 6, 8]],
                                 N_c=300, sigma=2.0, delta=1e-5)
    assert [r["client"] for r in rows] == [0, 1, 2, 3]
    assert [r["rounds_contributed"] for r in rows] == [3, 2, 0, 3]
    assert rows[2]["epsilon"] == 0.0           # never participated
    # identical schedules share one bisection -> identical epsilon
    assert rows[0]["epsilon"] == rows[3]["epsilon"]
    # fewer rounds cannot cost more privacy
    assert rows[1]["epsilon"] <= rows[0]["epsilon"]
    # rows agree with a direct accountant call
    want = moments_epsilon([4, 6, 8], 300, 2.0, 1e-5)
    assert rows[0]["epsilon"] == pytest.approx(want)


def test_per_client_accounting_inf_is_none():
    rows = per_client_accounting([[64]], N_c=100, sigma=0.3, delta=1e-9)
    assert rows[0]["epsilon"] is None          # below Lemma 4's regime


def test_participation_sizes_prefix_rule():
    rows = participation_sizes([[4, 6, 8], [5]], [5, 2])
    assert rows[0] == [4, 6, 8, 8, 8]          # last size repeats
    assert rows[1] == [5, 5]


def test_dp_rows_in_engine_reports():
    task = _task(dp_clip=1.0, dp_sigma=2.0)
    kw = dict(n_clients=4, sizes_per_client=[4, 6],
              round_stepsizes=[0.1, 0.08], d=1, seed=0, block=4,
              scenario="uniform")
    r_co = CohortSimulator(task, **kw).run(max_rounds=2)
    r_dv = DeviceCohortSimulator(task, **kw).run(max_rounds=2)
    for r in (r_co, r_dv):
        t = r["telemetry"]
        assert t.dp is not None and len(t.dp) == 4
        for row, did in zip(t.dp, t.participation):
            assert row["rounds_contributed"] == int(did)
            assert row["sigma"] == 2.0
            assert row["epsilon"] is not None and row["epsilon"] > 0
    # same participation => same accounting on both engines
    assert r_co["telemetry"].dp == r_dv["telemetry"].dp
    # no-DP runs carry no accounting rows
    r_plain = DeviceCohortSimulator(_task(), **kw).run(max_rounds=2)
    assert r_plain["telemetry"].dp is None


# --- JSONL traces -----------------------------------------------------------

def test_event_trace_jsonl_roundtrip():
    task = _task()
    buf = io.StringIO()
    kw = dict(n_clients=4, sizes_per_client=[4, 6],
              round_stepsizes=[0.1, 0.08], d=1, seed=0)
    res = AsyncFLSimulator(task, scenario="uniform", trace=buf,
                           **kw).run(max_rounds=2)
    recs = [json.loads(line) for line in
            buf.getvalue().strip().splitlines()]
    kinds = {r["kind"] for r in recs}
    assert {"update_sent", "update_applied", "broadcast_fired",
            "broadcast_applied", "report"} <= kinds
    t = res["telemetry"]
    sent = [r for r in recs if r["kind"] == "update_sent"]
    assert len(sent) == t.messages
    assert all(r["bytes"] == t.update_msg_bytes for r in sent)
    applied = [r for r in recs if r["kind"] == "update_applied"]
    # trace staleness values reproduce the histogram
    hist = np.zeros(STALE_BINS, dtype=np.int64)
    for r in applied:
        hist[staleness_bin(r["staleness"])] += 1
    np.testing.assert_array_equal(hist, t.staleness_hist)
    fired = [r for r in recs if r["kind"] == "broadcast_fired"]
    assert len(fired) == t.broadcasts
    # the final record is the full report
    rep = [r for r in recs if r["kind"] == "report"]
    assert len(rep) == 1 and rep[0]["messages"] == t.messages


@pytest.mark.parametrize("engine", ["cohort", "device"])
def test_cohort_segment_trace(engine, tmp_path):
    task = _task()
    path = tmp_path / f"{engine}.jsonl"
    cls = CohortSimulator if engine == "cohort" else DeviceCohortSimulator
    res = cls(task, n_clients=4, sizes_per_client=[4, 6],
              round_stepsizes=[0.1, 0.08], d=1, seed=0, block=4,
              scenario="uniform", trace=str(path)).run(max_rounds=3,
                                                       eval_every=1)
    recs = [json.loads(line) for line in
            path.read_text().strip().splitlines()]
    segs = [r for r in recs if r["kind"] == "segment"]
    assert len(segs) == len(res["history"])
    assert [s["round"] for s in segs] == \
        [h["round"] for h in res["history"]]
    for s in segs:
        assert s["messages"] >= 0 and len(s["staleness_hist"]) == STALE_BINS
    rep = [r for r in recs if r["kind"] == "report"]
    assert len(rep) == 1
    assert rep[0]["messages"] == res["telemetry"].messages
    assert rep[0]["participation"] == \
        [int(x) for x in res["telemetry"].participation]


# --- report schema / serialization ------------------------------------------

def test_report_to_json_roundtrip():
    rep = build_report(
        engine="host", clients=3, flat_dim=10, rounds=2, messages=6,
        broadcasts=2, participation=np.array([2, 2, 2]),
        bytes_up=np.array([112, 112, 112]),
        staleness_hist=np.zeros(STALE_BINS, np.int64),
        wall={"run": 0.5})
    d = json.loads(rep.to_json())
    assert d["engine"] == "host" and d["clients"] == 3
    assert d["bytes_down"] == [2 * rep.broadcast_msg_bytes] * 3
    assert isinstance(rep.summary(), str) and "rounds=2" in rep.summary()


def test_model_flat_dim_counts_pytree_scalars():
    assert model_flat_dim({"w": np.zeros((3, 4)), "b": np.zeros(())}) == 13


def test_phase_timer_accumulates():
    t = PhaseTimer()
    with t.phase("a"):
        pass
    with t.phase("a"):
        pass
    with t.phase("b"):
        pass
    assert t.counts["a"] == 2 and t.counts["b"] == 1
    d = t.as_dict()
    # seconds per phase plus span counts (SpanRecorder.as_dict)
    assert set(d) == {"a_s", "b_s", "a_n", "b_n"}
    assert all(v >= 0 for v in d.values())
    assert d["a_n"] == 2 and d["b_n"] == 1


def test_engine_reports_carry_wall_phases():
    task = _task()
    kw = dict(n_clients=4, sizes_per_client=[4, 6],
              round_stepsizes=[0.1, 0.08], d=1, seed=0)
    r_dv = DeviceCohortSimulator(task, block=4, scenario="uniform",
                                 **kw).run(max_rounds=2)
    assert "first_segment_s" in r_dv["telemetry"].wall
    r_ev = AsyncFLSimulator(task, scenario="uniform", **kw).run(max_rounds=2)
    assert r_ev["telemetry"].wall["run_s"] > 0


def test_trace_writer_coerces_numpy():
    buf = io.StringIO()
    w = JsonlTraceWriter(buf)
    w.emit("x", a=np.int64(3), b=np.arange(2), c=np.float32(0.5))
    w.close()
    assert json.loads(buf.getvalue()) == \
        {"kind": "x", "a": 3, "b": [0, 1], "c": 0.5}


# --- op census (PR 9) --------------------------------------------------------

@pytest.mark.parametrize("preset,strategy", [
    ("uniform", None),
    ("mobile_diurnal", "fedasync"),
    ("iot_straggler", "fedbuff"),
])
def test_op_census_bitwise_host_vs_device(preset, strategy):
    """The op-census vector joins the bitwise parity contract on DP +
    stochastic presets and every aggregation strategy."""
    task = _task(dp_clip=1.0, dp_sigma=1.5)
    kw = dict(n_clients=6, sizes_per_client=[4, 6, 8],
              round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=3, block=4,
              scenario=preset, strategy=strategy)
    r_co = CohortSimulator(task, **kw).run(max_rounds=3)
    r_dv = DeviceCohortSimulator(task, **kw).run(max_rounds=3)
    co, dv = r_co["telemetry"].ops, r_dv["telemetry"].ops
    assert co == dv
    assert tuple(co) == OP_NAMES
    assert co["ticks"] == r_co["telemetry"].ticks > 0
    assert co["block_ticks"] > 0 and co["complete_ticks"] > 0
    # float trajectory is unperturbed by the counter threading
    assert r_co["final"]["loss"] == r_dv["final"]["loss"]
    # the check_ops relations hold on a real run, on both engines
    for rep in (r_co["telemetry"], r_dv["telemetry"]):
        assert check_ops(rep.ops, messages=rep.messages,
                         broadcasts=rep.broadcasts,
                         far_messages=rep.far_messages,
                         clients=rep.clients, ticks=rep.ticks) == []


def test_check_ops_flags_inconsistencies():
    ops = dict.fromkeys(OP_NAMES, 0)
    ops.update(ticks=10, block_ticks=11)            # gated > ticks
    assert any("block_ticks" in p for p in check_ops(ops))
    ops = dict.fromkeys(OP_NAMES, 0)
    ops.update(ticks=10, complete_ticks=5)
    assert any("complete_ticks" in p
               for p in check_ops(ops, messages=3))
    ops = dict.fromkeys(OP_NAMES, 0)
    ops.update(ticks=10, far_ticks=4, far_groups=2)
    assert any("far_ticks" in p
               for p in check_ops(ops, far_messages=9))


def test_cost_decomposition_roofline_ratio():
    ops = dict.fromkeys(OP_NAMES, 0)
    ops.update(ticks=20, block_ticks=5, ring_scatters=8)
    dec = cost_decomposition(ops, steady_s=2.0)
    assert dec["tick_overhead_ratio"] == pytest.approx(0.75)
    assert dec["ring_scatters_per_tick"] == pytest.approx(0.4)
    assert dec["s_per_tick"] == pytest.approx(0.1)
    assert cost_decomposition({"ticks": 0}) == {}


# --- span recorder + Perfetto export (PR 9) ---------------------------------

def test_span_recorder_tracks_and_trace_events():
    rec = SpanRecorder()
    with rec.phase("steady", seg=1):
        pass
    with rec.phase("steady", seg=2):
        pass
    rec.add("compile", 0.25)
    events = rec.to_trace_events()
    doc = {"traceEvents": events}
    assert validate_trace_events(doc) == []
    slices = [e for e in events if e["ph"] == "X"]
    assert len(slices) == 3
    assert {e["name"] for e in slices} == {"steady", "compile"}
    # re-entrant phases stay on one track, back to back, not stacked
    assert len({(e["pid"], e["tid"]) for e in slices
                if e["name"] == "steady"}) == 1


def test_perfetto_event_trace_has_flows(tmp_path):
    """Event-sim JSONL -> Perfetto: message lifecycles become flow
    events on virtual-protocol time and the doc validates + round-trips
    through json.load."""
    task = _task()
    buf = io.StringIO()
    res = AsyncFLSimulator(task, n_clients=4, sizes_per_client=[4, 6],
                           round_stepsizes=[0.1, 0.08], d=1, seed=0,
                           scenario="uniform", trace=buf).run(max_rounds=2)
    records = [json.loads(line) for line in
               buf.getvalue().strip().splitlines()]
    events = trace_to_perfetto(records)
    out = tmp_path / "trace.json"
    write_perfetto(str(out), events)
    with open(out) as fh:
        doc = json.load(fh)
    assert validate_trace_events(doc) == []
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"s", "f", "i", "M"} <= phs          # flows + instants
    flows = [e for e in doc["traceEvents"] if e["ph"] in ("s", "f")]
    assert len(flows) >= 2 * res["telemetry"].messages


def test_perfetto_device_trace_segments(tmp_path):
    """Device-engine JSONL (segment summaries) -> Perfetto slices on
    the virtual clock, plus the run's wall spans, in one document."""
    task = _task()
    buf = io.StringIO()
    sim = DeviceCohortSimulator(task, n_clients=4, sizes_per_client=[4, 6],
                                round_stepsizes=[0.1, 0.08], d=1, seed=0,
                                block=4, scenario="uniform", trace=buf)
    sim.run(max_rounds=3, eval_every=1)
    records = [json.loads(line) for line in
               buf.getvalue().strip().splitlines()]
    events = trace_to_perfetto(records)
    events += sim.engine.timer.to_trace_events(process="wall")
    # two processes may share builder-less ids; validate separately
    assert validate_trace_events({"traceEvents": events},
                                 check_overlap=False) == []
    seg_slices = [e for e in events
                  if e["ph"] == "X" and e.get("args", {}).get("ops")]
    assert seg_slices, "segment slices should carry op-census args"


def test_write_perfetto_rejects_malformed(tmp_path):
    bad = [{"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0}]
    with pytest.raises(ValueError):
        write_perfetto(str(tmp_path / "bad.json"), bad)


def test_telemetry_cli_capture_and_convert(tmp_path):
    """ONE CLI invocation produces a Perfetto-loadable trace JSON."""
    from repro.telemetry.__main__ import main
    out = tmp_path / "timeline.json"
    jl = tmp_path / "run.jsonl"
    rc = main(["capture", "--engine", "event", "--rounds", "2",
               "--clients", "4", "--out", str(out),
               "--jsonl-out", str(jl)])
    assert rc == 0
    with open(out) as fh:
        doc = json.load(fh)
    assert doc["traceEvents"] and validate_trace_events(doc) == []
    out2 = tmp_path / "converted.json"
    assert main(["convert", str(jl), "--out", str(out2)]) == 0
    with open(out2) as fh:
        doc2 = json.load(fh)
    assert validate_trace_events(doc2) == []
