"""Golden-trajectory regression fixtures for the scenario presets.

Tier-1 parity tests pin engine-vs-engine agreement, which is blind to a
drift that hits all three engines identically (a changed preset, a
reordered reduction, a key-chain edit).  These tests pin ABSOLUTE
eval-loss trajectories of the device engine on the named presets
against committed JSON fixtures (tests/golden/), with tight tolerances.

Each run also exports its JSONL telemetry trace and model-checks it
with ``repro.analysis.invariants`` (the INV-* rule family), so every
golden trajectory — including the FedAsync and FedBuff aggregation
strategies — is replayed against the protocol invariants in CI; the
traces written at regen time are committed under tests/golden/traces/
and replayed as frozen fixtures too.

When a trajectory moves on purpose, regenerate and commit the fixtures:

    PYTHONPATH=src python -m pytest tests/test_golden_trajectories.py \
        --regen-golden
"""
import glob
import json
import os

import numpy as np
import pytest

from repro.analysis.invariants import check_trace
from repro.cohort import DeviceCohortSimulator
from repro.core import LogRegTask
from repro.data import make_binary_dataset

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "golden_trajectories.json")
TRACE_DIR = os.path.join(GOLDEN_DIR, "traces")
D_GATE = 2
# aggregation-strategy specs by fixture tag; the default strategy keeps
# the original preset-keyed fixture entries byte-identical
STRATEGIES = {
    "paper": None,
    "fedasync": "fedasync",
    "fedbuff": {"kind": "fedbuff", "buffer_size": 3},
}
#: (scenario preset, strategy tag) -> fixture key
CASES = [
    ("uniform", "paper"),
    ("mobile_diurnal", "paper"),
    ("iot_straggler", "paper"),
    ("mobile_diurnal", "fedasync"),
    ("iot_straggler", "fedbuff"),
]
# Tight but not bitwise: trajectories are f32 on-device reductions, and
# the fixtures must survive BLAS/XLA build differences across machines.
RTOL, ATOL = 1e-5, 1e-7


def _key(name, strategy):
    return name if strategy == "paper" else f"{name}+{strategy}"


def _run_preset(name, strategy="paper", trace=None):
    X, y = make_binary_dataset(300, 12, seed=9, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / 300, sample_seed=21)
    sim = DeviceCohortSimulator(
        task, n_clients=6, sizes_per_client=[4, 6, 8],
        round_stepsizes=[0.1, 0.08, 0.06], d=D_GATE, seed=2, block=4,
        scenario=name, strategy=STRATEGIES[strategy], trace=trace)
    res = sim.run(max_rounds=3, eval_every=1)
    tel = res["telemetry"]
    return {
        "losses": [float(h["loss"]) for h in res["history"]],
        "final_loss": float(res["final"]["loss"]),
        "rounds": int(res["final"]["round"]),
        "messages": int(res["final"]["messages"]),
        "broadcasts": int(res["final"]["broadcasts"]),
        # telemetry counter totals (repro.telemetry): integer-exact,
        # pinned against silent census drift that parity tests (which
        # compare engines to each other) cannot see
        "participation": [int(x) for x in tel.participation],
        "bytes_up_total": int(tel.bytes_up.sum()),
        "staleness_hist": [int(x) for x in tel.staleness_hist],
        "overflow_hwm": int(tel.overflow_hwm),
        "far_messages": int(tel.far_messages),
        # op census (PR 9): exact per-op totals of the tick loop
        "ops": {k: int(v) for k, v in tel.ops.items()},
    }


def _load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("name,strategy", CASES,
                         ids=[_key(n, s) for n, s in CASES])
def test_golden_trajectory(name, strategy, regen_golden, tmp_path):
    key = _key(name, strategy)
    if regen_golden:
        os.makedirs(TRACE_DIR, exist_ok=True)
        trace = os.path.join(TRACE_DIR, f"{key}.jsonl")
    else:
        trace = str(tmp_path / f"{key}.jsonl")
    got = _run_preset(name, strategy, trace=trace)
    # the exported trace must model-check clean on every golden run —
    # the INV-* replay that pins wait-gate/census behavior of the
    # aggregation strategies, not just their loss trajectories
    assert check_trace(trace, d=D_GATE) == []
    if regen_golden:
        golden = _load_golden() if os.path.exists(GOLDEN_PATH) else {}
        golden[key] = got
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(golden, f, indent=2, sort_keys=True)
        pytest.skip(f"regenerated golden fixture for {key!r}")
    assert os.path.exists(GOLDEN_PATH), (
        "no golden fixtures committed; run with --regen-golden")
    want = _load_golden()[key]
    # protocol and telemetry counts are integers: exact
    for k in ("rounds", "messages", "broadcasts", "participation",
              "bytes_up_total", "staleness_hist", "overflow_hwm",
              "far_messages", "ops"):
        assert got[k] == want[k], (k, got[k], want[k])
    np.testing.assert_allclose(got["losses"], want["losses"],
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got["final_loss"], want["final_loss"],
                               rtol=RTOL, atol=ATOL)


def test_golden_fixture_covers_all_cases():
    """The committed fixture must not silently drop a case."""
    if not os.path.exists(GOLDEN_PATH):
        pytest.skip("fixtures not generated yet")
    assert {_key(n, s) for n, s in CASES} <= set(_load_golden())


def test_committed_traces_replay_clean():
    """Frozen-trace replay: the committed regen-time traces stay clean
    under the CURRENT invariant checker, independent of today's engine
    output — a checker regression or a fixture edit both trip this."""
    traces = sorted(glob.glob(os.path.join(TRACE_DIR, "*.jsonl")))
    if not traces:
        pytest.skip("trace fixtures not generated yet")
    assert {os.path.splitext(os.path.basename(t))[0] for t in traces} \
        >= {_key(n, s) for n, s in CASES}
    for t in traces:
        assert check_trace(t, d=D_GATE) == [], t
