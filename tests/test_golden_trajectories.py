"""Golden-trajectory regression fixtures for the scenario presets.

Tier-1 parity tests pin engine-vs-engine agreement, which is blind to a
drift that hits all three engines identically (a changed preset, a
reordered reduction, a key-chain edit).  These tests pin ABSOLUTE
eval-loss trajectories of the device engine on the named presets
against committed JSON fixtures (tests/golden/), with tight tolerances.

When a trajectory moves on purpose, regenerate and commit the fixture:

    PYTHONPATH=src python -m pytest tests/test_golden_trajectories.py \
        --regen-golden
"""
import json
import os

import numpy as np
import pytest

from repro.cohort import DeviceCohortSimulator
from repro.core import LogRegTask
from repro.data import make_binary_dataset

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "golden_trajectories.json")
PRESETS = ["uniform", "mobile_diurnal", "iot_straggler"]
# Tight but not bitwise: trajectories are f32 on-device reductions, and
# the fixtures must survive BLAS/XLA build differences across machines.
RTOL, ATOL = 1e-5, 1e-7


def _run_preset(name):
    X, y = make_binary_dataset(300, 12, seed=9, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / 300, sample_seed=21)
    sim = DeviceCohortSimulator(
        task, n_clients=6, sizes_per_client=[4, 6, 8],
        round_stepsizes=[0.1, 0.08, 0.06], d=2, seed=2, block=4,
        scenario=name)
    res = sim.run(max_rounds=3, eval_every=1)
    tel = res["telemetry"]
    return {
        "losses": [float(h["loss"]) for h in res["history"]],
        "final_loss": float(res["final"]["loss"]),
        "rounds": int(res["final"]["round"]),
        "messages": int(res["final"]["messages"]),
        "broadcasts": int(res["final"]["broadcasts"]),
        # telemetry counter totals (repro.telemetry): integer-exact,
        # pinned against silent census drift that parity tests (which
        # compare engines to each other) cannot see
        "participation": [int(x) for x in tel.participation],
        "bytes_up_total": int(tel.bytes_up.sum()),
        "staleness_hist": [int(x) for x in tel.staleness_hist],
        "overflow_hwm": int(tel.overflow_hwm),
        "far_messages": int(tel.far_messages),
    }


def _load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("name", PRESETS)
def test_golden_trajectory(name, regen_golden):
    got = _run_preset(name)
    if regen_golden:
        golden = _load_golden() if os.path.exists(GOLDEN_PATH) else {}
        golden[name] = got
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(golden, f, indent=2, sort_keys=True)
        pytest.skip(f"regenerated golden fixture for {name!r}")
    assert os.path.exists(GOLDEN_PATH), (
        "no golden fixtures committed; run with --regen-golden")
    want = _load_golden()[name]
    # protocol and telemetry counts are integers: exact
    for k in ("rounds", "messages", "broadcasts", "participation",
              "bytes_up_total", "staleness_hist", "overflow_hwm",
              "far_messages"):
        assert got[k] == want[k], (k, got[k], want[k])
    np.testing.assert_allclose(got["losses"], want["losses"],
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got["final_loss"], want["final_loss"],
                               rtol=RTOL, atol=ATOL)


def test_golden_fixture_covers_all_presets():
    """The committed fixture must not silently drop a preset."""
    if not os.path.exists(GOLDEN_PATH):
        pytest.skip("fixtures not generated yet")
    assert set(PRESETS) <= set(_load_golden())
