"""Cross-engine parity for MODEL-SCALE cohort tasks: the event simulator
driving ``BatchModelTask`` vs the host ``CohortEngine`` vs the
``DeviceCohortEngine``, all through the flat-params adapter
(``repro.cohort.flat``) and the seed-addressed batcher.

The harness that pins the adapter: eval-loss trajectories agree to tight
tolerance across all three engines under deterministic latency,
flatten/unflatten round-trips are bit-exact, and DP preserves
host-cohort <-> device bit parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort import (CohortBatchModelTask, CohortSimulator,
                          DeviceCohortSimulator, PyTreeFlattener,
                          as_cohort_task)
from repro.configs import get_config, reduced
from repro.core import AsyncFLSimulator, BatchModelTask
from repro.data import FederatedBatcher, SeedAddressedBatcher
from repro.models import init_params


def _tiny(n_layers=1, d_model=32, vocab=64, batch=2, seq=16, **task_kw):
    """Tiny transformer config + a fresh BatchModelTask on it."""
    cfg = reduced(get_config("gemma-2b"), n_layers=n_layers,
                  d_model=d_model, vocab=vocab)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batcher = SeedAddressedBatcher(cfg, batch_size=batch, seq_len=seq,
                                   seed=3)
    return cfg, params, lambda: BatchModelTask(cfg, params, batcher,
                                               **task_kw)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def _assert_trees_equal(t1, t2, *, atol=0.0):
    assert (jax.tree_util.tree_structure(t1)
            == jax.tree_util.tree_structure(t2))
    for a, b in zip(_leaves(t1), _leaves(t2)):
        if atol == 0.0:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=atol, rtol=0)


# --- flat layout ------------------------------------------------------------

def test_flatten_roundtrip_bit_exact_model_params():
    _, params, mk = _tiny()
    ctask = as_cohort_task(mk(), 3)
    assert isinstance(ctask, CohortBatchModelTask)
    vec = ctask.flatten(params)
    assert vec.dtype == jnp.float32
    assert vec.shape == (ctask.D,)
    assert ctask.D == sum(int(np.prod(l.shape)) for l in _leaves(params))
    back = ctask.unflatten(vec)
    for a, b in zip(_leaves(params), _leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
    _assert_trees_equal(params, back)


def test_flattener_mixed_dtypes_roundtrip():
    tree = {"a": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
            "b": (jnp.asarray(3.0, jnp.float16),
                  jnp.arange(5, dtype=jnp.float32))}
    flt = PyTreeFlattener(tree)
    assert flt.D == 2 + 1 + 5
    back = flt.unflatten(flt.flatten(tree))
    for a, b in zip(_leaves(tree), _leaves(back)):
        assert a.dtype == b.dtype
        assert bool(jnp.all(a == b))


def test_flattener_rejects_inexact_dtypes():
    """int/bool (and f64) leaves would silently corrupt through the f32
    round trip (int32 above 2**24 loses bits) — rejected up front."""
    with pytest.raises(TypeError, match="f32"):
        PyTreeFlattener({"i": jnp.arange(3, dtype=jnp.int32)})
    with pytest.raises(TypeError, match="f32"):
        PyTreeFlattener({"b": jnp.zeros((2,), bool)})


def test_adapter_requires_seed_addressed_batcher():
    cfg, params, _ = _tiny()
    host_batcher = FederatedBatcher(cfg, batch_size=2, seq_len=16, seed=0)
    task = BatchModelTask(cfg, params, host_batcher)
    with pytest.raises(TypeError, match="batch_from_key"):
        as_cohort_task(task, 3)


# --- trajectory parity ------------------------------------------------------

KW = dict(n_clients=3, sizes_per_client=[[1, 2, 2]] * 3,
          round_stepsizes=[0.1, 0.08, 0.06], d=1, seed=0,
          speeds=[1.0, 0.8, 1.2])


def test_three_way_model_parity_tiny():
    """Tiny transformer, deterministic-at-1-tick latency: eval-loss
    trajectories agree across event / host-cohort / device engines, the
    two cohort engines are bit-identical, and the event simulator matches
    to float tolerance (vmapped vs per-client compute reorders float
    ops)."""
    _, _, mk = _tiny()
    res_ev = AsyncFLSimulator(mk(), **KW).run(max_rounds=3)
    res_co = CohortSimulator(mk(), block=4, **KW).run(max_rounds=3)
    res_dv = DeviceCohortSimulator(mk(), block=4, **KW).run(max_rounds=3)

    assert (res_ev["final"]["round"] == res_co["final"]["round"]
            == res_dv["final"]["round"] == 3)
    assert (res_ev["final"]["messages"] == res_co["final"]["messages"]
            == res_dv["final"]["messages"])

    # eval-loss trajectories (the metrics probe batch is engine-agnostic)
    ev = [h["loss"] for h in res_ev["history"]]
    co = [h["loss"] for h in res_co["history"]]
    dv = [h["loss"] for h in res_dv["history"]]
    np.testing.assert_allclose(ev, co, rtol=0, atol=5e-6)
    np.testing.assert_allclose(co, dv, rtol=0, atol=5e-6)

    # host-cohort <-> device: bit-for-bit; event <-> cohort: tolerance
    _assert_trees_equal(res_co["model"], res_dv["model"])
    _assert_trees_equal(res_ev["model"], res_co["model"], atol=1e-5)


def test_device_model_dp_bit_parity_with_host_cohort():
    """DP (per-step clip, round noise via the fused kernel, round clip)
    and multi-tick latency preserve host-cohort <-> device bit parity on
    the model-scale adapter."""
    _, _, mk = _tiny(dp_clip=0.5, dp_sigma=1.0)
    kw = dict(n_clients=3, sizes_per_client=[[1, 2]] * 3,
              round_stepsizes=[0.1, 0.08], d=2, seed=5,
              speeds=[1.0, 0.7, 1.3], block=2, dp_round_clip=1.0)
    # dt = 2 / 1.3; a 4-virtual-second latency spans multiple ticks
    res_co = CohortSimulator(mk(), latency_fn=lambda r: 4.0, **kw).run(
        max_rounds=2)
    res_dv = DeviceCohortSimulator(mk(), latency=4.0, **kw).run(
        max_rounds=2)
    _assert_trees_equal(res_co["model"], res_dv["model"])
    assert res_co["final"]["messages"] == res_dv["final"]["messages"]
    assert res_co["final"]["broadcasts"] == res_dv["final"]["broadcasts"]


def test_model_dp_noise_perturbs_model():
    _, _, mk_clean = _tiny()
    _, _, mk_noisy = _tiny(dp_clip=0.5, dp_sigma=2.0)
    kw = dict(n_clients=2, sizes_per_client=[[1, 1]] * 2,
              round_stepsizes=[0.1, 0.08], d=1, seed=0, block=2)
    m0 = CohortSimulator(mk_clean(), **kw).run(max_rounds=2)["model"]
    m1 = CohortSimulator(mk_noisy(), **kw).run(max_rounds=2)["model"]
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(_leaves(m0), _leaves(m1)))
    assert diff > 1e-6


@pytest.mark.slow
def test_three_way_model_parity_larger():
    """Larger config (2 layers, d_model=64, vocab=256, 4 clients,
    heterogeneous growing rounds): same pinning as the tiny case."""
    _, _, mk = _tiny(n_layers=2, d_model=64, vocab=256, batch=2, seq=32)
    kw = dict(n_clients=4, sizes_per_client=[[1, 2, 3, 4]] * 4,
              round_stepsizes=[0.1, 0.08, 0.06, 0.05], d=1, seed=0,
              speeds=[1.0, 0.8, 1.2, 0.9])
    res_ev = AsyncFLSimulator(mk(), **kw).run(max_rounds=4)
    res_co = CohortSimulator(mk(), block=4, **kw).run(max_rounds=4)
    res_dv = DeviceCohortSimulator(mk(), block=4, **kw).run(max_rounds=4)
    assert (res_ev["final"]["round"] == res_co["final"]["round"]
            == res_dv["final"]["round"] == 4)
    _assert_trees_equal(res_co["model"], res_dv["model"])
    _assert_trees_equal(res_ev["model"], res_co["model"], atol=5e-5)
    ev = [h["loss"] for h in res_ev["history"]]
    dv = [h["loss"] for h in res_dv["history"]]
    np.testing.assert_allclose(ev, dv, rtol=0, atol=2e-5)
