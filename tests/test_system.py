"""End-to-end system behaviour: training driver, serving driver, fl_step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DPConfig, FLConfig, RunConfig, get_config, reduced
from repro.core import AsyncFLSimulator, fl_step
from repro.core.tasks import BatchModelTask
from repro.data import FederatedBatcher
from repro.models import init_params, train_loss

# end-to-end driver runs: CI exercises these in the slow job
pytestmark = pytest.mark.slow


def test_fl_train_step_descends_and_matches_protocol():
    """One jitted FL round step: loss finite, params move."""
    cfg = reduced(get_config("gemma-2b"))
    run_cfg = RunConfig(model=cfg)
    step = fl_step.make_train_step(cfg, run_cfg, n_client_shards=1,
                                   client_axis=None)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batcher = FederatedBatcher(cfg, batch_size=2, seq_len=32, seed=0)
    batch = batcher.global_batch(1, 0)
    new_params, _, metrics = jax.jit(step)(
        params, None, batch, jnp.float32(0.01), jax.random.PRNGKey(1))
    assert bool(jnp.isfinite(metrics["loss"]))
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(new_params)))
    assert delta > 0.0


def test_fl_train_step_dp_clips_update():
    cfg = reduced(get_config("gemma-2b"))
    fl = FLConfig(dp=DPConfig(enabled=True, clip_norm=0.01, sigma=0.0))
    run_cfg = RunConfig(model=cfg, fl=fl)
    step = fl_step.make_train_step(cfg, run_cfg, n_client_shards=1,
                                   client_axis=None)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batcher = FederatedBatcher(cfg, batch_size=2, seq_len=32, seed=0)
    batch = batcher.global_batch(1, 0)
    _, _, metrics = jax.jit(step)(params, None, batch, jnp.float32(0.01),
                                  jax.random.PRNGKey(1))
    assert float(metrics["update_norm"]) <= 0.01 * 1.01


def test_async_fl_on_tiny_lm_loss_decreases():
    """The full protocol driving a (tiny) LM: loss should drop."""
    cfg = reduced(get_config("gemma-2b"), n_layers=1, d_model=64)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batcher = FederatedBatcher(cfg, batch_size=4, seq_len=32, seed=0)
    task = BatchModelTask(cfg, params, batcher)
    task.init_model = lambda key=None: params

    sizes = [[1, 1, 2, 2, 3]] * 2
    sim = AsyncFLSimulator(task, n_clients=2, sizes_per_client=sizes,
                           round_stepsizes=[0.5, 0.4, 0.3, 0.25, 0.2],
                           d=1, seed=0)
    loss0 = float(train_loss(cfg, sim.server.v, batcher(0, 0, 0)))
    res = sim.run(max_rounds=5)
    loss1 = float(train_loss(cfg, res["model"], batcher(0, 0, 0)))
    assert loss1 < loss0


def test_serve_driver_runs():
    from repro.launch import serve
    assert serve.main(["--arch", "mamba2-780m", "--reduced",
                       "--batch", "2", "--prompt-len", "8",
                       "--gen", "4"]) == 0


def test_train_driver_runs(tmp_path):
    import os
    from repro.launch import train as train_mod
    ckpt = str(tmp_path / "ck")
    assert train_mod.main(["--arch", "gemma-2b", "--reduced",
                           "--rounds", "3", "--clients", "2",
                           "--batch", "2", "--seq", "32",
                           "--checkpoint", ckpt]) == 0
    assert os.path.exists(os.path.join(ckpt, "global_model.npz"))
