"""DP mechanism: clipping, noise, per-example round computation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dp import (add_gaussian_noise, clip_accumulate, clip_tree,
                      dp_sgd_round, tree_norm)


def test_clip_tree_norm_bound():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5, 5))}
    clipped = clip_tree(tree, 1.0)
    assert float(tree_norm(clipped)) <= 1.0 + 1e-5


def test_clip_tree_noop_below_threshold():
    tree = {"a": jnp.asarray([0.1, 0.1])}
    clipped = clip_tree(tree, 10.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               np.asarray(tree["a"]))


def test_clip_accumulate_each_example_bounded():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (16, 32)) * 5.0,
             "b": jax.random.normal(key, (16,))}
    C = 0.5
    out = clip_accumulate(grads, C)
    # sum of 16 vectors each of norm <= C
    total = jnp.sqrt(jnp.sum(out["w"] ** 2) + out["b"] ** 2)
    assert float(total) <= 16 * C + 1e-4


def test_noise_statistics():
    key = jax.random.PRNGKey(1)
    tree = {"w": jnp.zeros((200, 200))}
    noised = add_gaussian_noise(tree, key, stddev=0.8)
    std = float(jnp.std(noised["w"]))
    assert abs(std - 0.8) < 0.02


def test_dp_sgd_round_matches_manual():
    key = jax.random.PRNGKey(2)
    d = 8
    params = {"w": jnp.zeros((d,))}
    X = jax.random.normal(key, (32, d))
    y = (jax.random.normal(jax.random.fold_in(key, 1), (32,)) > 0) \
        .astype(jnp.float32)

    def loss_fn(p, ex):
        xb, yb = ex
        z = xb @ p["w"]
        return jnp.maximum(z, 0) - z * yb + jnp.log1p(jnp.exp(-jnp.abs(z)))

    C = 0.3
    U, mean_loss = dp_sgd_round(loss_fn, params, (X, y), clip_norm=C,
                                sigma=0.0, rng=key)
    # manual per-example clipped sum
    gs = jax.vmap(lambda ex: jax.grad(loss_fn)(params, ex))((X, y))
    norms = jnp.sqrt(jnp.sum(gs["w"] ** 2, axis=1))
    scale = 1.0 / jnp.maximum(1.0, norms / C)
    manual = jnp.sum(gs["w"] * scale[:, None], axis=0)
    np.testing.assert_allclose(np.asarray(U["w"]), np.asarray(manual),
                               rtol=1e-5)
    assert float(mean_loss) > 0


def test_dp_sgd_round_microbatched_equivalent():
    key = jax.random.PRNGKey(3)
    d = 6
    params = {"w": jnp.ones((d,)) * 0.1}
    X = jax.random.normal(key, (24, d))
    y = jnp.ones((24,))

    def loss_fn(p, ex):
        xb, yb = ex
        return jnp.sum((xb @ p["w"] - yb) ** 2)

    U1, _ = dp_sgd_round(loss_fn, params, (X, y), clip_norm=0.5, sigma=0.0,
                         rng=key)
    U2, _ = dp_sgd_round(loss_fn, params, (X, y), clip_norm=0.5, sigma=0.0,
                         rng=key, microbatch=8)
    np.testing.assert_allclose(np.asarray(U1["w"]), np.asarray(U2["w"]),
                               rtol=1e-5)


# --- round-noise scale: engines and tasks agree on dp_clip * dp_sigma --------

def _chi2_bounds(n: int, var: float, z: float = 5.0):
    """Normal-approx chi-square band: sum(x^2) ~ var * (n +- z*sqrt(2n))."""
    half = z * np.sqrt(2.0 * n)
    return var * (n - half), var * (n + half)


def test_cohort_round_noise_std_matches_spec():
    """The cohort engines add round noise through ``cohort_clip_noise``
    with noise_scale = dp_clip * dp_sigma — for both ``CohortLogRegTask``
    and the flat-params model adapter, which share the op.  Empirical
    per-coordinate variance over many draws sits inside the chi-square
    band around (dp_clip * dp_sigma)^2."""
    from repro.kernels.cohort_dp import cohort_clip_noise
    dp_clip, dp_sigma = 0.5, 2.0
    scale = dp_clip * dp_sigma
    C, D, K = 8, 128, 64
    zeros = jnp.zeros((C, D), jnp.float32)
    wgt = jnp.ones((C,), jnp.float32)
    mask = jnp.ones((C,), bool)
    ss, n = 0.0, 0
    base = jax.random.PRNGKey(7)
    for t in range(K):
        out, _ = cohort_clip_noise(zeros, jax.random.fold_in(base, t),
                                   wgt, mask, clip=0.0,
                                   noise_scale=scale)
        ss += float(jnp.sum(out ** 2))
        n += C * D
    lo, hi = _chi2_bounds(n, scale ** 2)
    assert lo <= ss <= hi, (ss, lo, hi)


def test_task_round_noise_std_matches_cohort_path():
    """``BatchModelTask.add_round_noise`` (the event-engine path for
    model-scale rounds) draws with the same std dp_clip * dp_sigma as the
    cohort engines' fused kernel path, and ``LogRegTask.add_round_noise``
    matches too."""
    from repro.configs import get_config, reduced
    from repro.core import BatchModelTask, LogRegTask
    from repro.data import make_binary_dataset
    dp_clip, dp_sigma = 0.5, 2.0
    var = (dp_clip * dp_sigma) ** 2

    cfg = reduced(get_config("gemma-2b"), n_layers=1, d_model=32)
    template = {"w": jnp.zeros((2048,), jnp.float32)}
    bm = BatchModelTask(cfg, template, lambda *a: None,
                        dp_clip=dp_clip, dp_sigma=dp_sigma)
    X, y = make_binary_dataset(64, 255, seed=0)
    lr = LogRegTask(X, y, dp_clip=dp_clip, dp_sigma=dp_sigma)

    for task, zero_U in ((bm, bm.zero_update()), (lr, lr.zero_update())):
        w0 = jax.tree_util.tree_map(jnp.zeros_like, zero_U)
        ss, n = 0.0, 0
        base = jax.random.PRNGKey(11)
        for t in range(32):
            _, U = task.add_round_noise(w0, zero_U, eta=0.1,
                                        rng=jax.random.fold_in(base, t))
            ss += sum(float(jnp.sum(l.astype(jnp.float32) ** 2))
                      for l in jax.tree_util.tree_leaves(U))
            n += sum(l.size for l in jax.tree_util.tree_leaves(U))
        lo, hi = _chi2_bounds(n, var)
        assert lo <= ss <= hi, (type(task).__name__, ss, lo, hi)


def test_dp_sigma_without_clip_rejected():
    """Regression: dp_sigma > 0 with dp_clip == 0 silently added ZERO
    round noise (std = dp_clip * dp_sigma = 0) — no privacy, no error.
    Now every entry point validates and raises."""
    import pytest
    from repro.cohort import CohortSimulator, DeviceCohortSimulator
    from repro.configs import get_config, reduced
    from repro.core import BatchModelTask, LogRegTask
    from repro.data import make_binary_dataset

    X, y = make_binary_dataset(50, 4, seed=0)
    with pytest.raises(ValueError, match="dp_clip"):
        LogRegTask(X, y, dp_sigma=2.0)
    cfg = reduced(get_config("gemma-2b"), n_layers=1, d_model=32)
    with pytest.raises(ValueError, match="dp_clip"):
        BatchModelTask(cfg, {"w": jnp.zeros((4,))}, lambda *a: None,
                       dp_sigma=2.0)
    # engine-level knobs validate too (simulators forward task knobs, so
    # hit the engines directly with an already-adapted clean task)
    from repro.cohort import as_cohort_task
    from repro.cohort.device import DeviceCohortEngine
    from repro.cohort.engine import CohortEngine
    ctask = as_cohort_task(LogRegTask(X, y, sample_seed=0), 2)
    kw = dict(sizes_per_client=[2], round_stepsizes=[0.1], d=1, seed=0)
    with pytest.raises(ValueError, match="dp_clip"):
        CohortEngine(ctask, dp_sigma=2.0, **kw)
    with pytest.raises(ValueError, match="dp_clip"):
        DeviceCohortEngine(ctask, dp_sigma=2.0, **kw)
