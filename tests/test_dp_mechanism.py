"""DP mechanism: clipping, noise, per-example round computation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dp import (add_gaussian_noise, clip_accumulate, clip_tree,
                      dp_sgd_round, tree_norm)


def test_clip_tree_norm_bound():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5, 5))}
    clipped = clip_tree(tree, 1.0)
    assert float(tree_norm(clipped)) <= 1.0 + 1e-5


def test_clip_tree_noop_below_threshold():
    tree = {"a": jnp.asarray([0.1, 0.1])}
    clipped = clip_tree(tree, 10.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               np.asarray(tree["a"]))


def test_clip_accumulate_each_example_bounded():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (16, 32)) * 5.0,
             "b": jax.random.normal(key, (16,))}
    C = 0.5
    out = clip_accumulate(grads, C)
    # sum of 16 vectors each of norm <= C
    total = jnp.sqrt(jnp.sum(out["w"] ** 2) + out["b"] ** 2)
    assert float(total) <= 16 * C + 1e-4


def test_noise_statistics():
    key = jax.random.PRNGKey(1)
    tree = {"w": jnp.zeros((200, 200))}
    noised = add_gaussian_noise(tree, key, stddev=0.8)
    std = float(jnp.std(noised["w"]))
    assert abs(std - 0.8) < 0.02


def test_dp_sgd_round_matches_manual():
    key = jax.random.PRNGKey(2)
    d = 8
    params = {"w": jnp.zeros((d,))}
    X = jax.random.normal(key, (32, d))
    y = (jax.random.normal(jax.random.fold_in(key, 1), (32,)) > 0) \
        .astype(jnp.float32)

    def loss_fn(p, ex):
        xb, yb = ex
        z = xb @ p["w"]
        return jnp.maximum(z, 0) - z * yb + jnp.log1p(jnp.exp(-jnp.abs(z)))

    C = 0.3
    U, mean_loss = dp_sgd_round(loss_fn, params, (X, y), clip_norm=C,
                                sigma=0.0, rng=key)
    # manual per-example clipped sum
    gs = jax.vmap(lambda ex: jax.grad(loss_fn)(params, ex))((X, y))
    norms = jnp.sqrt(jnp.sum(gs["w"] ** 2, axis=1))
    scale = 1.0 / jnp.maximum(1.0, norms / C)
    manual = jnp.sum(gs["w"] * scale[:, None], axis=0)
    np.testing.assert_allclose(np.asarray(U["w"]), np.asarray(manual),
                               rtol=1e-5)
    assert float(mean_loss) > 0


def test_dp_sgd_round_microbatched_equivalent():
    key = jax.random.PRNGKey(3)
    d = 6
    params = {"w": jnp.ones((d,)) * 0.1}
    X = jax.random.normal(key, (24, d))
    y = jnp.ones((24,))

    def loss_fn(p, ex):
        xb, yb = ex
        return jnp.sum((xb @ p["w"] - yb) ** 2)

    U1, _ = dp_sgd_round(loss_fn, params, (X, y), clip_norm=0.5, sigma=0.0,
                         rng=key)
    U2, _ = dp_sgd_round(loss_fn, params, (X, y), clip_norm=0.5, sigma=0.0,
                         rng=key, microbatch=8)
    np.testing.assert_allclose(np.asarray(U1["w"]), np.asarray(U2["w"]),
                               rtol=1e-5)
