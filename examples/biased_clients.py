"""Biased (label-skewed) client datasets — the paper's Fig 2 regime.

Client 0 holds (almost) only positives, client 1 only negatives; the
asynchronous protocol still converges to the global objective.

    PYTHONPATH=src python examples/biased_clients.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (AsyncFLSimulator, LogRegTask, round_stepsizes,
                        rounds_for_budget)
from repro.data import biased_split, make_binary_dataset, unbiased_split


def run(shards, X, y, label):
    sizes = rounds_for_budget(
        SampleSequenceConfig(kind="linear", s0=100, a=100.0), 6_000)
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.01, beta=0.001), sizes)
    global_task = LogRegTask(X, y, l2=1.0 / len(X))
    sim = AsyncFLSimulator(
        global_task, n_clients=len(shards),
        sizes_per_client=[[max(1, s // len(shards)) for s in sizes]]
        * len(shards),
        round_stepsizes=etas, d=1, seed=0)
    for c, (sx, sy) in enumerate(shards):
        sim.clients[c].task = LogRegTask(sx, sy, l2=1.0 / len(sx))
    res = sim.run(max_rounds=len(sizes))
    print(f"[{label:9s}] rounds={res['final']['round']} "
          f"global-test acc={res['final']['accuracy']:.4f}")
    return res["final"]["accuracy"]


def main():
    X, y = make_binary_dataset(4_000, 16, seed=6, noise=0.3)
    a_u = run(unbiased_split(X, y, 2, seed=0), X, y, "unbiased")
    a_b = run(biased_split(X, y, 2, bias=1.0, seed=0), X, y, "biased")
    print(f"=> difference {abs(a_u - a_b):.4f}: the protocol tolerates "
          "label-skewed clients (paper Fig 2)")


if __name__ == "__main__":
    main()
