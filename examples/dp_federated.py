"""Differentially-private asynchronous FL, parameterized by Theorem 4.

Walks the paper's parameter-selection procedure (Supp. D.3.2, Example 3):
given (s0, N_c, p, epsilon, sigma) it derives the sample-size sequence,
round count, and achievable privacy budget — then trains with gradient
clipping + per-round Gaussian noise and reports the accuracy.

    PYTHONPATH=src python examples/dp_federated.py
"""
import sys, os, math
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import StepSizeConfig
from repro.core import AsyncFLSimulator, LogRegTask, round_stepsizes
from repro.data import make_binary_dataset
from repro.dp import select_parameters


def main():
    # 1. privacy planning with the Theorem-4 accountant
    sel = select_parameters(s0c=16, N_c=10_000, p=1.0, epsilon=1.0,
                            sigma=8.0, K=25_000, r0=1.0 / math.e)
    print("accountant:", sel.summary())
    print(f"  per-round noise sigma={sel.sigma}, rounds T={sel.T}")
    print(f"  vs constant-size FL: {sel.T_constant} rounds, aggregated "
          f"noise {sel.aggregated_noise_constant:.0f} -> "
          f"{sel.aggregated_noise:.0f}")

    # 2. train with exactly those parameters
    X, y = make_binary_dataset(4_000, 16, seed=2, noise=0.3)
    n_clients = 5
    task = LogRegTask(X, y, l2=1.0 / len(X), dp_clip=0.1,
                      dp_sigma=sel.sigma)
    sizes = sel.sizes
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.15, beta=0.001), sizes)
    sim = AsyncFLSimulator(
        task, n_clients=n_clients,
        sizes_per_client=[[max(1, s // n_clients) for s in sizes]]
        * n_clients,
        round_stepsizes=etas, d=1, seed=0)
    res = sim.run(max_rounds=min(len(sizes), 150))
    print(f"DP training: rounds={res['final']['round']} "
          f"acc={res['final']['accuracy']:.4f} "
          f"(eps={sel.epsilon}, delta={sel.delta:.2e})")


if __name__ == "__main__":
    main()
