"""Cohort engine quickstart: the same async FL protocol, three engines.

The event simulator (repro.core.simulator) steps one Python client object
at a time off a heapq — faithful but interpreter-bound.  The cohort
engine (repro.cohort) holds the whole population as stacked [C, D] arrays
and advances every unblocked client in one vmapped scan per tick, so
thousands of clients per process are practical.  The device-resident
engine goes one step further: the whole tick loop runs inside a single
jitted ``lax.while_loop``, the host syncing only at eval boundaries.
With a ``sample_seed`` task all three produce the same trajectory (d=1),
which this example checks before racing them.

    PYTHONPATH=src python examples/cohort_quickstart.py
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.cohort import make_simulator
from repro.configs.base import FLConfig
from repro.core import LogRegTask
from repro.data import make_binary_dataset


def main():
    X, y = make_binary_dataset(n=4_000, d=32, seed=0, noise=0.3)
    rounds, s, etas = 3, 16, [0.1, 0.08, 0.06]

    # -- agreement on a small cohort (noise off, deterministic sampling) --
    # the engine is an FLConfig knob: same call, any implementation
    task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=0)
    kw = dict(sizes_per_client=[s] * rounds, round_stepsizes=etas,
              d=1, seed=0)
    res_ev = make_simulator(FLConfig(engine="event"), task,
                            n_clients=8, **kw).run(max_rounds=rounds)
    res_co = make_simulator(FLConfig(engine="cohort", cohort_block=16),
                            task, n_clients=8, **kw).run(max_rounds=rounds)
    res_dv = make_simulator(FLConfig(engine="device", cohort_block=16),
                            task, n_clients=8, **kw).run(max_rounds=rounds)
    dw = np.abs(np.asarray(res_ev["model"]["w"])
                - np.asarray(res_co["model"]["w"])).max()
    dw_dev = np.abs(np.asarray(res_co["model"]["w"])
                    - np.asarray(res_dv["model"]["w"])).max()
    print(f"[parity C=8]    rounds {res_ev['final']['round']} == "
          f"{res_co['final']['round']} == {res_dv['final']['round']}, "
          f"max|dw| = {dw:.2e} (cohort vs device: {dw_dev:.0e})")

    # -- throughput at a population the event engine can't hold ----------
    C = 1024
    for engine, sim_task in (("cohort", LogRegTask(X, y, l2=1.0 / len(X),
                                                   sample_seed=0)),
                             ("device", LogRegTask(X, y, l2=1.0 / len(X),
                                                   sample_seed=0))):
        t0 = time.time()
        res = make_simulator(FLConfig(engine=engine), sim_task,
                             n_clients=C, **kw).run(max_rounds=rounds)
        dt = time.time() - t0
        print(f"[{engine} C={C}] rounds={res['final']['round']} "
              f"acc={res['final']['accuracy']:.4f} "
              f"({C * rounds / dt:,.0f} client-rounds/sec incl. jit)")

    # -- fleet-heterogeneity scenarios (repro.scenarios) -----------------
    # one FLConfig knob swaps the whole network model: empirical latency
    # table (alias-sampled inside the jitted tick loop), availability
    # windows/churn, drawn fleet speeds.  Virtual completion time shows
    # what stragglers and off-windows cost the asynchronous protocol.
    C = 256
    for preset in ("uniform", "mobile_diurnal", "iot_straggler",
                   "geo_regional", "sensor_renewal"):
        sim_task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=0)
        res = make_simulator(
            FLConfig(engine="device", cohort_block=16, scenario=preset),
            sim_task, n_clients=C, **kw).run(max_rounds=rounds)
        print(f"[scenario {preset:>15} C={C}] "
              f"rounds={res['final']['round']} "
              f"virtual_time={res['final']['time']:,.0f}s "
              f"messages={res['final']['messages']}")

    # -- heterogeneity v2: per-client tables + correlated churn ----------
    # two network populations assigned per client (a [T, K] table stack
    # gathered over table_id[c] inside the jitted loop) and regional
    # outages sharing a per-(epoch, region) factor — still bit-identical
    # between the host-loop and device engines.
    from repro.scenarios import (LatencyTable, RegionalChurn, Scenario,
                                 TableAssignment)
    scn = Scenario(
        "two_pop_regional",
        (LatencyTable.from_lognormal(median=0.08, sigma=0.4, n_bins=8),
         LatencyTable.from_pareto(scale=0.2, alpha=1.3, n_bins=8)),
        RegionalChurn(n_regions=4, p_available=0.9, p_region_up=0.95),
        assignment=TableAssignment("draw", weights=(0.7, 0.3)))
    sim_task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=0)
    res = make_simulator(
        FLConfig(engine="device", cohort_block=16, scenario=scn),
        sim_task, n_clients=C, **kw).run(max_rounds=rounds)
    print(f"[scenario {scn.name} C={C}] rounds={res['final']['round']} "
          f"virtual_time={res['final']['time']:,.0f}s "
          f"messages={res['final']['messages']}")

    # -- telemetry (repro.telemetry): every run() returns a MetricsReport
    # with the communication census (per-client messages / bytes on the
    # wire), the staleness-at-apply histogram, the far-tier overflow
    # high-water mark (the ring_cap tuning datum) and, when the task
    # carries DP noise, per-client (epsilon, sigma, rounds) accounting.
    # Counters are bitwise identical between the cohort engines and exact
    # against the event sim at d=1.
    tel = res["telemetry"]
    print("[telemetry]")
    print(tel.summary())

    # the event simulator can additionally stream a JSONL trace of every
    # send / apply / broadcast (kind + round + client + staleness):
    import io
    buf = io.StringIO()
    sim_task = LogRegTask(X, y, l2=1.0 / len(X), sample_seed=0)
    make_simulator(FLConfig(engine="event"), sim_task, n_clients=8,
                   trace=buf, **kw).run(max_rounds=rounds)
    lines = buf.getvalue().splitlines()
    print(f"[trace] {len(lines)} JSONL records; first: {lines[0]}")


if __name__ == "__main__":
    main()
