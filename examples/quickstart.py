"""Quickstart: asynchronous FL on a strongly-convex problem in ~60 lines.

Reproduces the paper's core recipe — increasing sample sizes + diminishing
round step sizes — and compares against original (constant/constant) FL.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SampleSequenceConfig, StepSizeConfig
from repro.core import (AsyncFLSimulator, LogRegTask, round_stepsizes,
                        rounds_for_budget, run_sync_baseline)
from repro.data import make_binary_dataset


def main():
    # 1. data + strongly-convex objective (logistic regression + L2)
    X, y = make_binary_dataset(n=4_000, d=32, seed=0, noise=0.3)
    task = LogRegTask(X, y, l2=1.0 / len(X))
    K = 8_000                      # total gradient budget
    n_clients = 5

    # 2. the paper's recipe: s_i = 100 + 100 i,  eta_i = 0.1 / (1 + 0.001 t)
    sizes = rounds_for_budget(
        SampleSequenceConfig(kind="linear", s0=100, a=100.0), K)
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_t", eta0=0.1, beta=0.001), sizes)

    # 3. run the asynchronous protocol (event-driven network simulator)
    sim = AsyncFLSimulator(
        task, n_clients=n_clients,
        sizes_per_client=[[max(1, s // n_clients) for s in sizes]]
        * n_clients,
        round_stepsizes=etas, d=1, seed=0,
        speeds=[1.0, 0.8, 1.2, 0.9, 1.1])   # heterogeneous clients
    res = sim.run(max_rounds=len(sizes))
    print(f"[async, increasing]  rounds={res['final']['round']:3d} "
          f"acc={res['final']['accuracy']:.4f} "
          f"messages={res['final']['messages']}")

    # 4. original FL baseline: constant step + constant sample size
    const = run_sync_baseline(task, n_clients=n_clients,
                              n_rounds=K // 400,
                              sample_size=400 // n_clients, eta=0.0025)
    print(f"[sync,  constant]    rounds={const['final']['round']:3d} "
          f"acc={const['final']['accuracy']:.4f}")
    print("=> same-or-better accuracy in far fewer communication rounds "
          "(paper Fig 1a)")


if __name__ == "__main__":
    main()
