"""End-to-end driver: asynchronous FL pre-training of a ~100M-class LM.

Trains a reduced 4-layer gemma-family decoder (same code path as the
production configs; see --full for the real sizes, which need the TPU
mesh of launch/dryrun.py) through the full async protocol for a few
hundred local steps, with round-growing sample sizes.

``--engine cohort|device`` runs the same task through the batched cohort
engines via the flat-params adapter (``repro.cohort.flat``) — the
whole population advances as one vmapped [C, D] block, which is the path
that scales past a handful of clients.  Batches are seed-addressed
((client, round, iteration) via ``fold_in``), so all engines follow the
same data order.

    PYTHONPATH=src python examples/llm_fl_pretrain.py [--rounds 8]
    PYTHONPATH=src python examples/llm_fl_pretrain.py --engine device
"""
import sys, os, argparse, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.cohort import make_simulator
from repro.core import BatchModelTask, round_stepsizes
from repro.configs import get_config, reduced
from repro.configs.base import StepSizeConfig
from repro.data import SeedAddressedBatcher
from repro.models import init_params, train_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--engine", default="event",
                    choices=["event", "cohort", "device"])
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), n_layers=args.layers,
                  d_model=args.d_model, vocab=2048)
    n_params = cfg.param_count()
    print(f"{cfg.arch_id} reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"~{n_params/1e6:.1f}M params")

    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batcher = SeedAddressedBatcher(cfg, batch_size=args.batch,
                                   seq_len=args.seq, seed=0)
    task = BatchModelTask(cfg, params, batcher)

    # growing rounds: 1, 2, 3, ... local batch-steps per round
    sizes = [[1 + i for i in range(args.rounds)]] * args.clients
    etas = round_stepsizes(
        StepSizeConfig(kind="inv_sqrt", eta0=0.1, beta=0.05),
        sizes[0])

    loss0 = float(train_loss(cfg, params, batcher(0, 0, 0)))
    t0 = time.time()
    sim = make_simulator(args.engine, task, n_clients=args.clients,
                         sizes_per_client=sizes,
                         round_stepsizes=etas, d=1, seed=0,
                         speeds=[1.0 + 0.2 * c
                                 for c in range(args.clients)])
    res = sim.run(max_rounds=args.rounds)
    loss1 = float(train_loss(cfg, res["model"], batcher(0, 0, 0)))
    steps = sum(sizes[0]) * args.clients
    print(f"async FL [{args.engine}]: "
          f"{res['final']['round']} rounds, {steps} local steps, "
          f"{res['final']['messages']} messages, "
          f"wall {time.time()-t0:.1f}s")
    print(f"eval loss {loss0:.3f} -> {loss1:.3f}")
    assert loss1 < loss0, "loss should decrease"


if __name__ == "__main__":
    main()
